"""Quickstart: the paper's whole story in one script.

1. A tiny MLP written once against the transparent dispatch API.
2. The same model runs under three policies — pure-jnp reference, XLA,
   Pallas (interpret) — with identical numerics and zero model-code changes.
3. The HSA runtime path: presynthesized roles, bounded regions with LRU,
   and the Table II overhead ledger.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401  (registers reference/xla/pallas kernels)
from repro.core import dispatch
from repro.core.hsa import hsa_init, hsa_shut_down, run_packet_sync
from repro.core.ledger import OverheadLedger
from repro.core.registry import GLOBAL_REGISTRY


def tiny_mlp(x, w1, w2):
    """User model code: no backend specifics, just logical ops."""
    h = dispatch.op("matmul", x, w1, activation="silu")
    h = dispatch.op("rmsnorm", h, jnp.ones(h.shape[-1], h.dtype))
    return dispatch.op("matmul", h, w2)


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(128, 256)) * 0.05, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(256, 32)) * 0.05, jnp.float32)

    print("== 1. transparent backend switch (same code, same numbers) ==")
    outs = {}
    for policy in ("reference", "xla", "pallas"):
        with dispatch.use(prefer=dispatch.policy_from_flag(policy),
                          interpret=True):
            outs[policy] = np.asarray(tiny_mlp(x, w1, w2))
        print(f"  policy={policy:10s} out[0,:3]={np.round(outs[policy][0,:3], 4)}")
    assert np.allclose(outs["reference"], outs["xla"], atol=1e-4)
    assert np.allclose(outs["reference"], outs["pallas"], atol=1e-3)
    print("  numerics agree across all three backends\n")

    print("== 2. HSA runtime: roles, regions, LRU, overhead ledger ==")
    ledger = OverheadLedger()
    sys_ = hsa_init(num_regions=2, ledger=ledger)
    try:
        impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
        a128 = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        a256 = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        w1s = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        w2s = jax.ShapeDtypeStruct((256, 32), jnp.float32)
        lib = sys_.library
        r1 = lib.make_role(impl, (a128, w1s), name="fc1")
        r2 = lib.make_role(impl, (a256, w2s), name="fc2")
        lib.synthesize_all()                      # presynthesis (device setup)

        agent = sys_.default_agent
        q, ex = sys_.queue_of(agent), sys_.executor_of(agent)
        for step in range(5):                     # both roles stay resident
            p1 = q.dispatch(r1.key, x, w1)
            h = run_packet_sync(ex, q, p1)
            p2 = q.dispatch(r2.key, jnp.asarray(h), w2)
            run_packet_sync(ex, q, p2)
        rm = sys_.regions_of(agent)
        print(f"  residency: {rm.stats} (regions={rm.num_regions})")
        print("  ledger (paper Table II layout):")
        for line in ledger.table().splitlines():
            print("   ", line)
    finally:
        hsa_shut_down()


if __name__ == "__main__":
    main()
