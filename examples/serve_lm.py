"""Serving with multi-tenant accelerator sharing — the paper's §III pitch.

A batched LM serving engine (continuous batching over fixed slots) runs
alongside a second producer submitting pre/post-processing conv jobs to the
SAME HSA queue — the accelerator "is not monopolized by the network and can
be used for other tasks like pre- and post-processing steps."

The engine runs with ``decode_fusion=4``: each launch is a jitted scan of 4
decode steps with on-device sampling, so the per-launch invocation overhead
(paper Table II row 3) is paid once per 4 tokens — with token streams
bitwise-identical to unfused decoding (checked at the end).

It also runs **paged** (``paged=True``): KV lives in a global page pool
addressed through per-request block tables — memory allocated at runtime
the way the paper's reconfigurable regions are, instead of a dense
``[slots, max_len]`` reservation per slot.  The demo at the end serves the
same prompts through a paged engine at *equal KV memory* but a quarter of
the dense slot count's reservation per request, and shows the identical
token streams plus the ledger's reserved/used/stranded memory split.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.hsa import hsa_init, hsa_shut_down
from repro.core.ledger import OverheadLedger
from repro.core.registry import GLOBAL_REGISTRY, KernelImpl
from repro.kernels import ref
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine


def main():
    # --- the LM being served -------------------------------------------------
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=128, vocab=512)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(7))
    engine = ServeEngine(model, params, batch_slots=4, max_len=96,
                         temperature=0.0, decode_fusion=4)

    prompts = [
        [1, 17, 33, 7],
        [2, 5],
        [9, 9, 9, 9, 9, 9],
        [4, 44, 14],
        [21, 12],
    ]
    for p in prompts:
        engine.submit(p, max_new_tokens=12)

    # --- a second producer on the same agent (sensor-fusion conv jobs) --------
    ledger = OverheadLedger()
    hsa_shut_down()
    sys_ = hsa_init(num_regions=2, ledger=ledger)
    conv_impl = KernelImpl(op="sensor_conv", device_kind="any", source="xla",
                           fn=lambda x: ref.conv2d(x, jnp.ones((3, 3, 1, 1),
                                                               jnp.int16)))
    GLOBAL_REGISTRY.register(conv_impl, allow_override=True)
    frame_spec = jax.ShapeDtypeStruct((1, 32, 32, 1), jnp.int16)
    conv_role = sys_.library.make_role(conv_impl, (frame_spec,),
                                       name="sensor_conv")
    sys_.library.synthesize_all()
    agent = sys_.default_agent
    q, ex = sys_.queue_of(agent), sys_.executor_of(agent)

    rng = np.random.default_rng(0)
    done, frames = [], 0
    step = 0
    while True:
        finished = engine.step()          # one fused wave: up to 4 tokens/slot
        done += finished
        # interleave: the "OpenCL" producer pushes a camera frame each step
        frame = jnp.asarray(rng.integers(-99, 99, size=(1, 32, 32, 1)), jnp.int16)
        pkt = q.dispatch(conv_role.key, frame, producer="opencl")
        ex.drain(q)
        pkt.completion.wait_eq(0)
        frames += 1
        step += 1
        if (not engine._active and not engine._queue) or step > 200:
            break

    tokens = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests ({tokens} tokens in {step} fused "
          f"decode waves) alongside {frames} conv frames on one agent")

    # fusion is a launch-count optimization, never a sampling change: the
    # unfused engine reproduces the exact token streams
    unfused = ServeEngine(model, params, batch_slots=4, max_len=96,
                          temperature=0.0, decode_fusion=1)
    for p in prompts:
        unfused.submit(p, max_new_tokens=12)
    same = {r.uid: r.generated for r in unfused.run_to_completion()} == {
        r.uid: r.generated for r in done
    }
    print(f"bitwise-identical to decode_fusion=1: {same}")
    # prompt bucketing: power-of-two padded prefill lengths hit the jit cache
    distinct = len({len(p) for p in prompts})
    unbucketed = ServeEngine(model, params, batch_slots=4, max_len=96,
                             bucket_prompts=False)
    for p in prompts:
        unbucketed.submit(p, max_new_tokens=1)
    unbucketed.run_to_completion()
    print(f"prefill traces: {engine.prefill_traces} bucketed vs "
          f"{unbucketed.prefill_traces} unbucketed "
          f"({distinct} distinct prompt lengths)")
    for req in sorted(done, key=lambda r: r.uid):
        print(f"  req {req.uid}: prompt={list(req.prompt)} -> "
              f"generated={req.generated}")

    # --- paged KV cache: same requests, runtime-allocated memory -----------
    # dense above: 4 slots x 96 rows reserved.  Paged: the same 384 KV rows
    # as a pool of 24-row pages shared by up to 8 live requests — admission
    # is bounded by actual footprint (AdmissionPolicy), not worst case.
    from repro.core.ledger import OverheadLedger as _Ledger

    pled = _Ledger()
    paged_eng = ServeEngine(model, params, batch_slots=8, max_len=96,
                            temperature=0.0, decode_fusion=4, paged=True,
                            page_size=24, pool_pages=17, ledger=pled)
    for p in prompts:
        paged_eng.submit(p, max_new_tokens=12)
    paged_done = paged_eng.run_to_completion()
    paged_same = {r.uid: r.generated for r in paged_done} == {
        r.uid: r.generated for r in done
    }
    split = pled.memory_split()
    print(f"\npaged engine: bitwise-identical to dense: {paged_same}; "
          f"sustained concurrency "
          f"{paged_eng.concurrency_stats()['sustained']:.1f} "
          f"(dense slots would cap at 4)")
    print(f"paged memory split: peak reserved {split['peak_reserved_bytes']:.0f} B, "
          f"peak stranded {split['peak_stranded_bytes']:.0f} B "
          f"(dense strands max_len - len per request)")
    print(f"pages: {paged_eng.allocator.stats()}")

    # --- overcommit + graceful preemption -----------------------------------
    # growth_reserve=0.5 funds only half of each request's decode budget at
    # admission, so more requests get in — and when the pool then runs dry
    # mid-decode, victims are parked (pages reclaimed, progress kept) and
    # resumed instead of failing.  Streams stay bitwise-identical anyway.
    from repro.core.policy import AdmissionPolicy, PreemptionPolicy

    oled = _Ledger()
    over_eng = ServeEngine(
        model, params, batch_slots=8, max_len=96, temperature=0.0,
        decode_fusion=4, paged=True, page_size=8, pool_pages=6,
        admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(snapshot_threshold_tokens=16),
        ledger=oled,
    )
    for p in prompts:
        over_eng.submit(p, max_new_tokens=12)
    over_done = over_eng.run_to_completion()
    over_same = {r.uid: r.generated for r in over_done} == {
        r.uid: r.generated for r in done
    }
    oc = oled.overcommit_split()
    print(f"\novercommitted engine (growth_reserve=0.5, 5-page pool): "
          f"bitwise-identical through preemption: {over_same}")
    print(f"  preemptions={oc['preemptions']:.0f} "
          f"(snapshot resumes {oc['snapshot_resumes']:.0f}, re-prefill "
          f"{oc['reprefill_resumes']:.0f}), pages reclaimed "
          f"{oc['pages_reclaimed']:.0f}, recompute tokens "
          f"{oc['recompute_tokens']:.0f}")

    # --- tiered KV pool: budgeted host arena --------------------------------
    # The overcommitted engine above parks snapshots on the host without
    # limit.  host_budget_bytes bounds that tier: parked KV spills D2H into
    # a fixed arena, streams back H2D ahead of resume, and when the budget
    # is oversubscribed the SpillPolicy demotes victims to re-prefill
    # replay — output never changes, only the cost of coming back does.
    from repro.core.policy import SpillPolicy

    tled = _Ledger()
    tiered_eng = ServeEngine(
        model, params, batch_slots=8, max_len=96, temperature=0.0,
        decode_fusion=4, paged=True, page_size=8, pool_pages=6,
        admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(snapshot_threshold_tokens=4),
        ledger=tled, host_budget_bytes=4096,
        spill=SpillPolicy(refill_lookahead=4),
    )
    for p in prompts:
        tiered_eng.submit(p, max_new_tokens=12)
    tiered_done = tiered_eng.run_to_completion()
    tiered_same = {r.uid: r.generated for r in tiered_done} == {
        r.uid: r.generated for r in done
    }
    sp = tled.spill_split()
    print(f"\ntiered engine (host_budget_bytes=4096): "
          f"bitwise-identical through spill/refill/demotion: {tiered_same}")
    print(f"  spills={sp['spills']:.0f} ({sp['spill_bytes']:.0f} B), "
          f"refills={sp['refills']:.0f}, demotions={sp['demotions']:.0f} "
          f"(replay fallback {sp['replay_fallback_tokens']:.0f} tokens), "
          f"host peak {sp['host_peak_bytes']:.0f} B of "
          f"{sp['host_budget_bytes']:.0f} B budget")

    print("\nshared-agent ledger:")
    for line in ledger.table().splitlines():
        print(" ", line)
    hsa_shut_down()


if __name__ == "__main__":
    main()
