"""Multi-tenant FPGA sharing, end to end.

Two tenants share one agent: a "TensorFlow serving" queue dispatching a
fully-connected role, and an "OpenCL" background producer cycling conv roles
through the reconfigurable regions.  The async scheduler round-robins grants
across the queues; reconfiguration stalls only the queue that missed
residency, so the trace below shows conv reconfigurations overlapping FC
execution — the paper's dynamic-sharing claim, observable per event.

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  (registers reference/xla/pallas kernels)
from repro.core.hsa import Queue, Scheduler, VirtualClock, dispatch_packet
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import RoleLibrary

RNG = np.random.default_rng(0)


def _mk_roles(lib: RoleLibrary):
    """Paper-style working set: one FC role + two conv 'bitstreams'."""
    mm = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    conv = GLOBAL_REGISTRY.resolve("conv2d", "any", ("xla", "reference"))
    roles = {}
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(128, 128)), jnp.float32)
    roles["role1_fc"] = (lib.make_role(mm, (a, a), name="role1_fc"), (x, x))
    xi = jnp.asarray(RNG.normal(size=(1, 32, 32, 1)), jnp.float32)
    xa = jax.ShapeDtypeStruct((1, 32, 32, 1), jnp.float32)
    for name, k in (("role3_conv5x5", 5), ("role4_conv3x3", 3)):
        w = jnp.asarray(RNG.normal(size=(k, k, 1, 1)), jnp.float32)
        wa = jax.ShapeDtypeStruct((k, k, 1, 1), jnp.float32)
        roles[name] = (lib.make_role(conv, (xa, wa), name=name), (xi, w))
    return roles


def _run(lookahead: int, burst: bool = False) -> Scheduler:
    ledger = OverheadLedger()
    lib = RoleLibrary(ledger=ledger)
    roles = _mk_roles(lib)
    regions = RegionManager(2, ledger=ledger)

    # fixed costs make the printed schedule easy to read; drop cost_model to
    # use real measured durations instead
    cost = {"reconfig": 5e-3, "exec": 1e-3}
    sched = Scheduler(
        regions, lib, ledger=ledger, clock=VirtualClock(),
        cost_model=lambda kind, what, measured: cost[kind],
        lookahead=lookahead,
    )
    q_tf = sched.add_queue(Queue(None, 256, name="tf-serving"))
    q_cl = sched.add_queue(Queue(None, 256, name="opencl"))

    fc, fc_args = roles["role1_fc"]
    c5, c5_args = roles["role3_conv5x5"]
    c3, c3_args = roles["role4_conv3x3"]

    if burst:
        # burst AQL submission: all 4 FC packets land on ONE doorbell, and
        # the grant loop drains the burst in a single wakeup
        q_tf.submit_burst([
            dispatch_packet(fc.key, *fc_args, producer="tf") for _ in range(4)
        ])
        for step in range(4):
            q_cl.dispatch((c5 if step % 2 == 0 else c3).key,
                          *(c5_args if step % 2 == 0 else c3_args),
                          producer="opencl")
    else:
        for step in range(4):
            q_tf.dispatch(fc.key, *fc_args, producer="tf")
            q_cl.dispatch((c5 if step % 2 == 0 else c3).key,
                          *(c5_args if step % 2 == 0 else c3_args),
                          producer="opencl")

    sched.run_until_idle()
    return sched


def main() -> None:
    sched = _run(lookahead=0)
    print("event log (virtual ms):")
    for ev in sched.event_log():
        print(f"  {ev.t*1e3:8.2f}  {ev.kind:15s} {ev.queue:11s} {ev.what}")
    tl = sched.timeline()
    print(f"\ndevice idle fraction: {tl['idle_fraction']:.3f} "
          f"(makespan {tl['makespan_s']*1e3:.1f} ms, busy {tl['busy_s']*1e3:.1f} ms)")
    print("\nper-queue breakdown:")
    for name, rep in sorted(sched.queue_report().items()):
        print(f"  {name:11s} exec {rep['exec_s']*1e3:6.1f} ms   "
              f"wait {rep['wait_s']*1e3:6.1f} ms   "
              f"reconfig {rep['reconfig_s']*1e3:6.1f} ms   "
              f"({int(rep['dispatched'])} packets)")

    # same workload with the reconfiguration-prefetch pipeline: conv loads
    # start while the opencl queue is still stalled on the previous one
    ahead = _run(lookahead=4)
    print(f"\nlookahead=4: exposed reconfig "
          f"{ahead.exposed_reconfig_s()*1e3:.1f} ms "
          f"(reactive {sched.exposed_reconfig_s()*1e3:.1f} ms); "
          f"prefetch events: "
          f"{sum(1 for e in ahead.event_log() if e.kind.startswith('prefetch'))}")

    # same workload again, the serving tenant submitting as one burst: one
    # doorbell for its 4 packets, submit cost amortized — compare the tf
    # tenant's submit totals (producer_breakdown keeps the opencl tenant's
    # individually-submitted packets out of both numbers)
    from repro.core import ledger as L

    solo_tf = sched.ledger.producer_breakdown()["tf"][L.DISPATCH_SUBMIT]
    burst_sched = _run(lookahead=0, burst=True)
    burst_tf = burst_sched.ledger.producer_breakdown()["tf"][L.DISPATCH_SUBMIT]
    print(f"\nburst submission (tf tenant, {burst_tf.count} packets): "
          f"{burst_tf.total_s*1e6:.0f} us total on one doorbell vs "
          f"{solo_tf.total_s*1e6:.0f} us submitted per-packet")


if __name__ == "__main__":
    main()
