"""End-to-end training driver: a small llama-family LM on synthetic data.

Fault-tolerant loop (checkpoint/restart, straggler watchdog), sharded
train_step, deterministic data pipeline.  Defaults train a ~25M-param model
for 200 steps on CPU in a few minutes; ``--params 100m --steps 300`` scales up
when you have the cycles.

Run: PYTHONPATH=src python examples/train_lm.py [--steps N] [--resume]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import init_train_state, make_train_step


def small_config(size: str) -> ArchConfig:
    base = ARCHS["llama3.2-1b"]
    if size == "100m":
        return dataclasses.replace(
            base, name="llama-100m", num_layers=8, d_model=768, num_heads=12,
            num_kv_heads=4, d_ff=2048, vocab_size=8192, head_dim=64,
            remat="none",
        )
    return dataclasses.replace(
        base, name="llama-25m", num_layers=4, d_model=384, num_heads=6,
        num_kv_heads=2, d_ff=1024, vocab_size=4096, head_dim=64, remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="25m", choices=["25m", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--no-ckpt", action="store_true")
    args = ap.parse_args()

    cfg = small_config(args.params)
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules.for_arch(cfg, mesh)
    model = build_model(cfg)
    opt = OptConfig(kind="adamw", lr=6e-4, warmup_steps=20,
                    decay_steps=args.steps)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))

    with jax.set_mesh(mesh):
        step, *_ = make_train_step(model, opt, rules, global_batch=args.batch)
        params, opt_state = init_train_state(model, opt, rules,
                                             jax.random.key(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"model {cfg.name}: {n/1e6:.1f}M params")

        loop = TrainLoop(
            step,
            lambda s: {k: jnp.asarray(v) for k, v in data.batch_at(s).items()},
            LoopConfig(
                total_steps=args.steps,
                ckpt_dir=None if args.no_ckpt else args.ckpt_dir,
                ckpt_every=50,
                log_every=10,
            ),
        )
        params, opt_state, report = loop.run(params, opt_state)
        print(f"done: {report.steps_run} steps, "
              f"final loss {report.last_metrics.get('loss', float('nan')):.4f}, "
              f"resumed_from={report.resumed_from}, "
              f"stragglers={report.stragglers}")


if __name__ == "__main__":
    main()
