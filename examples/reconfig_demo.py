"""Region-budget sweep: LRU thrashing → residency, and the role planner.

Reproduces the dynamics behind paper Table II's reconfiguration row: a model
whose working set is W roles, executed under region budgets R = 1..W+2.
Below W the LRU thrashes (every dispatch reconfigures); at R >= W everything
stays resident and dispatches cost microseconds.  The planner (paper §IV's
generic-vs-fixed-weight trade-off) is then run against the measured costs.

Run: PYTHONPATH=src python examples/reconfig_demo.py
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401
from repro.core import ledger as L
from repro.core import policy
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary


def main():
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    rng = np.random.default_rng(0)

    # a 6-role working set (distinct shapes = distinct "bitstreams")
    dims = [64, 96, 128, 160, 192, 224]
    lib = RoleLibrary(ledger=OverheadLedger())
    roles, args = [], []
    for d in dims:
        a = jax.ShapeDtypeStruct((d, d), jnp.float32)
        roles.append(lib.add(Role(impl, (a, a), name=f"fc{d}")))
        x = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
        args.append((x, x))
    lib.synthesize_all()

    print("R (regions) | hit rate | reconfigs | mean step [ms]")
    measured = {}
    for budget in range(1, len(dims) + 3):
        ledger = OverheadLedger()
        rm = RegionManager(budget, ledger=ledger)
        t0 = time.perf_counter()
        steps = 30
        for _ in range(steps):                    # one "inference" = all roles
            for role, a in zip(roles, args):
                rm.ensure_resident(role)
                jax.block_until_ready(role(*a))
        dt = (time.perf_counter() - t0) / steps
        s = rm.stats
        print(f"{budget:11d} | {s.hit_rate:8.2f} | {s.misses:9d} | {dt*1e3:11.2f}")
        measured[budget] = (s.hit_rate, dt)
        for r in roles:
            r.unload()

    # --- role planner on measured costs (paper §IV trade-off) -----------------
    print("\nplanner: generic vs fixed-weight under a 4-region budget")
    cost = policy.CostModel(
        reconfig_s=3e-3,
        dispatch_s=50e-6,
        exec_generic_s={"fc": 300e-6},
        exec_fixed_s={"fc": 200e-6},      # specialized roles run ~1.5x faster
    )
    for n_layers in (3, 8, 16):
        trace = [policy.Invocation("fc", i) for i in range(n_layers)]
        plan = policy.plan_roles(trace, budget=4, cost=cost)
        print(f"  {n_layers:2d} layers -> {plan.assignment['fc']:12s} "
              f"(predicted step {plan.predicted.total_s*1e3:.2f} ms, "
              f"hit rate {plan.predicted.hit_rate:.2f})")


if __name__ == "__main__":
    main()
