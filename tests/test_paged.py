"""Paged KV cache: dense↔paged bitwise equivalence, allocator properties,
admission, and the concurrency win at equal memory.

The acceptance bar mirrors fused decode's: paging is a pure *memory
management* change — token streams must be bitwise-identical to the dense
engine for the same requests, across sampling modes and fusion depths, or
the paged engine is silently a different model.
"""

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core.ledger import OverheadLedger
from repro.core.policy import AdmissionPolicy, PreemptionPolicy
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, ServeTruncated
from repro.serve.paged import (
    PageAllocator,
    PagePoolExhausted,
    TRASH_PAGE,
    pages_for,
)


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


PROMPTS = [[3, 14, 15, 92], [7, 8], [1, 2, 3, 4, 5, 6], [42]]


def _generate(model, params, *, paged, fusion=1, temperature=0.0, slots=2,
              max_new=7, seed=0, prompts=PROMPTS, **kw):
    eng = ServeEngine(model, params, batch_slots=slots, max_len=32,
                      decode_fusion=fusion, temperature=temperature,
                      seed=seed, paged=paged,
                      page_size=8 if paged else 16, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = sorted(eng.run_to_completion(), key=lambda r: r.uid)
    return [r.generated for r in done], eng


# ---------------------------------------------------------------------------
# dense <-> paged equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fusion", [1, 4])
def test_paged_greedy_bitwise_identical(engine_model, fusion):
    _, model, params = engine_model
    dense, _ = _generate(model, params, paged=False, fusion=fusion)
    paged, eng = _generate(model, params, paged=True, fusion=fusion)
    assert paged == dense
    assert all(len(g) == 7 for g in paged)
    # every page back in the pool the moment serving drained
    eng.allocator.check_invariants()
    assert eng.allocator.free_pages == eng.allocator.total_pages


@pytest.mark.parametrize("fusion", [1, 4])
def test_paged_temperature_bitwise_identical(engine_model, fusion):
    """Seeded temperature sampling must survive paging at any fusion depth —
    the draw depends only on (seed, uid, logits), and paged logits are
    bitwise-equal to dense."""
    _, model, params = engine_model
    dense, _ = _generate(model, params, paged=False, fusion=fusion,
                         temperature=0.7, seed=3)
    paged, _ = _generate(model, params, paged=True, fusion=fusion,
                         temperature=0.7, seed=3)
    assert paged == dense
    other, _ = _generate(model, params, paged=True, fusion=fusion,
                         temperature=0.7, seed=4)
    assert other != dense          # the seed knob is still live under paging


def test_paged_equal_memory_doubles_concurrency(engine_model):
    """At equal KV bytes (2 dense slots x 32 rows == 8 usable pages x 8
    rows) the paged engine sustains >= 2x the live requests — the tentpole
    claim, scaled down to test size — with identical streams."""
    _, model, params = engine_model
    reqs = [[3 + i, 14, 15] for i in range(8)]
    dense, deng = _generate(model, params, paged=False, slots=2, max_new=6,
                            prompts=reqs)
    paged, peng = _generate(model, params, paged=True, slots=8, max_new=6,
                            prompts=reqs, pool_pages=9)
    assert paged == dense
    ratio = (peng.concurrency_stats()["sustained"]
             / deng.concurrency_stats()["sustained"])
    assert ratio >= 2.0, peng.concurrency_stats()


def test_paged_rejects_recurrent_cache(engine_model):
    cfg = reduced(ARCHS["mamba2-780m"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                    page_size=8)


def test_paged_requires_page_aligned_max_len(engine_model):
    _, model, params = engine_model
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(model, params, batch_slots=2, max_len=30, paged=True,
                    page_size=8)


def test_paged_memory_split_accounting(engine_model):
    """The ledger's memory_split must show paged stranding < dense stranding
    on the same requests (pages strand at most a page tail; dense strands
    max_len - len)."""
    _, model, params = engine_model
    dled, pled = OverheadLedger(), OverheadLedger()
    _generate(model, params, paged=False, ledger=dled)
    _generate(model, params, paged=True, ledger=pled)
    dense, paged = dled.memory_split(), pled.memory_split()
    assert paged["peak_reserved_bytes"] > 0
    assert paged["peak_stranded_bytes"] < dense["peak_stranded_bytes"]


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------


def test_allocator_double_free_raises():
    alloc = PageAllocator(8)
    pages = alloc.allocate(owner=1, n=3)
    alloc.free(1, pages)
    with pytest.raises(ValueError, match="double free"):
        alloc.free(1, pages[:1])


def test_allocator_foreign_free_raises():
    alloc = PageAllocator(8)
    pages = alloc.allocate(owner=1, n=2)
    with pytest.raises(ValueError, match="belongs to"):
        alloc.free(2, pages)
    alloc.free(1, pages)


def test_allocator_never_hands_out_trash_page():
    alloc = PageAllocator(8)
    pages = alloc.allocate(owner=1, n=7)       # the whole usable pool
    assert TRASH_PAGE not in pages
    with pytest.raises(PagePoolExhausted):
        alloc.allocate(owner=2, n=1)
    with pytest.raises(ValueError, match="scratch"):
        alloc.free(1, [TRASH_PAGE])


def test_allocator_churn_invariants():
    """Random admit/grow/finish churn: no leak, no alias, allocation stats
    consistent."""
    rng = np.random.default_rng(7)
    alloc = PageAllocator(64)
    live: dict[int, list[int]] = {}
    uid = 0
    for _ in range(500):
        if live and rng.random() < 0.4:
            victim = int(rng.choice(list(live)))
            alloc.free(victim, live.pop(victim))
        elif alloc.free_pages > 4:
            uid += 1
            live[uid] = alloc.allocate(uid, int(rng.integers(1, 4)))
        elif live:                                # grow someone
            u = int(rng.choice(list(live)))
            if alloc.free_pages:
                live[u] += alloc.allocate(u, 1)
        alloc.check_invariants()
    for u, pages in list(live.items()):
        alloc.free(u, pages)
    alloc.check_invariants()
    assert alloc.free_pages == alloc.total_pages
    s = alloc.stats()
    assert s.allocs == s.frees


def test_no_leak_after_serve_truncated(engine_model):
    """Truncation parks requests with their pages (they are resumable);
    finishing the resume returns every page — nothing leaks across the
    error path."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8)
    eng.submit([1, 2, 3], max_new_tokens=10)
    eng.submit([4, 5], max_new_tokens=10)
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion(max_steps=2)
    # in-flight requests legitimately hold pages at truncation
    held = eng.allocator.allocated_pages
    assert held > 0 and len(ei.value.pending) == 2
    done = eng.run_to_completion()
    assert len(done) == 2 and all(len(r.generated) == 10 for r in done)
    eng.allocator.check_invariants()
    assert eng.allocator.free_pages == eng.allocator.total_pages


def test_engine_churn_fragmentation_bounded(engine_model):
    """Random admit/finish churn through the real engine: at every step the
    stranded reservation is bounded by live_requests x O(page_size) rows —
    internal fragmentation only, never accumulated leaks."""
    _, model, params = engine_model
    rng = np.random.default_rng(3)
    led = OverheadLedger()
    eng = ServeEngine(model, params, batch_slots=4, max_len=32, paged=True,
                      page_size=8, decode_fusion=2, ledger=led)
    submitted = 0
    for step in range(40):
        if submitted < 12 and rng.random() < 0.5:
            n = int(rng.integers(1, 6))
            eng.submit([int(t) for t in rng.integers(1, 100, size=n)],
                       max_new_tokens=int(rng.integers(1, 8)))
            submitted += 1
        eng.step()
        eng.allocator.check_invariants()
        live = len(eng._active)
        split = led.memory_split()
        if eng._token_bytes:
            stranded_rows = split["stranded_bytes"] / eng._token_bytes
            # <= one page tail + one growth page per live request
            assert stranded_rows <= live * 2 * eng.page_size, (
                step, live, stranded_rows)
    eng.run_to_completion()
    eng.allocator.check_invariants()
    assert eng.allocator.free_pages == eng.allocator.total_pages


# ---------------------------------------------------------------------------
# admission policy
# ---------------------------------------------------------------------------


def test_admission_projected_pages():
    pol = AdmissionPolicy()
    assert pol.projected_pages(4, 8, 8) == pages_for(12, 8) == 2
    assert pol.projected_pages(8, 8, 8) == 2
    half = AdmissionPolicy(growth_reserve=0.5)
    assert half.projected_pages(4, 8, 8) == 1      # projects 4 + 4 tokens
    assert half.projected_pages(4, 0, 8) == 1      # at least one new token


def test_admission_accounts_projected_growth():
    pol = AdmissionPolicy()
    # 4 free pages, but live requests will still map 3 more: only 1 is real
    assert pol.admit(free_pages=4, projected_growth_pages=3, request_pages=1)
    assert not pol.admit(free_pages=4, projected_growth_pages=3,
                         request_pages=2)
    held = AdmissionPolicy(watermark_pages=2)
    assert not held.admit(free_pages=4, projected_growth_pages=1,
                          request_pages=2)


def test_admission_head_of_line_blocks_until_pages_free(engine_model):
    """A pool sized for ~1 live request serializes admission through the
    AdmissionPolicy (not the slot count), still completing everything."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=4, max_len=32, paged=True,
                      page_size=8, pool_pages=4)   # 3 usable pages
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_to_completion()
    assert len(done) == 4 and all(len(r.generated) == 6 for r in done)
    assert eng.peak_concurrency < 4                # the pool was the limit
    assert eng.allocator.free_pages == eng.allocator.total_pages


def test_submit_rejects_never_fitting_request(engine_model):
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8, pool_pages=3)   # 2 usable pages
    with pytest.raises(ValueError, match="block the queue forever"):
        eng.submit(list(range(20)), max_new_tokens=10)


def test_submit_rejection_is_worst_case_under_overcommit(engine_model):
    """Permanent rejection must test the growth_reserve-independent worst
    case: a request whose *projection* fits but whose full budget cannot
    would otherwise park forever instead of failing fast at submit."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8, pool_pages=3,   # 2 usable pages
                      admission=AdmissionPolicy(growth_reserve=0.1))
    # projects pages_for(4 + 3) = 1 page, but worst case is 4 pages
    with pytest.raises(ValueError, match="block the queue forever"):
        eng.submit([1, 2, 3, 4], max_new_tokens=28)
    eng.submit([1, 2, 3, 4], max_new_tokens=8)     # worst case 2 pages: fits
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].generated) == 8


# ---------------------------------------------------------------------------
# preemption edge cases (PR 5)
# ---------------------------------------------------------------------------


def _dense_streams(model, params, reqs, **kw):
    eng = ServeEngine(model, params, batch_slots=len(reqs), max_len=32, **kw)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(), key=lambda r: r.uid)
    return [r.generated for r in done]


@pytest.mark.parametrize("threshold", [0, 1000])   # snapshot / re-prefill
def test_preempt_during_prefill_phase(engine_model, threshold):
    """A victim parked right after its prefill — one sampled token, zero
    decode steps — must resume and finish bitwise-identically."""
    _, model, params = engine_model
    reqs = [([3, 14, 15, 92], 6), ([7, 8], 6)]
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8,
                      preemption=PreemptionPolicy(
                          snapshot_threshold_tokens=threshold))
    eng.submit(*reqs[0])
    req = eng._queue.pop(0)                 # admit by hand: prefill only,
    eng._prefill_slot(0, req)               # no decode launch yet
    eng._active[0] = req
    assert len(req.generated) == 1
    eng.preempt(req.uid)
    assert req.parked and eng.allocator.allocated_pages == 0
    eng.submit(*reqs[1])
    done = sorted(eng.run_to_completion(), key=lambda r: r.uid)
    assert [r.generated for r in done] == _dense_streams(model, params, reqs)


@pytest.mark.parametrize("threshold", [0, 1000])
def test_preempt_at_exact_page_boundary(engine_model, threshold):
    """Park when written rows exactly fill the mapped pages (pos a multiple
    of page_size): the snapshot must keep exactly pos/page_size pages and
    the resume's next write must map a fresh page."""
    _, model, params = engine_model
    prompt = list(range(1, 9))              # prefill pos = 8 = page_size
    eng = ServeEngine(model, params, batch_slots=1, max_len=32, paged=True,
                      page_size=8, decode_fusion=1,
                      preemption=PreemptionPolicy(
                          snapshot_threshold_tokens=threshold))
    eng.submit(prompt, max_new_tokens=9)    # runs through rows 8..16
    req = eng._queue.pop(0)
    eng._prefill_slot(0, req)
    eng._active[0] = req
    assert int(eng._pos[0]) == 8 and int(eng._mapped[0]) == 1
    eng.preempt(req.uid)
    entry = eng._parked[0]
    assert entry.pos == 8
    if entry.snapshot is not None:
        assert all(leaf.shape[1] == 1 for leaf in jax.tree.leaves(entry.snapshot))
    done = eng.run_to_completion()
    assert [r.generated for r in done] == _dense_streams(
        model, params, [(prompt, 9)])


def test_resume_while_pool_full_reparks_not_loops(engine_model):
    """A parked request whose pages are still claimed stays parked — the
    engine keeps decoding the survivor (progress, not a spin) and resumes
    the victim only when pages actually free up.

    Both requests need 3 pages worst-case of a 4-page pool; overcommitted
    admission (reserve 0.25) lets both in, so the first page-3 crossing
    organically parks the younger one, whose snapshot restore then stays
    unfundable (watermark held back) until the survivor finishes."""
    _, model, params = engine_model
    reqs = [([1, 2, 3], 16), ([4, 5], 16)]
    eng = ServeEngine(
        model, params, batch_slots=2, max_len=32, paged=True, page_size=8,
        pool_pages=5, decode_fusion=1,
        admission=AdmissionPolicy(growth_reserve=0.25, watermark_pages=1),
        preemption=PreemptionPolicy(snapshot_threshold_tokens=0),
    )
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done, guard = [], 0
    while not eng.parked_requests:          # growth pressure parks uid 2
        done += eng.step()
        guard += 1
        assert guard < 30, "pool was never exhausted: test is vacuous"
    victim = eng.parked_requests[0].uid
    assert len(eng._active) == 1
    # forced resume while the survivor still holds the pool: clean no-op
    assert eng.resume(victim) is False
    assert [r.uid for r in eng.parked_requests] == [victim]
    parked_steps = 0
    while eng.parked_requests:              # survivor drains, victim waits
        done += eng.step()
        parked_steps += 1
        assert parked_steps < 60, "victim never resumed: livelock"
    assert parked_steps > 1, "victim resumed instantly: pool was never full"
    done = sorted(done + eng.run_to_completion(), key=lambda r: r.uid)
    assert eng.preemptions == 1 and eng.resumes == 1
    assert [r.generated for r in done] == _dense_streams(model, params, reqs)


def test_double_resume_and_bad_preempt_guards(engine_model):
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8)
    eng.submit([1, 2, 3], max_new_tokens=6)
    eng.step()
    uid = eng.preempt()
    with pytest.raises(ValueError, match="not active"):
        eng.preempt(uid)                    # parked, not active
    with pytest.raises(ValueError, match="not active"):
        eng.preempt(999)                    # unknown uid
    assert eng.resume(uid) is True
    with pytest.raises(ValueError, match="double resume"):
        eng.resume(uid)                     # second resume is a caller bug
    with pytest.raises(ValueError, match="no active request"):
        ServeEngine(model, params, batch_slots=1, max_len=32, paged=True,
                    page_size=8).preempt()
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].generated) == 6
