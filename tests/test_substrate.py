"""Substrate tests: checkpointing, fault-tolerant loop, data pipeline,
gradient compression, optimizers, serving engine."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.dist.collectives import (
    compression_ratio,
    dequantize_int8,
    quantize_int8,
)
from repro.models import build_model
from repro.models.params import init_params
from repro.optim.adamw import OptConfig, opt_init, opt_update
from repro.serve.engine import ServeEngine
from repro.train.loop import LoopConfig, TrainLoop


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 7, tree)
    restored, manifest = restore_checkpoint(path, tree)
    assert manifest["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), tree, restored)


def test_checkpoint_latest_and_retention(tmp_path):
    tree = _tree()
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), step, tree, keep=2)
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000005")
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_interrupted_write_is_invisible(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a writer killed mid-flight: stray .tmp dir
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000001")


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((3,), jnp.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(path, bad)


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------


def _toy_step():
    def step(params, opt_state, batch):
        params = jax.tree.map(lambda p: p - 0.1 * batch["g"], params)
        loss = jnp.sum(params["w"] ** 2)
        return params, opt_state, {"loss": loss}
    return step


def test_loop_checkpoint_and_resume(tmp_path):
    params = {"w": jnp.ones(4)}
    cfg = LoopConfig(total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=5,
                     log_every=0)
    batch_at = lambda s: {"g": jnp.full(4, 0.01)}

    loop = TrainLoop(_toy_step(), batch_at, cfg, log=lambda s: None)
    p1, _, rep1 = loop.run(params, {})
    assert rep1.steps_run == 10

    # a "restarted job" resumes from step 10 and does nothing more
    loop2 = TrainLoop(_toy_step(), batch_at, cfg, log=lambda s: None)
    p2, _, rep2 = loop2.run(params, {})
    assert rep2.resumed_from == 10 and rep2.steps_run == 0
    np.testing.assert_allclose(p1["w"], p2["w"])

    # extending total_steps continues from the checkpoint
    cfg3 = dataclasses.replace(cfg, total_steps=14)
    loop3 = TrainLoop(_toy_step(), batch_at, cfg3, log=lambda s: None)
    _, _, rep3 = loop3.run(params, {})
    assert rep3.resumed_from == 10 and rep3.steps_run == 4


def test_loop_nan_guard(tmp_path):
    def bad_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(float("nan"))}

    loop = TrainLoop(bad_step, lambda s: {}, LoopConfig(total_steps=3,
                                                        log_every=0),
                     log=lambda s: None)
    with pytest.raises(FloatingPointError):
        loop.run({"w": jnp.ones(2)}, {})


def test_loop_straggler_detection():
    import time

    def slow_step(params, opt_state, batch):
        if batch["i"] == 7:
            time.sleep(0.25)
        return params, opt_state, {"loss": jnp.float32(1.0)}

    loop = TrainLoop(slow_step, lambda s: {"i": s},
                     LoopConfig(total_steps=12, log_every=0,
                                straggler_factor=3.0),
                     log=lambda s: None)
    _, _, report = loop.run({"w": jnp.ones(2)}, {})
    assert 7 in report.stragglers


def test_loop_preemption_checkpoints(tmp_path):
    cfg = LoopConfig(total_steps=100, ckpt_dir=str(tmp_path), ckpt_every=1000,
                     log_every=0)
    loop = TrainLoop(_toy_step(), lambda s: {"g": jnp.full(4, 0.01)}, cfg,
                     log=lambda s: None)

    orig = loop.step_fn

    def step_then_preempt(params, opt_state, batch):
        out = orig(params, opt_state, batch)
        loop._preempt = True            # simulate SIGTERM arriving
        return out

    loop.step_fn = step_then_preempt
    _, _, report = loop.run({"w": jnp.ones(4)}, {})
    assert report.preempted and report.steps_run == 1
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000001")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    a, b = SyntheticTokens(cfg), SyntheticTokens(cfg)
    np.testing.assert_array_equal(a.batch_at(5)["tokens"], b.batch_at(5)["tokens"])
    assert not np.array_equal(a.batch_at(5)["tokens"], a.batch_at(6)["tokens"])
    assert a.batch_at(0)["tokens"].shape == (4, 64)
    assert a.batch_at(0)["tokens"].max() < 1000


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=32, seed=0,
                     repeat_prob=1.0)
    toks = SyntheticTokens(cfg).batch_at(0)["tokens"]
    # with repeat_prob=1 every row is periodic with period 64
    np.testing.assert_array_equal(toks[:, :64], toks[:, 64:128])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=400), st.integers(min_value=0, max_value=99))
def test_property_quantize_roundtrip_error_bounded(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.1, 10), jnp.float32)
    q, scale, shape = quantize_int8(x, block=64)
    x2 = dequantize_int8(q, scale, shape)
    # per-block error bounded by scale/2 = max|x_block|/254
    err = np.abs(np.asarray(x - x2))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-7
    assert err.max() <= bound


def test_error_feedback_unbiases_accumulation():
    """With error feedback, the *sum* of compressed steps tracks the true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(256, np.float32)
    comp_sum = np.zeros(256, np.float32)
    residual = jnp.zeros(256, jnp.float32)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        true_sum += np.asarray(g)
        carried = g + residual
        q, s, sh = quantize_int8(carried, block=64)
        sent = dequantize_int8(q, s, sh)
        residual = carried - sent
        comp_sum += np.asarray(sent)
    # the residual bounds the total drift (error feedback property)
    drift = np.abs(true_sum - comp_sum)
    assert drift.max() <= np.abs(np.asarray(residual)).max() + 1e-5


def test_compression_ratio():
    assert compression_ratio((1024, 1024)) > 1.8


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,b1", [("adamw", 0.9), ("adafactor", 0.9),
                                     ("adafactor", 0.0)])
def test_optimizers_reduce_quadratic(kind, b1):
    cfg = OptConfig(kind=kind, lr=0.1, warmup_steps=1, decay_steps=200,
                    weight_decay=0.0, b1=b1)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)),
                               jnp.float32)}
    state = opt_init(cfg, params)
    loss0 = float(jnp.mean(params["w"] ** 2))
    for _ in range(30):
        grads = jax.grad(lambda p: jnp.mean(p["w"] ** 2))(params)
        params, state, m = opt_update(cfg, params, grads, state)
    assert float(jnp.mean(params["w"] ** 2)) < 0.2 * loss0
    assert np.isfinite(m["grad_norm"])


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def _engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


def test_engine_matches_manual_decode():
    cfg, model, params = _engine_model()
    prompt = [3, 14, 15, 92]
    n_new = 6

    engine = ServeEngine(model, params, batch_slots=2, max_len=32)
    engine.submit(prompt, max_new_tokens=n_new)
    (req,) = engine.run_to_completion()

    # manual greedy decode, single sequence
    lg, cache = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                              cache_len=32)
    toks = [int(np.argmax(np.asarray(lg, np.float32)[0]))]
    for _ in range(n_new - 1):
        lg, cache = model.decode_step(
            params, jnp.asarray([[toks[-1]]]), cache
        )
        toks.append(int(np.argmax(np.asarray(lg, np.float32)[0])))
    assert req.generated == toks


def test_engine_decode_via_hsa_queue_matches_direct():
    """Routing decode launches through the async HSA scheduler (paper
    multi-tenancy path) must not change generations — even with an
    OpenCL-style background producer sharing the device."""
    import repro.kernels  # noqa: F401
    from repro.core.hsa import Queue, Scheduler, VirtualClock
    from repro.core.ledger import OverheadLedger
    from repro.core.reconfig import RegionManager
    from repro.core.registry import GLOBAL_REGISTRY
    from repro.core.roles import Role, RoleLibrary

    cfg, model, params = _engine_model()
    prompt = [3, 14, 15, 92]

    direct = ServeEngine(model, params, batch_slots=2, max_len=32)
    direct.submit(prompt, max_new_tokens=5)
    (want,) = direct.run_to_completion()

    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    regions = RegionManager(2, ledger=led)
    sched = Scheduler(regions, lib, ledger=led, clock=VirtualClock())
    q_serve = sched.add_queue(Queue(None, 256, name="serve"))
    q_bg = sched.add_queue(Queue(None, 256, name="opencl"))

    # background tenant: a role cycling through the regions
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    r = lib.add(Role(impl, (a, a), name="bg"))
    for _ in range(4):
        q_bg.dispatch(r.key, jnp.ones((8, 8)), jnp.ones((8, 8)), producer="opencl")

    routed = ServeEngine(model, params, batch_slots=2, max_len=32,
                         hsa_queue=q_serve, hsa_scheduler=sched)
    routed.submit(prompt, max_new_tokens=5)
    (got,) = routed.run_to_completion()
    sched.run_until_idle()          # finish the background tenant's leftovers

    assert got.generated == want.generated
    rep = sched.queue_report()
    assert rep["serve"]["dispatched"] >= 5       # prefill + decode steps
    assert rep["opencl"]["dispatched"] == 4
    assert led.queue_breakdown()["serve"]["wait"].count >= 5


def test_engine_prompt_bucketing_same_tokens_fewer_traces():
    """Power-of-two prompt bucketing must not change generations (greedy) and
    must collapse per-length prefill retraces into per-bucket ones."""
    cfg, model, params = _engine_model()
    prompts = [[1, 17, 33, 7], [2, 5], [9] * 6, [4, 44, 14], [21, 12],
               [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]]

    def run(bucket):
        e = ServeEngine(model, params, batch_slots=3, max_len=32,
                        bucket_prompts=bucket)
        for p in prompts:
            e.submit(p, max_new_tokens=6)
        done = e.run_to_completion()
        return {r.uid: r.generated for r in done}, e.prefill_traces

    bucketed, traces_b = run(True)
    plain, traces_p = run(False)
    assert bucketed == plain
    distinct_lengths = len({len(p) for p in prompts})
    assert traces_p == distinct_lengths
    assert traces_b < traces_p                  # the jit cache actually hits


def test_engine_bucketing_declines_for_sliding_window_attention():
    """Ring (windowed) KV caches clip to the last `window` prefill positions
    — which would be the pads — so bucketing must stay off."""
    import dataclasses as _dc

    cfg = _dc.replace(
        reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128),
        attn_window=8,
    )
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(5))
    eng = ServeEngine(model, params, batch_slots=1, max_len=32,
                      bucket_prompts=True)
    assert eng.bucket_prompts is False
    eng.submit(list(range(1, 13)), max_new_tokens=3)   # prompt 12 > window 8
    (req,) = eng.run_to_completion()
    assert len(req.generated) == 3


def test_engine_bucketing_declines_for_recurrent_caches():
    """SSM/hybrid caches fold pad tokens into unmasked recurrent state, so
    the engine must force prompt bucketing off for those model families."""
    cfg = reduced(ARCHS["mamba2-780m"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(3))
    eng = ServeEngine(model, params, batch_slots=1, max_len=32,
                      bucket_prompts=True)
    assert eng.bucket_prompts is False
    eng.submit([5, 6, 7], max_new_tokens=3)      # still serves, unbucketed
    (req,) = eng.run_to_completion()
    assert len(req.generated) == 3


def test_engine_continuous_batching_isolation():
    """Requests admitted at different times produce the same generations as
    they would alone (per-slot positions = continuous batching correctness)."""
    cfg, model, params = _engine_model()
    prompts = [[5, 6, 7], [100, 90], [1, 2, 3, 4, 5, 6]]

    solo = []
    for p in prompts:
        e = ServeEngine(model, params, batch_slots=1, max_len=48)
        e.submit(p, max_new_tokens=5)
        (r,) = e.run_to_completion()
        solo.append(r.generated)

    e = ServeEngine(model, params, batch_slots=2, max_len=48)   # < len(prompts)
    for p in prompts:
        e.submit(p, max_new_tokens=5)
    done = sorted(e.run_to_completion(), key=lambda r: r.uid)
    assert [r.generated for r in done] == solo
