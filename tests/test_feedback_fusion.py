"""Feedback-driven FusionPolicy: K adapts to the *measured* p99 foreign
dispatch_wait instead of the launch-time queue-depth guess.

All timing is virtual (the scheduler runs on a VirtualClock and the foreign
tenant's waits are virtual-clock durations), so every adaptation step here
is deterministic.
"""

import jax.numpy as jnp
import pytest

from repro.core.hsa.clock import VirtualClock
from repro.core.hsa.queue import Queue
from repro.core.hsa.scheduler import Scheduler
from repro.core.ledger import DISPATCH_WAIT, OverheadLedger
from repro.core.policy import FusionPolicy
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary


# ---------------------------------------------------------------------------
# policy unit behaviour
# ---------------------------------------------------------------------------


def test_feedback_halves_k_per_doubling_over_target():
    pol = FusionPolicy(max_fusion=8, feedback=True, target_wait_s=1e-3)
    assert pol.choose_k(observed_wait_s=0.5e-3) == 8      # under target
    assert pol.choose_k(observed_wait_s=2e-3) == 4        # 2x over
    assert pol.choose_k(observed_wait_s=4e-3) == 2
    assert pol.choose_k(observed_wait_s=64e-3) == 1       # floor holds
    assert pol.choose_k(observed_wait_s=None, queue_depth=0) == 8


def test_feedback_measurement_overrides_queue_depth_guess():
    """With a measurement in hand, the stale queue-depth heuristic is
    ignored: an empty-looking queue with terrible observed waits still
    pulls K down, and vice versa."""
    pol = FusionPolicy(max_fusion=8, feedback=True, target_wait_s=1e-3,
                       fairness_depth=1)
    assert pol.choose_k(queue_depth=0, observed_wait_s=8e-3) == 1
    assert pol.choose_k(queue_depth=100, observed_wait_s=0.1e-3) == 8
    # no measurement yet -> fall back to the queue-depth heuristic
    assert pol.choose_k(queue_depth=100, observed_wait_s=None) == 1


def test_feedback_respects_min_fusion_and_request_len():
    pol = FusionPolicy(max_fusion=8, min_fusion=2, feedback=True,
                       target_wait_s=1e-3)
    assert pol.choose_k(observed_wait_s=1.0) == 2
    assert pol.choose_k(mean_request_len=3.0, observed_wait_s=0.0001) == 2


def test_non_feedback_policy_ignores_observation():
    pol = FusionPolicy(max_fusion=8, feedback=False)
    assert pol.choose_k(observed_wait_s=1.0) == 8


# ---------------------------------------------------------------------------
# ledger quantile window
# ---------------------------------------------------------------------------


def test_ledger_quantile_per_producer():
    led = OverheadLedger()
    for i in range(100):
        led.record(DISPATCH_WAIT, 1e-4, producer="serve")
        led.record(DISPATCH_WAIT, 1e-2 if i % 2 else 1e-3, producer="opencl")
    assert led.quantile(DISPATCH_WAIT, 0.99, producer="serve") == pytest.approx(1e-4)
    assert led.quantile(DISPATCH_WAIT, 0.99, producer="opencl") == pytest.approx(1e-2)
    assert led.quantile(DISPATCH_WAIT, 0.25, producer="opencl") == pytest.approx(1e-3)
    assert led.quantile(DISPATCH_WAIT, 0.5, producer="missing") is None
    assert sorted(led.producers()) == ["opencl", "serve"]


def test_ledger_quantile_window_is_recent():
    """The window is bounded: a regime change displaces old samples."""
    from repro.core.ledger import QUANTILE_WINDOW

    led = OverheadLedger()
    for _ in range(QUANTILE_WINDOW):
        led.record(DISPATCH_WAIT, 1.0, producer="p")
    for _ in range(QUANTILE_WINDOW):
        led.record(DISPATCH_WAIT, 1e-6, producer="p")
    assert led.quantile(DISPATCH_WAIT, 0.99, producer="p") == pytest.approx(1e-6)


# ---------------------------------------------------------------------------
# virtual-clock integration: measured foreign waits drive the engine's K
# ---------------------------------------------------------------------------


def _engine_probe(ledger):
    """A real ServeEngine shell (no jax model build) exposing exactly the
    state _observed_foreign_wait reads."""
    from repro.serve.engine import ServeEngine

    probe = ServeEngine.__new__(ServeEngine)
    probe._producer = "tf-serving"
    probe._hsa_queue = None
    probe.ledger = ledger
    probe._wait_freshness = {}
    return probe


def _foreign_tenant_round(sched, queue, ledger, clock, cost_s):
    """One foreign packet whose completion wait is a *virtual* duration:
    the scheduler stamps the completion signal with its virtual-timeline
    finish (``_complete_t``), and submit-to-completion on that timeline is
    what the tenant records as its wait."""
    t0 = clock.now()
    pkt = queue.call(lambda: None, producer="opencl")
    sched.drain(queue)
    pkt.completion.wait_eq(0)
    ledger.record(DISPATCH_WAIT, pkt.completion._complete_t - t0,
                  queue=queue.name, producer="opencl", virtual=True)


@pytest.mark.parametrize("cost_s,expect_k", [(16e-3, 1), (0.01e-3, 8)])
def test_virtual_clock_foreign_waits_drive_engine_fusion(cost_s, expect_k):
    """End to end on the virtual clock: a foreign tenant's measured waits
    (slow device -> long waits -> K collapses; fast device -> K rides the
    maximum), read by the engine through the shared ledger.

    The tenant's packets chain on the virtual compute timeline while its
    submit clock stays at 0, so round ``i`` waits ``i·cost`` — the p99 over
    32 rounds is deterministically ~32·cost (a backlog, exactly the signal
    the feedback loop is for)."""
    ledger = OverheadLedger()
    lib = RoleLibrary(ledger=ledger)
    clock = VirtualClock()
    sched = Scheduler(
        RegionManager(2, ledger=ledger), lib, ledger=ledger, clock=clock,
        cost_model=lambda kind, what, measured: (
            cost_s if kind == "exec" else 0.0
        ),
    )
    q = sched.add_queue(Queue(None, 256, name="shared"))
    for _ in range(32):
        _foreign_tenant_round(sched, q, ledger, clock, cost_s)

    # the engine-side selection logic, minus the jax model: a feedback
    # policy fed by _observed_foreign_wait over the same ledger
    from repro.serve.engine import ServeEngine

    probe = _engine_probe(ledger)
    observed = ServeEngine._observed_foreign_wait(probe)
    assert observed == pytest.approx(32 * cost_s)

    pol = FusionPolicy(max_fusion=8, feedback=True, target_wait_s=1e-3)
    assert pol.choose_k(observed_wait_s=observed) == expect_k


def test_feedback_engine_reduces_launch_depth(monkeypatch):
    """Full engine path: identical serving runs, but a ledger pre-loaded
    with slow foreign waits makes the feedback engine spend MORE launches
    (smaller K) than the same engine with a clean ledger — and the token
    stream stays identical (K never changes sampling)."""
    import jax

    from repro.configs import ARCHS, reduced
    from repro.models import build_model
    from repro.models.params import init_params
    from repro.serve.engine import ServeEngine

    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))

    def run(congested: bool):
        led = OverheadLedger()
        if congested:
            for _ in range(64):
                led.record(DISPATCH_WAIT, 20e-3, producer="opencl")
        eng = ServeEngine(
            model, params, batch_slots=1, max_len=32,
            decode_fusion=FusionPolicy(max_fusion=8, feedback=True,
                                       target_wait_s=1e-3),
            ledger=led,
        )
        launches = 0
        orig = eng._launch

        def counting_launch(fn, *a, **kw):
            nonlocal launches
            launches += 1
            return orig(fn, *a, **kw)

        eng._launch = counting_launch
        eng.submit([5, 6, 7], max_new_tokens=8)
        (req,) = eng.run_to_completion()
        return req.generated, launches

    calm_stream, calm_launches = run(congested=False)
    congested_stream, congested_launches = run(congested=True)
    assert congested_stream == calm_stream
    # calm: one prefill + one K=8 fused launch; congested: K=1 -> 8 launches
    assert congested_launches > calm_launches


def test_stale_foreign_waits_age_out():
    """A tenant that bursts and then leaves must not pin K low forever:
    after FEEDBACK_STALE_LAUNCHES launches with no new samples, its p99
    stops counting and fusion recovers."""
    from repro.serve.engine import ServeEngine

    ledger = OverheadLedger()
    for _ in range(64):
        ledger.record(DISPATCH_WAIT, 20e-3, producer="opencl")
    probe = _engine_probe(ledger)
    for _ in range(ServeEngine.FEEDBACK_STALE_LAUNCHES):
        assert ServeEngine._observed_foreign_wait(probe) == pytest.approx(20e-3)
    assert ServeEngine._observed_foreign_wait(probe) is None   # aged out
    # fresh activity revives the signal immediately
    ledger.record(DISPATCH_WAIT, 30e-3, producer="opencl")
    assert ServeEngine._observed_foreign_wait(probe) == pytest.approx(30e-3)


def test_contention_read_from_queue_ledger_with_explicit_ledger():
    """ledger= (memory accounting) alongside an HSA queue must not hide
    the queue ledger's dispatch_wait samples from the feedback loop."""
    from repro.serve.engine import ServeEngine

    q_led = OverheadLedger()
    for _ in range(16):
        q_led.record(DISPATCH_WAIT, 5e-3, producer="opencl")

    class _Q:
        ledger = q_led

    probe = _engine_probe(OverheadLedger())    # empty explicit ledger
    probe._hsa_queue = _Q()
    assert ServeEngine._observed_foreign_wait(probe) == pytest.approx(5e-3)
