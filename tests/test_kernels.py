"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registration)
from repro.kernels import ops, ref
from repro.kernels.conv2d import conv2d, conv2d_fixed_weight
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul, matmul_fixed_weight
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd import ssd

RNG = np.random.default_rng(1234)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (32, 64, 32, 16, 16, 32),
        (64, 128, 96, 32, 32, 64),
        (128, 256, 128, 128, 128, 128),
        (8, 512, 16, 8, 16, 256),
    ],
)
def test_matmul_sweep(dtype, m, k, n, bm, bn, bk):
    x, w = _rand((m, k), dtype), _rand((k, n), dtype)
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_matmul_fused_activation(activation):
    x, w = _rand((32, 64), jnp.float32), _rand((64, 32), jnp.float32)
    got = matmul(x, w, block_m=16, block_n=16, block_k=32,
                 activation=activation, interpret=True)
    want = ref.matmul(x, w, activation=activation)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_fixed_weight_role_matches_generic():
    x, w = _rand((32, 64), jnp.float32), _rand((64, 32), jnp.float32)
    fixed = matmul_fixed_weight(w, block_m=16, block_n=16, block_k=32)
    got = fixed(x, interpret=True)
    want = matmul(x, w, block_m=16, block_n=16, block_k=32, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_pallas_matmul_wrapper_batched():
    x, w = _rand((2, 3, 64), jnp.float32), _rand((64, 48), jnp.float32)
    got = ops.pallas_matmul(x, w, interpret=True)
    np.testing.assert_allclose(got, ref.matmul(x.reshape(6, 64), w).reshape(2, 3, 48),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (3, 5, 256), (64, 512)])
def test_rmsnorm_sweep(dtype, shape):
    x = _rand(shape, dtype)
    w = _rand(shape[-1:], dtype)
    got = rmsnorm(x, w, block_rows=16, interpret=True)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, hq, hkv, causal):
    B, S, D = 2, 64, 32
    q, k, v = (_rand((B, hq, S, D), dtype), _rand((B, hkv, S, D), dtype),
               _rand((B, hkv, S, D), dtype))
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    B, H, S, D = 1, 2, 128, 32
    q, k, v = (_rand((B, H, S, D), jnp.float32), _rand((B, H, S, D), jnp.float32),
               _rand((B, H, S, D), jnp.float32))
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_query_at_kv_tail():
    """S < T: queries sit at the end of the KV axis (chunked prefill/decode)."""
    B, H, S, T, D = 1, 2, 32, 128, 32
    q = _rand((B, H, S, D), jnp.float32)
    k, v = _rand((B, H, T, D), jnp.float32), _rand((B, H, T, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_xla_flash_attention_matches_ref():
    B, H, S, D = 2, 2, 96, 16
    q, k, v = (_rand((B, H, S, D), jnp.float32), _rand((B, H, S, D), jnp.float32),
               _rand((B, H, S, D), jnp.float32))
    for kw in [dict(causal=True), dict(causal=False), dict(causal=True, window=32)]:
        got = ops.xla_flash_attention(q, k, v, block_q=32, **kw)
        want = ref.flash_attention(q, k, v, **kw)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# conv2d (paper roles 3/4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kh,kw,cin,f,dtype",
    [
        (5, 5, 1, 1, jnp.int16),   # paper role 3
        (3, 3, 1, 2, jnp.int16),   # paper role 4
        (3, 3, 4, 8, jnp.float32),
    ],
)
def test_conv2d_sweep(kh, kw, cin, f, dtype):
    if dtype == jnp.int16:
        x = jnp.asarray(RNG.integers(-100, 100, size=(2, 20, 20, cin)), dtype)
        w = jnp.asarray(RNG.integers(-8, 8, size=(kh, kw, cin, f)), dtype)
    else:
        x, w = _rand((2, 20, 20, cin), dtype), _rand((kh, kw, cin, f), dtype)
    got = conv2d(x, w, interpret=True)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_conv2d_fixed_weight_role():
    x = jnp.asarray(RNG.integers(-50, 50, size=(1, 12, 12, 1)), jnp.int16)
    w = jnp.asarray(RNG.integers(-4, 4, size=(3, 3, 1, 2)), jnp.int16)
    fixed = conv2d_fixed_weight(w)
    np.testing.assert_array_equal(fixed(x, interpret=True), ref.conv2d(x, w))


# ---------------------------------------------------------------------------
# ssd (Mamba-2)
# ---------------------------------------------------------------------------


def _ssd_inputs(B=2, S=64, H=4, P=16, G=2, N=32, dtype=jnp.float32):
    x = _rand((B, S, H, P), dtype)
    a_log = jnp.asarray(-np.abs(RNG.normal(size=(H,))), jnp.float32)
    b = _rand((B, S, G, N), dtype)
    c = _rand((B, S, G, N), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1, jnp.float32)
    return x, a_log, b, c, dt


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_pallas_chunk_sweep(chunk):
    x, a_log, b, c, dt = _ssd_inputs()
    want, wstate = ref.ssd(x, a_log, b, c, dt, return_state=True)
    got, gstate = ssd(x, a_log, b, c, dt, chunk=chunk, return_state=True, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gstate, wstate, rtol=1e-4, atol=1e-4)


def test_ssd_xla_matches_ref_and_step():
    x, a_log, b, c, dt = _ssd_inputs()
    want, wstate = ref.ssd(x, a_log, b, c, dt, return_state=True)
    got, gstate = ops.xla_ssd(x, a_log, b, c, dt, chunk=16, return_state=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gstate, wstate, rtol=1e-4, atol=1e-4)

    # sequential single-token decode agrees with the parallel scan
    B, S, H, P = x.shape
    N = b.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(12):
        h, y = ops.ssd_step(h, x[:, t], a_log, b[:, t], c[:, t], dt[:, t])
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), want[:, :12], rtol=1e-4, atol=1e-4)


def test_ssd_bf16_inputs():
    x, a_log, b, c, dt = _ssd_inputs(dtype=jnp.bfloat16)
    want = ref.ssd(x, a_log, b, c, dt)
    got = ssd(x, a_log, b, c, dt, chunk=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def test_decode_attention_matches_full_attention():
    """Decoding one token == last row of full causal attention."""
    B, Hq, Hkv, T, D = 2, 4, 2, 64, 16
    q_full = _rand((B, Hq, T, D), jnp.float32)
    k = _rand((B, Hkv, T, D), jnp.float32)
    v = _rand((B, Hkv, T, D), jnp.float32)
    full = ref.flash_attention(q_full, k, v, causal=True)
    got = ref.decode_attention(q_full[:, :, -1], k, v, length=T)
    np.testing.assert_allclose(got, full[:, :, -1], rtol=2e-4, atol=2e-4)


def test_decode_attention_respects_length_mask():
    B, H, T, D = 1, 2, 32, 8
    q = _rand((B, H, D), jnp.float32)
    k = _rand((B, H, T, D), jnp.float32)
    v = _rand((B, H, T, D), jnp.float32)
    short = ref.decode_attention(q, k[:, :, :10], v[:, :, :10], length=10)
    padded = ref.decode_attention(q, k, v, length=10)
    np.testing.assert_allclose(short, padded, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Pallas decode attention (serving hot-spot kernel)
# ---------------------------------------------------------------------------

from repro.kernels.decode_attention import decode_attention as pallas_decode


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv,T,bk", [(8, 2, 64, 16), (4, 4, 128, 32),
                                         (8, 1, 64, 64)])
def test_pallas_decode_attention_sweep(dtype, hq, hkv, T, bk):
    B, D = 2, 32
    q = _rand((B, hq, D), dtype)
    k = _rand((B, hkv, T, D), dtype)
    v = _rand((B, hkv, T, D), dtype)
    got = pallas_decode(q, k, v, T, block_k=bk, interpret=True)
    want = ref.decode_attention(q, k, v, T)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_pallas_decode_attention_per_sequence_lengths():
    """Continuous batching: each slot masks its own cache length."""
    B, Hq, Hkv, T, D = 3, 4, 2, 64, 16
    q = _rand((B, Hq, D), jnp.float32)
    k = _rand((B, Hkv, T, D), jnp.float32)
    v = _rand((B, Hkv, T, D), jnp.float32)
    lengths = jnp.asarray([5, 64, 33])
    got = pallas_decode(q, k, v, lengths, block_k=16, interpret=True)
    want = ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pallas_decode_attention_matches_grouped_xla():
    B, Hq, Hkv, T, D = 2, 8, 2, 96, 32
    q = _rand((B, Hq, D), jnp.float32)
    k = _rand((B, Hkv, T, D), jnp.float32)
    v = _rand((B, Hkv, T, D), jnp.float32)
    a = pallas_decode(q, k, v, 70, block_k=32, interpret=True)
    b = ops.xla_decode_attention(q, k, v, 70)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# paged decode attention (block-table KV gather)
# ---------------------------------------------------------------------------
from repro.kernels.decode_attention import (                      # noqa: E402
    paged_decode_attention as pallas_paged_decode,
)


def _paged_case(B, hkv, ps, n_pages, pool_pages, D, dtype, seed=0):
    """Random pool + disjoint per-sequence tables (page 0 left as scratch)."""
    rng = np.random.default_rng(seed)
    k_pages = _rand((pool_pages, hkv, ps, D), dtype)
    v_pages = _rand((pool_pages, hkv, ps, D), dtype)
    perm = rng.permutation(np.arange(1, pool_pages))[: B * n_pages]
    table = jnp.asarray(perm.reshape(B, n_pages), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, n_pages * ps + 1, size=B), jnp.int32)
    return k_pages, v_pages, table, lengths


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv,ps,n_pages", [(8, 2, 16, 4), (4, 4, 32, 2),
                                               (8, 1, 8, 8)])
def test_pallas_paged_decode_attention_sweep(dtype, hq, hkv, ps, n_pages):
    B, D = 2, 32
    q = _rand((B, hq, D), dtype)
    k_pages, v_pages, table, lengths = _paged_case(
        B, hkv, ps, n_pages, B * n_pages + 3, D, dtype)
    got = pallas_paged_decode(q, k_pages, v_pages, table, lengths,
                              interpret=True)
    want = ref.paged_decode_attention(q, k_pages, v_pages, table, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_paged_gather_is_bitwise_dense():
    """The gather-based XLA source must be bitwise-equal to dense decode
    attention over the gathered cache — the paged serving engine's
    equivalence guarantee bottoms out in this property."""
    B, Hq, Hkv, ps, n_pages, D = 3, 4, 2, 16, 4, 16
    q = _rand((B, Hq, D), jnp.float32)
    k_pages, v_pages, table, lengths = _paged_case(
        B, Hkv, ps, n_pages, B * n_pages + 1, D, jnp.float32)
    paged = ops.xla_paged_decode_attention(q, k_pages, v_pages, table, lengths)
    dense = ops.xla_decode_attention(
        q, ref.gather_kv_pages(k_pages, table),
        ref.gather_kv_pages(v_pages, table), lengths)
    assert np.array_equal(np.asarray(paged), np.asarray(dense))


def test_paged_scrambled_table_matches_contiguous():
    """Page placement is transparent: scrambling WHERE pages live in the
    pool (fixing what they contain) cannot change the result."""
    B, Hkv, ps, n_pages, D = 2, 2, 8, 4, 16
    pool_pages = B * n_pages + 1
    q = _rand((B, 8, D), jnp.float32)
    k_pages, v_pages, table, lengths = _paged_case(
        B, Hkv, ps, n_pages, pool_pages, D, jnp.float32)
    base = ref.paged_decode_attention(q, k_pages, v_pages, table, lengths)

    # relocate every page under a permutation of the pool
    perm = np.random.default_rng(5).permutation(np.arange(1, pool_pages))
    relocate = np.zeros(pool_pages, np.int64)
    relocate[1:] = perm
    k2 = jnp.asarray(np.asarray(k_pages)[np.argsort(relocate)])
    v2 = jnp.asarray(np.asarray(v_pages)[np.argsort(relocate)])
    table2 = jnp.asarray(relocate[np.asarray(table)], jnp.int32)
    moved = ref.paged_decode_attention(q, k2, v2, table2, lengths)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(moved))
