"""Graceful preemption: randomized churn/recovery against a dense oracle.

The headline claim of overcommitted paged serving: preemption is *invisible*
in the token streams.  A request may be parked (pages reclaimed) and resumed
(snapshot restore or re-prefill + replay) any number of times, at any point
in its life, and every completed request must still be bitwise-identical to
an unconstrained dense run — greedy and seeded temperature, at any
``decode_fusion`` depth.  A seeded generator drives admit/decode/preempt/
resume schedules; allocator invariants (no leak, no alias, free-list
conserved) are checked after every step.
"""

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core.ledger import OverheadLedger
from repro.core.policy import (
    RESUME_REPREFILL,
    RESUME_SNAPSHOT,
    AdmissionPolicy,
    PreemptionCandidate,
    PreemptionPolicy,
)
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, ServeTruncated


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


def _requests(rng, n):
    """(prompt, max_new) pairs; lengths sized for max_len=32, page_size=8."""
    out = []
    for _ in range(n):
        p = [int(t) for t in rng.integers(1, 100, size=int(rng.integers(1, 8)))]
        out.append((p, int(rng.integers(2, 12))))
    return out


def _dense_reference(model, params, reqs, *, temperature=0.0, seed=0):
    """Unconstrained run: every request in its own slot, never preempted."""
    eng = ServeEngine(model, params, batch_slots=len(reqs), max_len=32,
                      temperature=temperature, seed=seed)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=100_000),
                  key=lambda r: r.uid)
    return [r.generated for r in done]


def _check_invariants(eng):
    """No leak, no alias, free-list conserved, pages owned only by actives."""
    eng.allocator.check_invariants()
    if eng.arena is not None:
        eng.arena.check_invariants()
    assert (eng.allocator.free_pages + eng.allocator.allocated_pages
            == eng.allocator.total_pages)
    mapped = 0
    for slot in range(eng.slots):
        if slot in eng._active:
            mapped += int(eng._mapped[slot])
        else:
            assert int(eng._mapped[slot]) == 0, f"idle slot {slot} holds pages"
    assert eng.allocator.allocated_pages == mapped
    for req in eng.parked_requests:
        assert req.parked and not req.done


def _churn(model, params, *, steps, n_requests, seed, temperature=0.0,
           fusion=1, snapshot_threshold=8, preempt_p=0.25, resume_p=0.2,
           submit_p=0.6, pool_pages=8):
    """Seeded admit/decode/preempt/resume schedule; returns (streams, eng)."""
    rng = np.random.default_rng(seed)
    reqs = _requests(rng, n_requests)
    eng = ServeEngine(
        model, params, batch_slots=4, max_len=32, paged=True, page_size=8,
        pool_pages=pool_pages, decode_fusion=fusion, temperature=temperature,
        seed=0, admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(snapshot_threshold_tokens=snapshot_threshold),
    )
    done, i = [], 0
    for _ in range(steps):
        if i < len(reqs) and rng.random() < submit_p:
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        if eng._active and rng.random() < preempt_p:
            uid = int(rng.choice([r.uid for r in eng._active.values()]))
            eng.preempt(uid)
        if eng.parked_requests and rng.random() < resume_p:
            uid = int(rng.choice([r.uid for r in eng.parked_requests]))
            eng.resume(uid)               # may be unfundable: stays parked
        done += eng.step()
        _check_invariants(eng)
        if i >= len(reqs) and not (eng._active or eng._queue
                                   or eng.parked_requests):
            break
    while i < len(reqs):
        p, m = reqs[i]
        eng.submit(p, max_new_tokens=m)
        i += 1
    done += eng.run_to_completion(max_steps=100_000)
    _check_invariants(eng)
    assert eng.allocator.free_pages == eng.allocator.total_pages
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert len(streams) == len(reqs)      # zero drops
    return streams, reqs, eng


# ---------------------------------------------------------------------------
# randomized churn/recovery (tier-1 bounded, slow soak)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fusion,temperature,threshold", [
    (1, 0.0, 8),          # greedy, mixed snapshot/re-prefill resumes
    (4, 0.0, 0),          # fused, snapshot-always
    (2, 0.7, 1000),       # seeded temperature, re-prefill-always
])
def test_churn_recovery_bitwise_identical(engine_model, fusion, temperature,
                                          threshold):
    _, model, params = engine_model
    streams, reqs, eng = _churn(
        model, params, steps=40, n_requests=8, seed=5, fusion=fusion,
        temperature=temperature, snapshot_threshold=threshold,
    )
    assert eng.preemptions > 0            # the schedule actually churned
    assert eng.resumes == eng.preemptions
    dense = _dense_reference(model, params, reqs, temperature=temperature)
    assert streams == dense
    assert all(len(s) == m for s, (_, m) in zip(streams, reqs))


def test_churn_growth_preemption_without_explicit_preempts(engine_model):
    """With no external preempt calls, overcommit pressure alone must drive
    park/resume (pool too small for the admitted requests' real growth)."""
    _, model, params = engine_model
    streams, reqs, eng = _churn(
        model, params, steps=60, n_requests=8, seed=9, preempt_p=0.0,
        resume_p=0.0, pool_pages=4, submit_p=0.9,
    )
    assert eng.preemptions > 0, "pool was never exhausted: test is vacuous"
    dense = _dense_reference(model, params, reqs)
    assert streams == dense


@pytest.mark.slow
def test_churn_soak_10k_steps(engine_model):
    """10k-step-bounded churn soak: sustained preempt/resume cycling over
    hundreds of requests, invariants checked every step, every stream
    bitwise-checked (ends early once every request drains — the bound is
    the harness's safety rail, not a busy-wait target)."""
    _, model, params = engine_model
    streams, reqs, eng = _churn(
        model, params, steps=10_000, n_requests=250, seed=13, fusion=2,
        preempt_p=0.15, resume_p=0.15, submit_p=0.3,
    )
    assert eng.preemptions > 50
    dense = _dense_reference(model, params, reqs)
    assert streams == dense


# ---------------------------------------------------------------------------
# PreemptionPolicy unit behavior
# ---------------------------------------------------------------------------


def _cands():
    return [
        PreemptionCandidate(uid=1, mapped_pages=4, tokens_done=30),
        PreemptionCandidate(uid=2, mapped_pages=1, tokens_done=5),
        PreemptionCandidate(uid=3, mapped_pages=2, tokens_done=12),
    ]


def test_victims_youngest_first():
    assert PreemptionPolicy().victims(_cands(), 3) == [3, 2]
    assert PreemptionPolicy().victims(_cands(), 1) == [3]


def test_victims_other_orders():
    assert PreemptionPolicy(order="oldest").victims(_cands(), 3) == [1]
    assert PreemptionPolicy(order="most_pages").victims(_cands(), 5) == [1, 3]


def test_victims_insufficient_returns_all():
    assert PreemptionPolicy().victims(_cands(), 100) == [3, 2, 1]
    assert PreemptionPolicy().victims(_cands(), 0) == []
    assert PreemptionPolicy().victims([], 4) == []


def test_resume_mode_cost_crossover():
    pol = PreemptionPolicy(snapshot_threshold_tokens=24)
    assert pol.resume_mode(tokens_done=23) == RESUME_REPREFILL
    assert pol.resume_mode(tokens_done=24) == RESUME_SNAPSHOT
    no_snap = PreemptionPolicy(allow_snapshot=False)
    assert no_snap.resume_mode(tokens_done=1000) == RESUME_REPREFILL


def test_policy_validation():
    with pytest.raises(ValueError, match="order"):
        PreemptionPolicy(order="eldest")
    with pytest.raises(ValueError, match="snapshot_threshold"):
        PreemptionPolicy(snapshot_threshold_tokens=-1)


def test_admission_worst_case_pages():
    pol = AdmissionPolicy(growth_reserve=0.5)
    assert pol.projected_pages(4, 16, 8) == 2      # funds 4 + 8 rows
    assert pol.worst_case_pages(4, 16, 8) == 3     # writes up to 19 rows
    assert pol.overcommitted
    assert not AdmissionPolicy().overcommitted
    # exact at the boundary: the final sampled token's row is never written,
    # so prompt 9 + 8 new = 16 written rows = exactly 2 pages, not 3
    assert pol.worst_case_pages(9, 8, 8) == 2


def test_boundary_request_completes_not_rejected(engine_model):
    """A request whose written rows exactly fill the pool must be admitted
    and complete — rounding the unwritten final-token row up to an extra
    page would falsely *permanently* reject it."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=1, max_len=32, paged=True,
                      page_size=8, pool_pages=3)   # 2 usable pages
    eng.submit(list(range(1, 10)), max_new_tokens=8)   # 16 written rows
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].generated) == 8
    assert eng.allocator.free_pages == eng.allocator.total_pages


# ---------------------------------------------------------------------------
# ledger overcommit accounting
# ---------------------------------------------------------------------------


def test_ledger_overcommit_split():
    led = OverheadLedger()
    led.record_preemption(pages_reclaimed=3, snapshot_bytes=1024)
    led.record_preemption(pages_reclaimed=2)
    led.record_resume(mode="snapshot")
    led.record_resume(mode="reprefill", recompute_tokens=17)
    out = led.overcommit_split()
    assert out["preemptions"] == 2 and out["resumes"] == 2
    assert out["pages_reclaimed"] == 5 and out["snapshot_bytes"] == 1024
    assert out["snapshot_resumes"] == 1 and out["reprefill_resumes"] == 1
    assert out["recompute_tokens"] == 17
    led.reset()
    assert led.overcommit_split()["preemptions"] == 0


def test_engine_counters_mirror_ledger(engine_model):
    _, model, params = engine_model
    led = OverheadLedger()
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8, ledger=led,
                      preemption=PreemptionPolicy(snapshot_threshold_tokens=0))
    eng.submit([1, 2, 3], max_new_tokens=6)
    eng.step()
    eng.preempt()
    eng.run_to_completion()
    out = led.overcommit_split()
    assert out["preemptions"] == eng.preemptions == 1
    assert out["resumes"] == eng.resumes == 1
    assert out["snapshot_resumes"] == 1
    assert out["pages_reclaimed"] == eng.pages_reclaimed > 0
    assert out["park_s"] > 0 and out["resume_s"] > 0


# ---------------------------------------------------------------------------
# ServeTruncated: parked vs rejected vs pending
# ---------------------------------------------------------------------------


def test_truncation_reports_parked_separately(engine_model):
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8)
    eng.submit([1, 2, 3], max_new_tokens=8)
    eng.submit([4, 5], max_new_tokens=8)
    eng.step()
    parked_uid = eng.preempt()
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion(max_steps=0)
    err = ei.value
    assert [r.uid for r in err.parked] == [parked_uid]
    assert parked_uid not in [r.uid for r in err.pending]
    assert err.rejected == []
    # transient by construction: more steps finish everything, nothing leaks
    done = eng.run_to_completion()
    assert len(done) == 2 and all(len(r.generated) == 8 for r in done)
    assert eng.allocator.free_pages == eng.allocator.total_pages


def test_truncation_reports_permanently_rejected(engine_model):
    """A request admissible at submit but impossible under a later, tighter
    policy is *rejected* (permanent), not pending (transient)."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8, pool_pages=6)
    eng.submit([1] * 8, max_new_tokens=16)          # worst case: 3 pages
    eng.admission = AdmissionPolicy(watermark_pages=4)   # cap drops to 1
    # default max_steps: a permanently stuck head must fail FAST (the
    # engine detects a no-op state), not spin out 10k empty steps
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion()
    err = ei.value
    assert len(err.rejected) == 1 and err.pending == [] and err.parked == []


def test_truncation_rejects_unresumable_parked_victim(engine_model):
    """A *parked* victim the tightened policy can never re-admit is rejected
    (permanent), not parked (transient) — callers must not retry forever."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=32, paged=True,
                      page_size=8, pool_pages=6)
    eng.submit([1, 2, 3], max_new_tokens=16)        # worst case: 3 pages
    eng.step()
    eng.preempt()
    eng.admission = AdmissionPolicy(watermark_pages=4)   # cap drops to 1
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion()                     # fail-fast, not 10k spins
    err = ei.value
    assert len(err.rejected) == 1 and err.parked == [] and err.pending == []
    assert err.rejected[0].parked                   # still holds its progress


def test_preempt_requires_paged(engine_model):
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=1, max_len=32)
    with pytest.raises(RuntimeError, match="paged"):
        eng.preempt()
