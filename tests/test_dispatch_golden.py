"""Golden-path regression net for the transparency one-flag switch.

The paper's headline property is that retargeting a model is a *flag*, not a
code change.  These tests pin the exact flag -> preference-order mapping and
the op sequence a traced model produces, so runtime refactors (like the
async scheduler) cannot silently change what the flag dispatches to.
"""

import jax.numpy as jnp
import pytest

import repro.kernels  # noqa: F401
from repro.core import dispatch

# the contract: flag -> source preference order, verbatim
POLICY_GOLDEN = {
    "reference": ("reference",),
    "xla": ("xla", "reference"),
    "pallas": ("pallas", "xla", "reference"),
    "pallas-strict": ("pallas",),
}


def test_policy_from_flag_orders_are_stable():
    for flag, expected in POLICY_GOLDEN.items():
        assert dispatch.policy_from_flag(flag) == expected


def test_policy_from_flag_rejects_unknown():
    with pytest.raises(ValueError) as ei:
        dispatch.policy_from_flag("tensorflow")
    # error enumerates the valid flags
    for flag in POLICY_GOLDEN:
        assert flag in str(ei.value)


def test_policy_flag_set_is_closed():
    """Adding/removing a policy flag must update this golden set."""
    for flag in POLICY_GOLDEN:
        dispatch.policy_from_flag(flag)
    assert set(POLICY_GOLDEN) == {"reference", "xla", "pallas", "pallas-strict"}


def _traced_mlp_counts(prefer):
    """One transformer-ish block traced under a policy; returns op_counts."""
    trace = dispatch.DispatchTrace()
    x = jnp.ones((4, 32))
    w1 = jnp.ones((32, 64))
    w2 = jnp.ones((64, 32))
    g = jnp.ones((32,))
    with dispatch.use(prefer=prefer, trace=trace, interpret=True):
        h = dispatch.op("matmul", x, w1)
        h = dispatch.op("matmul", h, w2)
        h = dispatch.op("rmsnorm", h, g)
        h = dispatch.op("matmul", h, w2.T)
    return trace.op_counts()


GOLDEN_COUNTS = {"matmul": 3, "rmsnorm": 1}


def test_dispatch_trace_op_counts_stable_across_policies():
    """Same model, any policy: identical op multiset (transparency)."""
    for flag in POLICY_GOLDEN:
        counts = _traced_mlp_counts(dispatch.policy_from_flag(flag))
        assert counts == GOLDEN_COUNTS, flag


def test_dispatch_trace_records_impl_source_switch():
    """The trace records *which* impl served each op — and it follows the flag."""
    trace_ref = dispatch.DispatchTrace()
    trace_xla = dispatch.DispatchTrace()
    x = jnp.ones((8, 8))
    with dispatch.use(prefer=("reference",), trace=trace_ref):
        dispatch.op("matmul", x, x)
    with dispatch.use(prefer=("xla", "reference"), trace=trace_xla):
        dispatch.op("matmul", x, x)
    (op_r, impl_r), = trace_ref.events
    (op_x, impl_x), = trace_xla.events
    assert op_r == op_x == "matmul"
    assert impl_r != impl_x                       # different backends resolved


def test_op_counts_empty_trace():
    assert dispatch.DispatchTrace().op_counts() == {}
