"""End-to-end data integrity: silent corruption detection and recovery.

The integrity claim, tested across all four state tiers:

  - **device pages** — content digests stamped at every write boundary
    (prefill scatter, chunk scatter, decode page-crossing commit, snapshot
    restore); a flipped page is caught by the pre-commit read verification
    or the budgeted scrubber, quarantined, and its owner re-prefilled.
  - **host arena blocks** — parked snapshots carry their pre-transfer
    digest; a rotted block is caught by the scrubber or the refill-wait
    payload check and demoted to replay.
  - **DMA payloads** — every D2H spill and H2D refill is digest-verified
    (spills at issue, refills at wait); a corrupted transfer never
    delivers its bytes.
  - **reconfig regions** — a region load's image digest is verified before
    any packet executes against it; a stale image retires through the
    existing abort/retry lane.

Every injected corruption must be detected before its bytes influence a
sampled token (``integrity_split()["escaped"] == 0``), and completed
streams must stay bitwise-identical to corruption-free runs.  With
verification off, the same injections *must* escape — proving the
accounting is honest, not tautological.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (populates GLOBAL_REGISTRY)
from repro.configs import ARCHS, reduced
from repro.core.hsa import FaultPlan, Queue, Scheduler, VirtualClock
from repro.core.hsa.faults import (
    CORRUPTION_KINDS,
    CorruptPayload,
    SilentCorruption,
    StaleRegionImage,
)
from repro.core.ledger import OverheadLedger
from repro.core.policy import (
    AdmissionPolicy,
    IntegrityPolicy,
    PreemptionPolicy,
    RetryPolicy,
)
from repro.core.reconfig import (
    RegionManager,
    TransferEngine,
    region_image_digest,
)
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.paged import (
    HostArena,
    PageAllocator,
    flip_page,
    flip_tree,
    page_digest,
    tree_digest,
)


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


def _requests(rng, n):
    out = []
    for _ in range(n):
        p = [int(t) for t in rng.integers(1, 100, size=int(rng.integers(1, 8)))]
        out.append((p, int(rng.integers(2, 12))))
    return out


def _dense_reference(model, params, reqs, *, temperature=0.0, seed=0):
    eng = ServeEngine(model, params, batch_slots=len(reqs), max_len=32,
                      temperature=temperature, seed=seed)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=100_000),
                  key=lambda r: r.uid)
    return [r.generated for r in done]


def _integrity_engine(model, params, *, faults=None, integrity=None,
                      temperature=0.0, fusion=1, chunk=None, spill=False,
                      pool_pages=48, recoveries=64):
    kw = {}
    if chunk is not None:
        kw["prefill_chunk"] = chunk
    return ServeEngine(
        model, params, batch_slots=4, max_len=32, paged=True, page_size=4,
        pool_pages=pool_pages, decode_fusion=fusion, temperature=temperature,
        seed=0, ledger=OverheadLedger(),
        retry=RetryPolicy(max_request_recoveries=recoveries),
        clock=VirtualClock(), step_time_model=lambda p, d: 1e-3,
        transfer_bandwidth_bytes_s=64e6,
        admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(
            snapshot_threshold_tokens=2 if spill else 10**9
        ),
        host_budget_bytes=(1 << 20) if spill else None,
        faults=faults, integrity=integrity, **kw,
    )


def _churn(model, params, *, steps, n_requests, seed, preempt_p=0.2,
           resume_p=0.2, submit_p=0.6, **ekw):
    """Seeded admit/decode/preempt/spill schedule under corruption; the
    allocator and arena invariants are asserted after every step."""
    rng = np.random.default_rng(seed)
    reqs = _requests(rng, n_requests)
    eng = _integrity_engine(model, params, **ekw)
    done, i = [], 0
    for _ in range(steps):
        if i < len(reqs) and rng.random() < submit_p:
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        if eng._active and rng.random() < preempt_p:
            uid = int(rng.choice([r.uid for r in eng._active.values()]))
            eng.preempt(uid)
        if eng.parked_requests and rng.random() < resume_p:
            uid = int(rng.choice([r.uid for r in eng.parked_requests]))
            eng.resume(uid)
        done += eng.step()
        eng.allocator.check_invariants()
        eng.arena.check_invariants()
    while i < len(reqs):
        p, m = reqs[i]
        eng.submit(p, max_new_tokens=m)
        i += 1
    done += eng.run_to_completion(max_steps=100_000)
    eng.allocator.check_invariants()
    eng.arena.check_invariants()
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert len(streams) == len(reqs)
    return streams, reqs, eng


# ---------------------------------------------------------------------------
# IntegrityPolicy
# ---------------------------------------------------------------------------


def test_integrity_policy_validation_and_of():
    assert IntegrityPolicy.of(None) is None
    assert IntegrityPolicy.of(False) is None
    pol = IntegrityPolicy.of(True)
    assert pol == IntegrityPolicy()
    assert IntegrityPolicy.of(pol) is pol
    with pytest.raises(ValueError, match="scrub_pages_per_step"):
        IntegrityPolicy(scrub_pages_per_step=-1)
    with pytest.raises(TypeError):
        IntegrityPolicy.of(3)


def test_integrity_requires_paged(engine_model):
    cfg, model, params = engine_model
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(model, params, batch_slots=2, max_len=32,
                    integrity=IntegrityPolicy())


# ---------------------------------------------------------------------------
# digest primitives (paged.py)
# ---------------------------------------------------------------------------


def test_page_digest_localized_to_page():
    segs = [{"k": jnp.arange(2 * 4 * 3 * 8, dtype=jnp.float32)
             .reshape(2, 4, 3, 8)}]
    d2 = page_digest(segs, 2)
    assert d2 == page_digest(segs, 2)            # deterministic
    assert d2 != page_digest(segs, 1)            # page-local content
    flipped = flip_page(segs, 1)
    assert page_digest(flipped, 1) != page_digest(segs, 1)
    assert page_digest(flipped, 2) == d2         # other pages untouched
    assert tree_digest(flipped) != tree_digest(segs)


def test_flip_tree_copies_and_diverges():
    tree = {"a": jnp.ones((2, 3)), "b": jnp.zeros(4)}
    flipped = flip_tree(tree)
    assert tree_digest(flipped) != tree_digest(tree)
    assert float(jnp.sum(tree["a"])) == 6.0      # source untouched


def test_arena_digest_stamp_verify_corrupt():
    a = HostArena(budget_bytes=1 << 16)
    a.configure(1 << 12)
    data = {"k": np.arange(16, dtype=np.float32)}
    d = tree_digest(data)
    a.store(7, data, 64, digest=d)
    assert a.digest_of(7) == d
    assert a.verify(7)
    a.corrupt(7)
    assert not a.verify(7)                       # digest kept, bytes rotted
    assert a.digest_of(7) == d
    a.check_invariants()
    a.discard(7)
    assert a.digest_of(7) is None
    a.store(8, data, 64)                         # unstamped: verify passes
    assert a.verify(8)
    with pytest.raises((KeyError, ValueError)):
        a.corrupt(99)                            # nothing stored under 99


def test_allocator_quarantine_semantics():
    alloc = PageAllocator(8)
    pages = alloc.allocate(1, 3)
    with pytest.raises(ValueError):
        alloc.quarantine(pages[0])               # owned: park owner first
    with pytest.raises(ValueError):
        alloc.quarantine(0)                      # the scratch page
    alloc.free(1, pages)
    total = alloc.total_pages
    alloc.quarantine(pages[0])
    assert alloc.total_pages == total - 1        # pool shrank
    assert alloc.quarantined_pages == 1
    assert alloc.stats().quarantined == 1
    with pytest.raises(ValueError):
        alloc.quarantine(pages[0])               # already quarantined
    alloc.check_invariants()                     # tiling holds post-retire
    got = alloc.allocate(2, alloc.free_pages)
    assert pages[0] not in got                   # never re-issued
    alloc.free(2, got)
    alloc.check_invariants()


# ---------------------------------------------------------------------------
# engine: one forced corruption per tier, detected, streams bitwise-identical
# ---------------------------------------------------------------------------


def _run_engine(eng, reqs):
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=50_000),
                  key=lambda r: r.uid)
    return [r.generated for r in done]


REQS = [([1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12], 8), ([5, 6, 7], 6),
        ([9] * 10, 10)]


def test_flip_page_detected_by_read_verification(engine_model):
    cfg, model, params = engine_model
    ref = _dense_reference(model, params, REQS)
    plan = FaultPlan(seed=3)
    plan.force("flip_page")
    eng = _integrity_engine(model, params, faults=plan,
                            integrity=IntegrityPolicy(scrub_pages_per_step=0))
    out = _run_engine(eng, REQS)
    assert out == ref
    sp = eng.ledger.integrity_split()
    assert sp["corrupt_pages"] == 1
    assert sp["detected_read"] == 1 and sp["escaped"] == 0
    assert sp["quarantined_pages"] == 1
    assert eng.corruptions_detected == eng.corruptions_injected == 1


def test_flip_page_detected_by_scrubber(engine_model):
    cfg, model, params = engine_model
    ref = _dense_reference(model, params, REQS)
    plan = FaultPlan(seed=3)
    plan.force("flip_page", count=2)
    # budget >= every sealed page: the scrub pass right after each injection
    # catches the flip in the same step, before any decode read
    eng = _integrity_engine(model, params, faults=plan,
                            integrity=IntegrityPolicy(scrub_pages_per_step=32))
    out = _run_engine(eng, REQS)
    assert out == ref
    sp = eng.ledger.integrity_split()
    assert sp["corrupt_pages"] == 2 and sp["escaped"] == 0
    assert sp["detected"] == 2
    assert sp["detected_scrub"] == 2             # budget catches it cold
    assert sp["scrub_passes"] > 0 and sp["scrubbed_pages"] > 0
    assert 0.0 < sp["scrub_coverage"] <= 1.0
    assert eng.allocator.quarantined_pages       # retired from circulation
    eng.allocator.check_invariants()


def test_flip_block_detected_before_restore(engine_model):
    """A parked snapshot rots in the arena; the refill payload check (or
    the scrubber) catches it and the entry demotes to replay."""
    cfg, model, params = engine_model
    ref = _dense_reference(model, params, REQS)
    plan = FaultPlan(seed=4)
    plan.force("flip_block")
    eng = _integrity_engine(model, params, faults=plan, spill=True,
                            integrity=IntegrityPolicy(scrub_pages_per_step=1))
    for p, m in REQS:
        eng.submit(p, max_new_tokens=m)
    done, step = [], 0
    while True:
        step += 1
        if step in (3, 4, 5, 6, 7, 8) and eng._active:
            eng.preempt(sorted(r.uid for r in eng._active.values())[0])
        done += eng.step()
        with eng._lock:
            if not (eng._active or eng._prefilling or eng._queue
                    or eng._parked):
                break
        assert step < 5000
    out = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert out == ref
    sp = eng.ledger.integrity_split()
    assert sp["corrupt_blocks"] == 1 and sp["escaped"] == 0
    assert sp["detected"] >= 1


def test_corrupt_transfer_detected_at_dma_boundary(engine_model):
    cfg, model, params = engine_model
    ref = _dense_reference(model, params, REQS)
    plan = FaultPlan(seed=5)
    plan.force("corrupt_transfer", count=2)
    eng = _integrity_engine(model, params, faults=plan, spill=True,
                            integrity=IntegrityPolicy(scrub_pages_per_step=0))
    for p, m in REQS:
        eng.submit(p, max_new_tokens=m)
    done, step = [], 0
    while True:
        step += 1
        if step in (3, 4, 5, 6) and eng._active:
            eng.preempt(sorted(r.uid for r in eng._active.values())[0])
        done += eng.step()
        with eng._lock:
            if not (eng._active or eng._prefilling or eng._queue
                    or eng._parked):
                break
        assert step < 5000
    out = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert out == ref
    sp = eng.ledger.integrity_split()
    assert sp["corrupt_transfers"] >= 1
    assert sp["detected_transfer"] >= 1 and sp["escaped"] == 0
    assert sp["verified_transfers"] >= 1


# ---------------------------------------------------------------------------
# engine: verification off -> the same injections escape (honest accounting)
# ---------------------------------------------------------------------------

_VERIFY_OFF = IntegrityPolicy(scrub_pages_per_step=0, verify_reads=False,
                              verify_transfers=False, verify_regions=False)


def test_flip_page_escapes_with_verification_off(engine_model):
    cfg, model, params = engine_model
    ref = _dense_reference(model, params, REQS)
    plan = FaultPlan(seed=3)
    plan.force("flip_page")
    eng = _integrity_engine(model, params, faults=plan,
                            integrity=_VERIFY_OFF)
    out = _run_engine(eng, REQS)
    sp = eng.ledger.integrity_split()
    assert sp["escaped"] >= 1                    # consumed, uncaught
    assert sp["detected"] == 0
    assert out != ref                            # the stream really diverged


def test_flip_block_escapes_with_verification_off(engine_model):
    cfg, model, params = engine_model
    plan = FaultPlan(seed=4)
    plan.force("flip_block")
    eng = _integrity_engine(model, params, faults=plan, spill=True,
                            integrity=_VERIFY_OFF)
    for p, m in REQS:
        eng.submit(p, max_new_tokens=m)
    done, step = [], 0
    while True:
        step += 1
        if step in (3, 4, 5, 6, 7, 8) and eng._active:
            eng.preempt(sorted(r.uid for r in eng._active.values())[0])
        done += eng.step()
        with eng._lock:
            if not (eng._active or eng._prefilling or eng._queue
                    or eng._parked):
                break
        assert step < 5000
    assert eng.ledger.integrity_split()["escaped"] >= 1


# ---------------------------------------------------------------------------
# reconfig regions: stale image caught before any packet executes
# ---------------------------------------------------------------------------

_COST = {"reconfig": 10.0, "exec": 1.0}


def _mk_region_sched(*, faults=None, retry=None, verify_images=True):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(2, ledger=led, verify_images=verify_images)
    sched = Scheduler(rm, lib, ledger=led, clock=VirtualClock(),
                      cost_model=lambda k, w, m: _COST[k],
                      retry=retry, faults=faults)
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    role = lib.add(Role(impl, (a, a), name="mm8"))
    return sched, role, led


def test_stale_region_detected_and_retried():
    plan = FaultPlan()
    plan.force("stale_region")
    sched, role, led = _mk_region_sched(
        faults=plan,
        retry=RetryPolicy(backoff_s=0.5, backoff_factor=2.0,
                          max_backoff_s=8.0),
    )
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(role.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    sched.run_until_idle()
    assert pkt.out.error is None                 # retry absorbed the fault
    np.testing.assert_allclose(np.asarray(pkt.out.value)[0, 0], 8.0)
    briefs = [e.brief() for e in sched.event_log()]
    assert briefs.count(("reconfig_start", "A", "mm8")) == 2
    sp = led.integrity_split()
    assert sp["stale_regions"] == 1 and sp["detected_region"] == 1
    assert sp["escaped"] == 0 and sp["verified_regions"] == 2
    assert led.availability_split()["load_faults"] == 1


def test_stale_region_escapes_with_verification_off():
    plan = FaultPlan()
    plan.force("stale_region")
    sched, role, led = _mk_region_sched(faults=plan, verify_images=False)
    q = sched.add_queue(Queue(None, 64, name="A"))
    q.dispatch(role.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    sched.run_until_idle()
    sp = led.integrity_split()
    assert sp["stale_regions"] == 1 and sp["escaped"] == 1
    assert sp["detected"] == 0
    # escape counted once per stale load, not once per packet
    q.dispatch(role.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    sched.run_until_idle()
    assert led.integrity_split()["escaped"] == 1


def test_region_image_digest_identity():
    _, role, _ = _mk_region_sched()
    d = region_image_digest(role)
    assert d == region_image_digest(role) and len(d) == 16


# ---------------------------------------------------------------------------
# seeded corruption churn across decode_fusion x prefill_chunk x spill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fusion,chunk,spill,temperature", [
    (1, None, False, 0.0),       # greedy, plain prefill, device-only
    (4, None, True, 0.0),        # fused decode, spill tier live
    (1, 4, True, 0.0),           # chunked prefill + spill
    (4, 4, True, 0.7),           # everything on, seeded temperature
])
def test_churn_seeded_corruption_streams_identical(engine_model, fusion,
                                                   chunk, spill, temperature):
    cfg, model, params = engine_model
    plan = FaultPlan(seed=29, corrupt_rate=0.05)
    streams, reqs, eng = _churn(
        model, params, steps=60, n_requests=10, seed=21, faults=plan,
        integrity=IntegrityPolicy(scrub_pages_per_step=2),
        fusion=fusion, chunk=chunk, spill=spill, temperature=temperature,
    )
    ref = _dense_reference(model, params, reqs, temperature=temperature)
    assert streams == ref                        # bitwise, per request
    sp = eng.ledger.integrity_split()
    assert sp["escaped"] == 0
    # anything injected but never detected must be latent (its pages or
    # blocks were freed before any read consumed them) — never escaped
    assert sp["detected"] <= sp["corruptions"]
    if sp["corruptions"]:
        assert sp["detection_rate"] == sp["detected"] / sp["corruptions"]


def test_corruption_draws_do_not_perturb_failstop_stream():
    """The corruption stream is a separate seeded rng: interleaving
    corruption draws between fail-stop draws must not shift which exec or
    transfer attempts fault (the PR 7/8 schedules stay frozen when a test
    turns corruption on)."""
    def failstop_seq(interleave):
        plan = FaultPlan(seed=13, exec_rate=0.3, transfer_rate=0.3,
                         corrupt_rate=0.5)
        out = []
        for i in range(40):
            if interleave:
                plan.draw_corruption("flip_page", ["page[1]", "page[2]"])
                plan.draw_corruption("flip_block", ["block[uid=0]"])
            out.append(type(plan.draw_exec(f"pkt{i}", queue="A")).__name__)
            out.append(type(plan.draw_transfer("h2d", f"kv[{i}]")).__name__)
        return out

    assert failstop_seq(False) == failstop_seq(True)


# ---------------------------------------------------------------------------
# ledger oracles (zero-division guards on empty ledgers)
# ---------------------------------------------------------------------------


def test_integrity_split_empty_ledger_all_zero():
    sp = OverheadLedger().integrity_split()
    assert sp["escaped"] == 0 and sp["corruptions"] == 0
    assert sp["scrub_coverage"] == 0.0           # no scrubs: no division
    assert sp["detection_rate"] == 0.0           # no corruptions: no division
    assert all(v == 0.0 for v in sp.values())
    # A scrub pass over a tier with zero *stamped* targets must not inflate
    # coverage: unstamped entries are not auditable and do not count.
    led = OverheadLedger()
    led.record_scrub(pages=0, blocks=0, targets=0)
    sp = led.integrity_split()
    assert sp["scrub_targets"] == 0.0
    assert sp["scrub_coverage"] == 0.0


def test_availability_split_empty_ledger_all_zero():
    av = OverheadLedger().availability_split()
    assert av["mttr_s"] == 0.0                   # no recoveries: no division
    assert av["fault_rate"] == 0.0               # no attempts: no division
    assert all(v == 0.0 for v in av.values())


# ---------------------------------------------------------------------------
# soak (slow): 10k churn steps under seeded corruption
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_churn_corruption_soak_10k_steps(engine_model):
    cfg, model, params = engine_model
    plan = FaultPlan(seed=97, corrupt_rate=0.02)
    streams, reqs, eng = _churn(
        model, params, steps=10_000, n_requests=120, seed=55, faults=plan,
        integrity=IntegrityPolicy(scrub_pages_per_step=2),
        fusion=4, chunk=4, spill=True, submit_p=0.25,
        pool_pages=96, recoveries=256,
    )
    ref = _dense_reference(model, params, reqs)
    assert streams == ref
    sp = eng.ledger.integrity_split()
    assert sp["escaped"] == 0
    assert sp["corruptions"] > 0                 # the soak actually injected
    assert sp["detected"] >= 1
