"""Per-architecture smoke + consistency tests (reduced configs, CPU).

For each assigned arch: forward/train-step shape + NaN checks, and the
cache-correctness property: prefill + N decode steps == teacher-forced forward
(exact in f32; bf16 is used only in production configs).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as layers_mod
from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.models.params import abstract_params, count_params, init_params

ALL_ARCHS = sorted(ARCHS)
RNG = np.random.default_rng(42)


def _f32(params):
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a, params
    )


def _smoke_cfg(name):
    cfg = reduced(ARCHS[name])
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


def _batch(cfg, B, S):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.frontend_seq, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.frontend_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_loss(name):
    cfg = _smoke_cfg(name)
    m = build_model(cfg)
    params = init_params(m.param_specs(), jax.random.key(0))
    batch = _batch(cfg, 2, 32)
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss)), metrics
    assert float(loss) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_grads_finite(name):
    cfg = _smoke_cfg(name)
    m = build_model(cfg)
    params = _f32(init_params(m.param_specs(), jax.random.key(0)))
    batch = _batch(cfg, 2, 16)
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # at least the embedding gradient must be nonzero
    assert float(jnp.abs(grads["embed"]["tok"]).sum()) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    """The cache-correctness property across every family."""
    cfg = _smoke_cfg(name)
    m = build_model(cfg)
    params = _f32(init_params(m.param_specs(), jax.random.key(3)))
    B, S, EXTRA = 2, 16, 4
    full = _batch(cfg, B, S + EXTRA)
    pre = {k: (v[:, :S] if k == "tokens" else v) for k, v in full.items()}

    old = layers_mod.COMPUTE_DTYPE
    layers_mod.COMPUTE_DTYPE = jnp.float32
    try:
        if hasattr(m, "forward"):
            logits_full, _ = m.forward(params, full)
        else:
            memory = m.encode(params, full["frames"])
            h, _ = m._decode_full(params, full["tokens"], memory, "full")
            h = layers_mod.apply_norm(params["ln_f"], h, cfg.norm_eps)
            logits_full = layers_mod.unembed(params["embed"], h)

        lg, cache = m.prefill(params, pre, cache_len=S + EXTRA)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, S - 1]), rtol=1e-4, atol=1e-4
        )
        for t in range(EXTRA):
            lg, cache = m.decode_step(params, full["tokens"][:, S + t: S + t + 1], cache)
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(logits_full[:, S + t]),
                rtol=1e-4, atol=1e-4,
                err_msg=f"decode step {t}",
            )
    finally:
        layers_mod.COMPUTE_DTYPE = old


def test_ring_buffer_window_attention_long_decode():
    """hymba: decoding past the window uses the ring buffer correctly."""
    cfg = _smoke_cfg("hymba-1.5b")          # window=32 in reduced form
    m = build_model(cfg)
    params = _f32(init_params(m.param_specs(), jax.random.key(5)))
    B, S, EXTRA = 1, 48, 3                  # S > window: ring engaged at prefill
    full = _batch(cfg, B, S + EXTRA)
    pre = {"tokens": full["tokens"][:, :S]}

    old = layers_mod.COMPUTE_DTYPE
    layers_mod.COMPUTE_DTYPE = jnp.float32
    try:
        logits_full, _ = m.forward(params, full)
        lg, cache = m.prefill(params, pre, cache_len=S + EXTRA)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, S - 1]), rtol=1e-4, atol=1e-4
        )
        for t in range(EXTRA):
            lg, cache = m.decode_step(params, full["tokens"][:, S + t: S + t + 1], cache)
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(logits_full[:, S + t]),
                rtol=1e-4, atol=1e-4, err_msg=f"ring decode step {t}",
            )
    finally:
        layers_mod.COMPUTE_DTYPE = old


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_specs_match_prefill_cache(name):
    cfg = _smoke_cfg(name)
    m = build_model(cfg)
    params = init_params(m.param_specs(), jax.random.key(0))
    B, S = 2, 16
    lg, cache = m.prefill(params, _batch(cfg, B, S), cache_len=S)
    specs = m.cache_specs(B, S)
    got = jax.tree.map(lambda a: (a.shape, str(a.dtype)), cache)
    want = jax.tree.map(lambda s: (s.shape, str(s.dtype)), specs)
    assert got == want


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_full_config_abstract_params_match_published_size(name):
    """Full (production) configs: abstract param tree matches total_params()."""
    cfg = ARCHS[name]
    m = build_model(cfg)
    specs = m.param_specs()
    n = count_params(specs)
    expected = cfg.total_params()
    # layer norms / small vectors are excluded from the analytic count
    assert abs(n - expected) / expected < 0.01, (n, expected)
    # and nothing was materialized
    ap = abstract_params(specs)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in jax.tree.leaves(ap))
