"""Tiered KV page pool: budgeted host arena, spill/refill, degradation.

The tiering claim: bounding the host tier changes *cost*, never output.
Parked snapshots spill D2H into a budgeted :class:`HostArena`; refills
stream back H2D ahead of need; when the budget is oversubscribed a
:class:`SpillPolicy` demotes victims from snapshot-resume to re-prefill
replay.  Under any budget — including zero — completed token streams must
stay bitwise-identical to an unconstrained dense run, the arena free-list
must conserve blocks, and ``used_bytes`` must never exceed the budget (both
asserted after *every* step of a randomized churn schedule).
"""

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core.hsa.clock import VirtualClock
from repro.core.ledger import OverheadLedger
from repro.core.policy import SpillCandidate, SpillPolicy
from repro.core.reconfig import Transfer, TransferEngine
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.paged import HostArena, HostArenaExhausted


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


def _requests(rng, n):
    """(prompt, max_new) pairs; lengths sized for max_len=32, page_size=8."""
    out = []
    for _ in range(n):
        p = [int(t) for t in rng.integers(1, 100, size=int(rng.integers(1, 8)))]
        out.append((p, int(rng.integers(2, 12))))
    return out


def _dense_reference(model, params, reqs, *, temperature=0.0, seed=0):
    eng = ServeEngine(model, params, batch_slots=len(reqs), max_len=32,
                      temperature=temperature, seed=seed)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=100_000),
                  key=lambda r: r.uid)
    return [r.generated for r in done]


def _check_invariants(eng):
    eng.allocator.check_invariants()
    eng.arena.check_invariants()
    if eng.host_budget_bytes is not None:
        assert eng.arena.used_bytes <= eng.host_budget_bytes, \
            "host budget exceeded"


def _churn(model, params, *, steps, n_requests, seed, temperature=0.0,
           fusion=1, snapshot_threshold=8, preempt_p=0.25, resume_p=0.2,
           submit_p=0.6, pool_pages=8, host_budget_bytes=None,
           spill=None, faults=None, use_clock=False):
    """Seeded admit/decode/preempt/spill/refill/fault schedule with the
    arena free-list and host budget asserted after every step."""
    from repro.core.policy import AdmissionPolicy, PreemptionPolicy

    rng = np.random.default_rng(seed)
    reqs = _requests(rng, n_requests)
    kw = {}
    if use_clock:
        kw["clock"] = VirtualClock()
        kw["step_time_model"] = lambda prefill, decode: 1e-3
        kw["transfer_bandwidth_bytes_s"] = 64e6
    eng = ServeEngine(
        model, params, batch_slots=4, max_len=32, paged=True, page_size=8,
        pool_pages=pool_pages, decode_fusion=fusion, temperature=temperature,
        seed=0, admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(snapshot_threshold_tokens=snapshot_threshold),
        ledger=OverheadLedger(), host_budget_bytes=host_budget_bytes,
        spill=spill, faults=faults, **kw,
    )
    done, i = [], 0
    for _ in range(steps):
        if i < len(reqs) and rng.random() < submit_p:
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        if eng._active and rng.random() < preempt_p:
            uid = int(rng.choice([r.uid for r in eng._active.values()]))
            eng.preempt(uid)
        if eng.parked_requests and rng.random() < resume_p:
            uid = int(rng.choice([r.uid for r in eng.parked_requests]))
            eng.resume(uid)               # may be unfundable: stays parked
        done += eng.step()
        _check_invariants(eng)
    while i < len(reqs):
        p, m = reqs[i]
        eng.submit(p, max_new_tokens=m)
        i += 1
    done += eng.run_to_completion(max_steps=100_000)
    _check_invariants(eng)
    assert eng.allocator.free_pages == eng.allocator.total_pages
    assert not eng.arena.entries(), "arena holds snapshots after drain"
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert len(streams) == len(reqs)      # zero drops
    return streams, reqs, eng


# ---------------------------------------------------------------------------
# HostArena
# ---------------------------------------------------------------------------


def test_arena_store_load_take_discard():
    a = HostArena(budget_bytes=4096)
    a.configure(1024)
    assert a.total_blocks == 4 and a.free_blocks == 4
    a.store(1, "snap1", 1500)             # 2 blocks
    assert a.holds(1) and a.bytes_of(1) == 1500
    assert a.used_blocks == 2 and a.free_blocks == 2
    assert a.load(1) == "snap1"
    assert a.holds(1)                     # load does not evict
    assert a.take(1) == "snap1"
    assert not a.holds(1) and a.free_blocks == 4
    a.store(2, "snap2", 100)
    assert a.discard(2) == 100
    assert a.used_bytes == 0
    a.check_invariants()


def test_arena_budget_enforced_and_fits():
    a = HostArena(budget_bytes=2048)
    a.configure(1024)
    assert a.fits(2048) and not a.fits(2049)
    assert a.can_ever_fit(2048) and not a.can_ever_fit(2049)
    a.store(1, "x", 1024)
    assert not a.fits(2000)               # only 1 block free
    with pytest.raises(HostArenaExhausted):
        a.store(2, "y", 2000)
    assert a.peak_bytes == 1024
    a.check_invariants()


def test_arena_unbounded_mints_blocks():
    a = HostArena()                       # budget None: pre-tiering behavior
    a.configure(512)
    for uid in range(10):
        a.store(uid, f"s{uid}", 1000)
    assert a.used_blocks == 20 and a.free_blocks == 0
    assert a.fits(10**9) and a.can_ever_fit(10**12)
    a.check_invariants()
    for uid in range(10):
        a.discard(uid)
    a.check_invariants()


def test_arena_store_duplicate_and_configure_conflict():
    a = HostArena(budget_bytes=4096)
    a.configure(1024)
    a.configure(1024)                     # idempotent
    with pytest.raises(ValueError):
        a.configure(2048)                 # conflicting block size
    a.store(1, "x", 10)
    with pytest.raises(ValueError):
        a.store(1, "y", 10)               # uid already resident
    b = HostArena(budget_bytes=4096)
    with pytest.raises(RuntimeError):
        b.blocks_for(10)                  # unconfigured


def test_arena_eviction_order_is_store_order():
    a = HostArena()
    a.configure(64)
    for uid in (3, 1, 2):
        a.store(uid, None, 64)
    assert a.entries() == [3, 1, 2]
    a.take(1)
    assert a.entries() == [3, 2]


# ---------------------------------------------------------------------------
# SpillPolicy
# ---------------------------------------------------------------------------


def _spill_cands():
    return [
        SpillCandidate(uid=1, arena_bytes=4096, tokens_done=30),
        SpillCandidate(uid=2, arena_bytes=1024, tokens_done=5),
        SpillCandidate(uid=3, arena_bytes=2048, tokens_done=12),
    ]


def test_spill_victims_cheapest_replay_first():
    v = SpillPolicy().victims(_spill_cands(), 1000)
    assert v == [2]                       # fewest tokens to replay
    v = SpillPolicy().victims(_spill_cands(), 2000)
    assert v == [2, 3]


def test_spill_victims_other_orders():
    assert SpillPolicy(order="largest").victims(_spill_cands(), 1000) == [1]
    assert SpillPolicy(order="oldest").victims(_spill_cands(), 1000) == [1]
    assert SpillPolicy(order="largest").victims(_spill_cands(), 5000) == [1, 3]


def test_spill_victims_insufficient_returns_all():
    v = SpillPolicy().victims(_spill_cands(), 10**9)
    assert sorted(v) == [1, 2, 3]
    assert SpillPolicy().victims([], 1) == []


def test_spill_policy_validation_and_of():
    with pytest.raises(ValueError):
        SpillPolicy(order="random")
    with pytest.raises(ValueError):
        SpillPolicy(refill_lookahead=-1)
    assert SpillPolicy.of(None) == SpillPolicy()
    p = SpillPolicy(order="largest")
    assert SpillPolicy.of(p) is p


# ---------------------------------------------------------------------------
# TransferEngine (virtual clock: exact timestamps)
# ---------------------------------------------------------------------------


def test_transfer_exposed_vs_hidden():
    clock = VirtualClock()
    led = OverheadLedger()
    xfer = TransferEngine(bandwidth_bytes_s=1000.0, clock=clock, ledger=led)
    t = xfer.issue("h2d", "kv[uid=1]", 500)      # 0.5 s transfer
    assert (t.start_t, t.ready_t) == (0.0, 0.5)
    clock.advance(0.2)                            # 0.3 s still in flight
    exposed = xfer.wait(t)
    assert exposed == pytest.approx(0.3)
    assert clock.now() == pytest.approx(0.5)      # wait advanced to ready
    split = led.spill_split()
    assert split["refill_exposed_s"] == pytest.approx(0.3)
    assert split["refill_hidden_s"] == pytest.approx(0.2)
    assert split["refill_hidden_frac"] == pytest.approx(0.4)
    # fully hidden: decode time covered the whole DMA
    t2 = xfer.issue("h2d", "kv[uid=2]", 500)
    clock.advance(1.0)
    assert xfer.wait(t2) == 0.0
    with pytest.raises(ValueError):
        xfer.wait(t2)                             # double wait


def test_transfer_engine_serializes_dmas():
    clock = VirtualClock()
    xfer = TransferEngine(bandwidth_bytes_s=1000.0, clock=clock)
    a = xfer.issue("d2h", "kv[uid=1]", 1000)      # occupies [0, 1]
    b = xfer.issue("h2d", "kv[uid=2]", 1000)      # queues behind: [1, 2]
    assert (a.start_t, a.ready_t) == (0.0, 1.0)
    assert (b.start_t, b.ready_t) == (1.0, 2.0)
    assert xfer.bytes_moved == 2000


def test_transfer_fault_backoff_and_ledger():
    from repro.core.hsa.faults import FaultPlan, InjectedTransferFault

    clock = VirtualClock()
    led = OverheadLedger()
    plan = FaultPlan()
    plan.force("h2d")
    xfer = TransferEngine(bandwidth_bytes_s=1000.0, clock=clock, ledger=led,
                          faults=plan, fault_backoff_s=0.25)
    t = xfer.issue("h2d", "kv[uid=1]", 100)
    assert isinstance(t.error, InjectedTransferFault)
    assert xfer.faulted == 1
    with pytest.raises(InjectedTransferFault):
        xfer.wait(t)
    assert led.spill_split()["transfer_faults"] == 1
    # the backoff occupies the engine timeline: next DMA starts at 0.25
    t2 = xfer.issue("d2h", "kv[uid=2]", 100)
    assert t2.start_t == pytest.approx(0.25)
    xfer.cancel(t2)
    assert xfer.cancelled == 1


def test_transfer_validation():
    xfer = TransferEngine(clock=VirtualClock())
    with pytest.raises(ValueError):
        xfer.issue("sideways", "x", 10)
    with pytest.raises(ValueError):
        xfer.issue("h2d", "x", -1)
    with pytest.raises(ValueError):
        TransferEngine(bandwidth_bytes_s=0.0)


def test_cancel_inflight_refill_on_demote_race(engine_model):
    """Spill -> demote race: request A's snapshot is in the arena with its
    ahead-of-need H2D refill already in flight when request B's spill
    demands the arena space.  Demoting A must cancel the refill cleanly:
    no REFILL seconds ever reach the ledger (H2D accounts at ``wait``, and
    a cancelled transfer is never waited), the DMA timeline slot stays
    spent (bandwidth was really consumed), and both streams still complete
    bitwise-identical to the dense reference."""
    from repro.core.policy import (
        AdmissionPolicy, IntegrityPolicy, PreemptionPolicy,
    )

    cfg, model, params = engine_model
    reqs = [([3, 1, 4, 1, 5, 9, 2, 6], 8), ([2, 7, 1, 8, 2, 8, 1, 8], 8)]
    ref = _dense_reference(model, params, reqs)

    def _mk(budget):
        eng = ServeEngine(
            model, params, batch_slots=2, max_len=32, paged=True,
            page_size=8, pool_pages=16, seed=0, ledger=OverheadLedger(),
            clock=VirtualClock(), step_time_model=lambda p, d: 1e-3,
            transfer_bandwidth_bytes_s=64e6,
            admission=AdmissionPolicy(growth_reserve=0.5),
            preemption=PreemptionPolicy(snapshot_threshold_tokens=2),
            host_budget_bytes=budget, integrity=IntegrityPolicy(),
        )
        for p, m in reqs:
            eng.submit(p, max_new_tokens=m)
        for _ in range(3):
            eng.step()
        return eng

    # probe: how many arena bytes does one snapshot at this point occupy?
    probe = _mk(1 << 24)
    probe.preempt(1)                              # uids are 1-based
    one_snapshot = probe.arena.used_bytes
    assert one_snapshot > 0

    # budget = exactly one snapshot: B's store can only fit by demoting A
    eng = _mk(one_snapshot)
    eng.preempt(1)
    entry_a = eng._parked[0]
    with eng._lock:
        eng._pump_refills()
    refill = entry_a.refill
    assert refill is not None and refill.error is None    # in flight

    eng.preempt(2)          # B spills; A is the only demotable victim
    assert entry_a.refill is None
    assert eng._xfer.cancelled == 1
    assert eng.demotions == 1
    assert eng.arena.holds(2) and not eng.arena.holds(1)  # A discarded
    eng.arena.check_invariants()
    # cancelled H2D never reached wait(): zero refill time on the ledger
    sp = eng.ledger.spill_split()
    assert sp["refill_s"] == 0.0
    assert sp["refill_exposed_s"] == 0.0 and sp["refill_hidden_s"] == 0.0
    # the timeline slot stays spent: bandwidth spent on the cancelled DMA
    # (and B's D2H queued behind it) is sunk, not reclaimed
    x_probe = eng._xfer.issue("h2d", "probe", 1)
    assert x_probe.start_t >= refill.ready_t
    eng._xfer.cancel(x_probe)
    assert eng._xfer.cancelled == 2

    done = eng.run_to_completion(max_steps=100_000)
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert streams == ref   # A replayed, B restored — bitwise intact
    assert eng.ledger.integrity_split()["escaped"] == 0


# ---------------------------------------------------------------------------
# ledger accounting
# ---------------------------------------------------------------------------


def test_ledger_host_memory_rows_and_budget_guard():
    led = OverheadLedger()
    led.record_host_memory(used_bytes=1000, budget_bytes=4096)
    mem = led.memory_split()
    assert mem["host_used_bytes"] == 1000
    assert mem["host_peak_bytes"] == 1000
    assert mem["host_budget_bytes"] == 4096
    led.record_host_memory(used_bytes=500, budget_bytes=4096)
    assert led.memory_split()["host_peak_bytes"] == 1000
    with pytest.raises(ValueError):
        led.record_host_memory(used_bytes=5000, budget_bytes=4096)


def test_ledger_demotion_undoes_snapshot_double_count():
    led = OverheadLedger()
    led.record_preemption(pages_reclaimed=4, snapshot_bytes=4096)
    assert led.overcommit_split()["snapshot_bytes"] == 4096
    led.record_demotion(bytes_freed=4096, replay_tokens=20)
    out = led.overcommit_split()
    assert out["snapshot_bytes"] == 0     # demoted bytes no longer counted
    spill = led.spill_split()
    assert spill["demotions"] == 1
    assert spill["demoted_bytes"] == 4096
    assert spill["replay_fallback_tokens"] == 20


def test_ledger_spill_split_rates():
    led = OverheadLedger()
    led.record_spill(nbytes=2048)
    led.record_refill(nbytes=2048)
    out = led.spill_split()
    assert out["spills"] == 1 and out["spill_bytes"] == 2048
    assert out["refills"] == 1 and out["refill_bytes"] == 2048
    assert out["refill_hidden_frac"] == 0.0   # no timed waits recorded


# ---------------------------------------------------------------------------
# engine integration: budget squeeze, degradation, bitwise identity
# ---------------------------------------------------------------------------


def test_churn_unbounded_arena_matches_dense(engine_model):
    """Default (no budget): the arena is pure plumbing — same streams, and
    every snapshot park round-trips through it."""
    _, model, params = engine_model
    streams, reqs, eng = _churn(model, params, steps=40, n_requests=8, seed=5)
    assert eng.preemptions > 0
    assert eng.spills > 0 and eng.refills == eng.spills
    assert eng.demotions == 0
    assert streams == _dense_reference(model, params, reqs)


def test_churn_tiny_budget_demotes_but_streams_identical(engine_model):
    """A one-block budget forces SpillPolicy demotions under churn; output
    must not change — only resume cost does."""
    _, model, params = engine_model
    probe, _, eng0 = _churn(model, params, steps=40, n_requests=8, seed=5)
    budget = eng0.arena.block_bytes       # exactly one snapshot block
    streams, reqs, eng = _churn(model, params, steps=40, n_requests=8,
                                seed=5, host_budget_bytes=budget)
    assert eng.spills > 0
    assert eng.demotions > 0, "budget never squeezed: test is vacuous"
    assert eng.arena.peak_bytes <= budget
    assert streams == _dense_reference(model, params, reqs)
    split = eng.ledger.spill_split()
    assert split["demotions"] == eng.demotions
    assert split["replay_fallback_tokens"] == eng.replay_fallback_tokens > 0


def test_churn_zero_budget_all_replay_identical(engine_model):
    """budget=0: no snapshot ever fits, every park degrades to re-prefill
    replay — the graceful-degradation floor, still bitwise-identical."""
    _, model, params = engine_model
    streams, reqs, eng = _churn(model, params, steps=40, n_requests=8,
                                seed=5, host_budget_bytes=0)
    assert eng.preemptions > 0
    assert eng.spills == 0 and eng.refills == 0
    assert eng.demotions > 0
    assert eng.arena.peak_bytes == 0
    assert streams == _dense_reference(model, params, reqs)


def test_churn_refill_hidden_behind_decode(engine_model):
    """On the virtual clock with a step-time model, ahead-of-need refills
    are overlapped with decode: the hidden share must dominate.  Parks are
    growth-driven (pool pressure), so the pump sees every parked snapshot
    a step before the engine tries to resume it."""
    _, model, params = engine_model
    streams, reqs, eng = _churn(
        model, params, steps=60, n_requests=8, seed=7, use_clock=True,
        preempt_p=0.0, resume_p=0.0, pool_pages=4, submit_p=0.9,
        snapshot_threshold=0, spill=SpillPolicy(refill_lookahead=4),
    )
    assert eng.refills > 0
    split = eng.ledger.spill_split()
    assert split["refill_hidden_frac"] > 0.5
    assert streams == _dense_reference(model, params, reqs)


def test_churn_transfer_faults_absorbed(engine_model):
    """Forced D2H and H2D faults: the victim falls back to re-prefill
    replay and streams stay identical — the fault never reaches the user."""
    from repro.core.hsa.faults import FaultPlan

    _, model, params = engine_model
    plan = FaultPlan()
    plan.force("d2h")
    plan.force("h2d")
    streams, reqs, eng = _churn(model, params, steps=40, n_requests=8,
                                seed=5, faults=plan)
    assert eng.transfer_faults == 2
    assert len(plan.trace) == 2
    assert eng.demotions >= 1             # faulted transfers degrade to replay
    assert streams == _dense_reference(model, params, reqs)


def test_host_budget_requires_paged(engine_model):
    _, model, params = engine_model
    with pytest.raises(ValueError):
        ServeEngine(model, params, batch_slots=2, host_budget_bytes=4096)


@pytest.mark.slow
def test_churn_spill_soak_10k_steps(engine_model):
    """10k-step-bounded soak under a squeezed budget: sustained spill/
    refill/demote cycling over hundreds of requests, arena and budget
    invariants checked every step, every stream bitwise-checked."""
    _, model, params = engine_model
    _, _, eng0 = _churn(model, params, steps=40, n_requests=8, seed=5)
    budget = eng0.arena.block_bytes       # one block: constant squeeze
    streams, reqs, eng = _churn(
        model, params, steps=10_000, n_requests=250, seed=13, fusion=2,
        preempt_p=0.15, resume_p=0.15, submit_p=0.3,
        host_budget_bytes=budget,
    )
    assert eng.spills > 0 and eng.demotions > 0
    assert eng.arena.peak_bytes <= budget
    assert streams == _dense_reference(model, params, reqs)
