"""Burst AQL submission: one doorbell for N packets, burst-drain grants,
composite completion waits, and the dispatch_submit/grant/wait ledger split.

Like test_scheduler.py, everything deterministic runs on the virtual clock.
"""

import threading

import jax
import jax.numpy as jnp
import pytest

import repro.kernels  # noqa: F401
from repro.core import ledger as ledger_mod
from repro.core.hsa import (
    CompositeSignal,
    Queue,
    Scheduler,
    Signal,
    VirtualClock,
    call_packet,
    dispatch_packet,
    wait_all,
)
from repro.core.ledger import OverheadLedger
from repro.core.policy import FusionPolicy
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary

COST = {"reconfig": 10.0, "exec": 1.0}


def _cost_model(kind, what, measured):
    return COST[kind]


def _mk_role(lib, n, name=None):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), name=name or f"mm{n}"))


def _mk_sched(num_regions=2, **kw):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(num_regions, ledger=led)
    sched = Scheduler(
        rm, lib, ledger=led, clock=VirtualClock(), cost_model=_cost_model, **kw
    )
    return sched, lib, rm, led


def _x(n):
    return jnp.ones((n, n))


# ---------------------------------------------------------------------------
# signals
# ---------------------------------------------------------------------------


def test_wait_all_and_composite_signal():
    sigs = [Signal(1, name=f"s{i}") for i in range(3)]
    comp = CompositeSignal(sigs)
    assert comp.load() == 3 and len(comp) == 3

    # a background completer stores 0 on each with small delays
    def complete():
        for s in sigs:
            s.store(0)

    t = threading.Thread(target=complete)
    t.start()
    assert comp.wait_eq(0, timeout=5.0)
    t.join()
    assert comp.load() == 0
    assert wait_all(sigs, 0, timeout=0.0)          # already satisfied: instant


def test_wait_all_times_out_when_any_signal_unmet():
    sigs = [Signal(0), Signal(1)]                  # second never completes
    assert not wait_all(sigs, 0, timeout=0.05)
    assert not CompositeSignal(sigs).wait_eq(0, timeout=0.05)
    with pytest.raises(ValueError):
        CompositeSignal(sigs).wait_eq(1)


# ---------------------------------------------------------------------------
# burst submission
# ---------------------------------------------------------------------------


def test_submit_burst_rings_doorbell_once():
    q = Queue(None, 64, name="b")
    rings = []
    q._notify = lambda: rings.append(q.doorbell.load())

    pkts = [call_packet(lambda: i, producer="tf") for i in range(5)]
    q.submit_burst(pkts)
    assert q.doorbell.load() == 5                  # write index after the burst
    assert rings == [5]                            # ONE notify for 5 packets
    assert {p.burst_id for p in pkts} == {pkts[0].burst_id}
    assert pkts[0].burst_id is not None
    assert all(p.burst_n == 5 for p in pkts)

    q.submit(call_packet(lambda: 9))
    assert rings == [5, 6]                         # plain submit: one each


def test_submit_burst_rejects_overflow_and_empty():
    q = Queue(None, 4, name="tiny")
    q.clock = VirtualClock(start=7.0)
    with pytest.raises(ValueError):
        q.submit_burst([])
    from repro.core.hsa.queue import QueueFullError
    pkts = [call_packet(lambda: i) for i in range(5)]
    with pytest.raises(QueueFullError):
        q.submit_burst(pkts)
    assert q.pending() == 0                        # nothing partially written
    # and nothing partially stamped: a caller may retry these packets
    # individually without dragging a dead burst_id / stale enqueue_t along
    for p in pkts:
        assert p.burst_id is None and p.burst_n == 1 and p.enqueue_t is None
    q.submit(pkts[0])
    assert pkts[0].enqueue_t == 7.0 and pkts[0].burst_n == 1


def test_burst_drains_in_one_grant_pass_round_robin_preserved():
    """A granted burst drains before round-robin moves on; a second tenant's
    individually-submitted packets then run.  With burst_grants=False the
    same workload interleaves — the amortization is the scheduler's doing."""

    def run(burst_grants):
        sched, lib, rm, led = _mk_sched(burst_grants=burst_grants)
        qa = sched.add_queue(Queue(None, 64, name="A"))
        qb = sched.add_queue(Queue(None, 64, name="B"))
        # pinned-shell fn packets: both queues flow from t=0 (no reconfig),
        # so grant order is purely the scheduler's burst-vs-round-robin choice
        qa.submit_burst(
            [call_packet(lambda: None, producer="tf") for _ in range(3)]
        )
        for _ in range(3):
            qb.call(lambda: None)
        sched.run_until_idle()
        return [e.queue for e in sched.event_log() if e.kind == "exec_start"]

    assert run(True) == ["A", "A", "A", "B", "B", "B"]
    assert run(False) == ["A", "B", "A", "B", "A", "B"]


def test_chained_burst_executes_in_submit_order():
    """Dependency-chained packets (a fused-decode stream) submitted as one
    burst: in-order consumption + completion signals sequence them."""
    sched, lib, rm, led = _mk_sched()
    r = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="serve"))

    order = []
    pkts = []
    prev = None
    for i in range(4):
        def fn(i=i):
            order.append(i)
            return i
        fn.__name__ = f"step{i}"
        pkts.append(call_packet(
            fn, producer="tf-serving",
            deps=(prev.completion,) if prev is not None else (),
        ))
        prev = pkts[-1]
    q.submit_burst(pkts)
    sched.run_until_idle()
    assert order == [0, 1, 2, 3]
    assert wait_all([p.completion for p in pkts], 0, timeout=0.0)
    assert [p.out.value for p in pkts] == [0, 1, 2, 3]


def test_burst_stops_draining_at_reconfig_stall():
    """A mid-burst residency miss stalls the queue; the drain must stop at
    the stalled packet, not skip it, and the burst completes after the load."""
    sched, lib, rm, led = _mk_sched(num_regions=1)
    ra, rb = _mk_role(lib, 8, name="ra"), _mk_role(lib, 16, name="rb")
    q = sched.add_queue(Queue(None, 64, name="A"))
    q.submit_burst([
        dispatch_packet(ra.key, _x(8), _x(8)),
        dispatch_packet(rb.key, _x(16), _x(16)),   # misses: ra occupies the region
        dispatch_packet(rb.key, _x(16), _x(16)),
    ])
    sched.run_until_idle()
    kinds = [e.kind for e in sched.event_log()]
    # first reconfig(ra), one exec, then the mid-burst stall for rb
    assert kinds.count("reconfig_start") == 2
    assert kinds.count("exec_end") == 3
    assert q.pending() == 0


# ---------------------------------------------------------------------------
# ledger split
# ---------------------------------------------------------------------------


def test_dispatch_split_submit_amortized_by_burst():
    """Submit-side only (no scheduler, no exec noise): one doorbell over 16
    packets must amortize the per-packet submit cost.  Noise robustness: the
    solo side is a *mean* of 16 independently-timed submits (a stall inflates
    it, which only widens the margin), the burst side the *min* of 3 bursts
    (a stall must hit all three windows to flip the assertion)."""
    led = OverheadLedger(keep_entries=True)

    def fresh_queue():
        q = Queue(None, 256, name="A")
        q.ledger = led
        return q

    q = fresh_queue()
    for _ in range(16):
        q.submit(call_packet(lambda: None, producer="solo"))
    for _ in range(3):
        fresh_queue().submit_burst(
            [call_packet(lambda: None, producer="burst") for _ in range(16)]
        )

    entries = [e for e in led.entries() if e.category == ledger_mod.DISPATCH_SUBMIT]
    solo = [e.seconds for e in entries if e.meta.get("burst") == 1]
    burst = [e.seconds for e in entries if e.meta.get("burst") == 16]
    assert len(solo) == 16 and len(burst) == 48
    assert min(burst) < (sum(solo) / len(solo)) * 0.5

    split = led.dispatch_split()
    assert split["submit_n"] == 64


def test_producer_breakdown_attributes_split_per_producer():
    sched, lib, rm, led = _mk_sched()
    r = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    q.dispatch(r.key, _x(8), _x(8), producer="tf-serving")
    q.dispatch(r.key, _x(8), _x(8), producer="opencl")
    sched.run_until_idle()

    by_prod = led.producer_breakdown()
    for prod in ("tf-serving", "opencl"):
        assert by_prod[prod][ledger_mod.DISPATCH_SUBMIT].count == 1
        assert by_prod[prod][ledger_mod.DISPATCH_GRANT].count == 1
    # the split appears in the Table II rendering once populated
    assert "submit (packet + doorbell)" in led.table()
    assert "grant (scheduler launch)" in led.table()


# ---------------------------------------------------------------------------
# fusion policy
# ---------------------------------------------------------------------------


def test_fusion_policy_contention_and_length_aware():
    pol = FusionPolicy(max_fusion=8, min_fusion=1, fairness_depth=4)
    # uncontended, long requests: full depth
    assert pol.choose_k(queue_depth=0, mean_request_len=64) == 8
    # short requests cap useful depth (pow2-rounded down)
    assert pol.choose_k(queue_depth=0, mean_request_len=3) == 2
    # contention halves per fairness_depth foreign packets
    assert pol.choose_k(queue_depth=4, mean_request_len=64) == 4
    assert pol.choose_k(queue_depth=8, mean_request_len=64) == 2
    # never below the floor, never above the cap
    assert pol.choose_k(queue_depth=10_000, mean_request_len=64) == 1
    assert pol.choose_k(queue_depth=0, mean_request_len=0.0) == 8
    assert FusionPolicy.of(6).choose_k(queue_depth=0, mean_request_len=100) == 6
    assert FusionPolicy.of(None).choose_k() == 1
    assert FusionPolicy.of(pol) is pol
    with pytest.raises(ValueError):
        FusionPolicy(max_fusion=0)
    with pytest.raises(ValueError):
        FusionPolicy(max_fusion=2, min_fusion=4)
