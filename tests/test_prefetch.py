"""Deterministic tests for the lookahead reconfiguration-prefetch pipeline.

Everything runs on the virtual clock with a fixed cost model, so the tests
assert *exact* event logs, exposed/hidden splits, and residency states —
including the acceptance property: a prefetched packet's ``prefetch_end``
precedes its ``exec_start`` with no intervening ``reconfig_start`` on that
queue (the region is hot before the packet is granted).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.core import ledger as ledger_mod
from repro.core.hsa import Queue, Scheduler, VirtualClock
from repro.core.ledger import OverheadLedger
from repro.core.policy import PrefetchPolicy
from repro.core.reconfig import PREFETCHING, RESERVED, RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary

COST = {"reconfig": 10.0, "exec": 1.0}


def _cost_model(kind, what, measured):
    return COST[kind]


def _mk_role(lib, n, name=None):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), name=name or f"mm{n}"))


def _mk_sched(num_regions=2, lookahead=0, **kw):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(num_regions, ledger=led)
    sched = Scheduler(
        rm, lib, ledger=led, clock=VirtualClock(), cost_model=_cost_model,
        lookahead=lookahead, **kw,
    )
    return sched, lib, rm, led


def _x(n):
    return jnp.ones((n, n))


def _settle(sched, max_steps=200):
    """Drive until no progress; a gated head reads as a (virtual) deadlock,
    which is exactly the settled state these tests inspect."""
    from repro.core.hsa import SchedulerDeadlock

    for _ in range(max_steps):
        try:
            if sched.step() is None:
                return
        except SchedulerDeadlock:
            return


# ---------------------------------------------------------------------------
# the acceptance property: prefetch fully hides the load
# ---------------------------------------------------------------------------


def test_prefetch_end_precedes_exec_start_no_reconfig_on_queue():
    """B's head waits on A's 12th completion (t=12); B's role loads [0, 10)
    on the reconfiguration engine while A computes.  Exact event log: the
    prefetch_end precedes B's exec_start and queue B never reconfigures."""
    sched, lib, rm, led = _mk_sched(num_regions=3, lookahead=1)
    ra, rb = _mk_role(lib, 8, "roleA"), _mk_role(lib, 16, "roleB")
    rm.ensure_resident(ra)
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))

    pkts = [qa.dispatch(ra.key, _x(8), _x(8)) for _ in range(12)]
    pb = qb.dispatch(rb.key, _x(16), _x(16), deps=[pkts[-1].completion])
    sched.run_until_idle()

    b_events = [e.brief() for e in sched.event_log() if e.queue == "B"]
    assert b_events == [
        ("prefetch_start", "B", "roleB"),
        ("prefetch_end", "B", "roleB"),
        ("prefetch_hit", "B", "roleB"),
        ("exec_start", "B", str(rb.key)),
        ("exec_end", "B", str(rb.key)),
    ]
    log = sched.event_log()
    t_pf_end = next(e.t for e in log if e.kind == "prefetch_end")
    t_exec = next(e.t for e in log if e.kind == "exec_start" and e.queue == "B")
    assert t_pf_end == 10.0 and t_exec == 12.0 and t_pf_end < t_exec
    assert not any(e.kind == "reconfig_start" and e.queue == "B" for e in log)

    # the load is fully hidden: no exposed stall on B, 10s hidden in the ledger
    assert sched.stats["B"].reconfig_s == 0.0
    assert sched.stats["B"].reconfig_hidden_s == 10.0
    assert sched.stats["B"].prefetch_hits == 1
    assert rm.stats.prefetch_issued == 1 and rm.stats.prefetch_hits == 1
    split = led.reconfig_split()
    assert split["exposed_s"] == 0.0 and split["hidden_s"] == 10.0
    assert pb.out.error is None
    np.testing.assert_allclose(np.asarray(pb.out.value)[0, 0], 16.0)


def test_demand_miss_joins_inflight_prefetch_partial_hiding():
    """B becomes ready at t=5 while its prefetch runs [0, 10): B joins the
    load instead of double-loading — 5s exposed, 5s hidden, one real load."""
    sched, lib, rm, led = _mk_sched(num_regions=3, lookahead=1)
    ra, rb = _mk_role(lib, 8, "roleA"), _mk_role(lib, 16, "roleB")
    rm.ensure_resident(ra)
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))

    pkts = [qa.dispatch(ra.key, _x(8), _x(8)) for _ in range(5)]
    pb = qb.dispatch(rb.key, _x(16), _x(16), deps=[pkts[-1].completion])
    sched.run_until_idle()

    log = sched.event_log()
    assert [e.brief() for e in log if e.queue == "B"] == [
        ("prefetch_start", "B", "roleB"),
        ("prefetch_hit", "B", "roleB"),
        ("prefetch_end", "B", "roleB"),
        ("exec_start", "B", str(rb.key)),
        ("exec_end", "B", str(rb.key)),
    ]
    assert next(e.t for e in log if e.kind == "exec_start" and e.queue == "B") == 10.0
    assert sched.stats["B"].reconfig_s == 5.0          # exposed residual only
    assert sched.stats["B"].reconfig_hidden_s == 5.0
    split = led.reconfig_split()
    assert split["exposed_s"] == 5.0 and split["hidden_s"] == 5.0
    # one real load served both the prefetch and the demand miss
    assert led.stat(ledger_mod.RECONFIG).count == 2    # roleA seed + roleB
    assert rm.stats.prefetch_hits == 1
    assert pb.out.error is None


def test_lookahead_zero_is_reactive_baseline():
    """lookahead=0 (the default) must produce zero prefetch machinery."""
    sched, lib, rm, led = _mk_sched(num_regions=2, lookahead=0)
    ra = _mk_role(lib, 8, "roleA")
    q = sched.add_queue(Queue(None, 64, name="A"))
    q.dispatch(ra.key, _x(8), _x(8))
    sched.run_until_idle()
    kinds = {e.kind for e in sched.event_log()}
    assert "prefetch_start" not in kinds and "prefetch_hit" not in kinds
    assert rm.stats.prefetch_issued == 0
    assert led.reconfig_split()["hidden_s"] == 0.0


# ---------------------------------------------------------------------------
# queue-aware (approximate Bélády) eviction
# ---------------------------------------------------------------------------


def test_eviction_skips_roles_in_lookahead_window():
    """Victim search must pass over a role a queued packet is about to use."""
    sched, lib, rm, led = _mk_sched(num_regions=2, lookahead=2)
    rx, ry, rz = (_mk_role(lib, n, f"r{n}") for n in (8, 16, 32))
    rm.ensure_resident(rx)       # LRU-oldest: the naive victim
    rm.ensure_resident(ry)       # referenced by A's dep-blocked head below
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))

    from repro.core.hsa import Signal

    gate = Signal(1, name="gate")
    pa = qa.dispatch(ry.key, _x(16), _x(16), deps=[gate])
    pb = qb.dispatch(rz.key, _x(32), _x(32))       # forces an eviction
    _settle(sched)
    # Z's demand load must have evicted X (LRU) — not window-protected Y
    assert not rm.is_resident(rx.key)
    assert rm.is_resident(ry.key)
    assert rm.is_resident(rz.key)
    gate.store(0)
    sched.run_until_idle()
    assert pa.out.error is None and pb.out.error is None
    # Y stayed hot: queue A never reconfigured
    assert sched.stats["A"].reconfigs == 0


def test_reactive_eviction_would_have_evicted_window_role():
    """Control for the test above: with lookahead=0 the same workload evicts
    the about-to-be-used role and pays a second reconfiguration."""
    sched, lib, rm, led = _mk_sched(num_regions=2, lookahead=0)
    rx, ry, rz = (_mk_role(lib, n, f"r{n}") for n in (8, 16, 32))
    rm.ensure_resident(rx)
    rm.ensure_resident(ry)
    rm.ensure_resident(rx)       # X most-recent: LRU victim is Y
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))

    from repro.core.hsa import Signal

    gate = Signal(1, name="gate")
    qa.dispatch(ry.key, _x(16), _x(16), deps=[gate])
    qb.dispatch(rz.key, _x(32), _x(32))
    _settle(sched)
    assert not rm.is_resident(ry.key)              # blind LRU took Y
    gate.store(0)
    sched.run_until_idle()
    assert sched.stats["A"].reconfigs == 1         # A paid for the reload


# ---------------------------------------------------------------------------
# prefetch/evict races and error paths (RegionManager state machine)
# ---------------------------------------------------------------------------


def test_touch_returns_false_after_reserved_role_force_flushed():
    """A reserved (prefetched-for-a-packet) role torn down by flush() must
    read as non-resident, and the waiting packet must reload cleanly."""
    from repro.core.hsa import Signal

    sched, lib, rm, led = _mk_sched(num_regions=3, lookahead=1)
    rb = _mk_role(lib, 16, "roleB")
    q = sched.add_queue(Queue(None, 64, name="B"))
    gate = Signal(1, name="gate")
    pb = q.dispatch(rb.key, _x(16), _x(16), deps=[gate])

    # the dep-blocked head's role prefetches and completes: resident + reserved
    _settle(sched)
    assert rm.state(rb.key) == RESERVED
    rm.flush()                                     # force-flush: all torn down
    assert rm.touch(rb.key) is False               # the race the exec path checks
    assert rm.stats.prefetch_wasted >= 1
    gate.store(0)
    sched.run_until_idle()
    # the packet still completed: the demand path reloaded under full accounting
    assert pb.out.error is None
    np.testing.assert_allclose(np.asarray(pb.out.value)[0, 0], 16.0)
    assert led.stat(ledger_mod.RECONFIG).count == 2  # prefetch load + reload
    assert sched.stats["B"].reconfigs == 1           # the reload was a stall


def test_begin_prefetch_raises_when_all_regions_pinned():
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(1, ledger=led)
    pinned, other = _mk_role(lib, 8, "pinned"), _mk_role(lib, 16, "other")
    rm.pin(pinned)
    with pytest.raises(RuntimeError, match="pinned"):
        rm.begin_prefetch(other)
    assert not rm.is_resident(other.key) and not rm.is_prefetching(other.key)


def test_demand_load_fails_when_pinned_plus_pending_prefetch_fill_regions():
    """A pending prefetch occupies a slot and is never an eviction victim:
    with the rest pinned, a third role's demand load must surface an error."""
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(2, ledger=led)
    pinned, pre, third = (
        _mk_role(lib, 8, "pinned"), _mk_role(lib, 16, "pre"), _mk_role(lib, 32, "third"),
    )
    rm.pin(pinned)
    assert rm.begin_prefetch(pre) is not None
    assert rm.state(pre.key) == PREFETCHING
    with pytest.raises(RuntimeError, match="pinned or loading"):
        rm.ensure_resident(third)
    # the in-flight prefetch survived the failed demand
    assert rm.state(pre.key) == PREFETCHING
    rm.complete_prefetch(pre.key)
    assert rm.state(pre.key) == RESERVED
    assert rm.touch(pre.key)                       # first touch consumes it
    assert rm.stats.prefetch_hits == 1


def test_scheduler_survives_all_pinned_with_lookahead():
    """All regions pinned + lookahead on: packets fail loudly (demand path),
    the prefetcher never loops, the scheduler goes idle."""
    sched, lib, rm, led = _mk_sched(num_regions=1, lookahead=4)
    pinned, other = _mk_role(lib, 8, "pinned"), _mk_role(lib, 16, "other")
    rm.pin(pinned)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkts = [q.dispatch(other.key, _x(16), _x(16)) for _ in range(3)]
    sched.run_until_idle()
    for pkt in pkts:
        assert isinstance(pkt.out.error, RuntimeError)
        assert pkt.completion.load() == 0
    assert rm.stats.prefetch_issued == 0
    assert not any(e.kind == "prefetch_start" for e in sched.event_log())


def test_single_region_never_speculates_and_demand_still_succeeds():
    """With one region the in-flight cap is 0: a dep-blocked queue's window
    must not let speculation occupy the only slot and fail other demand."""
    from repro.core.hsa import Signal

    sched, lib, rm, led = _mk_sched(num_regions=1, lookahead=2)
    rx, ry = _mk_role(lib, 8, "rx"), _mk_role(lib, 16, "ry")
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))
    gate = Signal(1, name="gate")
    pa = qa.dispatch(rx.key, _x(8), _x(8), deps=[gate])   # blocked: would prefetch
    pb = qb.dispatch(ry.key, _x(16), _x(16))              # flowing demand
    _settle(sched)
    gate.store(0)
    sched.run_until_idle()
    assert pa.out.error is None and pb.out.error is None
    assert rm.stats.prefetch_issued == 0


def test_sync_baseline_with_lookahead_never_prefetches():
    """overlap_reconfig=False models a device with no reconfiguration engine:
    the prefetch pipeline must stay off regardless of lookahead, so the sync
    schedule is identical to the reactive one."""
    def build(lookahead):
        sched, lib, rm, led = _mk_sched(
            num_regions=2, lookahead=lookahead, overlap_reconfig=False
        )
        ra, rb = _mk_role(lib, 8, "roleA"), _mk_role(lib, 16, "roleB")
        rm.ensure_resident(ra)
        qa = sched.add_queue(Queue(None, 64, name="A"))
        qb = sched.add_queue(Queue(None, 64, name="B"))
        pkts = [qa.dispatch(ra.key, _x(8), _x(8)) for _ in range(5)]
        qb.dispatch(rb.key, _x(16), _x(16), deps=[pkts[-1].completion])
        sched.run_until_idle()
        return [(e.t, e.brief()) for e in sched.event_log()], sched.timeline()

    log4, tl4 = build(4)
    log0, tl0 = build(0)
    assert log4 == log0
    assert tl4 == tl0
    assert not any(kind.startswith("prefetch") for _, (kind, _, _) in log4)


def test_speculation_never_displaces_sooner_demand():
    """begin_prefetch with a target needed later than every resident role's
    next use must decline (return None), not steal the region."""
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(1, ledger=led)
    soon, later = _mk_role(lib, 8, "soon"), _mk_role(lib, 16, "later")
    rm.ensure_resident(soon)
    # 'soon' is demanded at rank 0; prefetching 'later' (rank 4) must not evict it
    assert rm.begin_prefetch(later, protect={soon.key: 0}, target_rank=4) is None
    assert rm.is_resident(soon.key)
    # the Bélády argument cuts both ways: a sooner target MAY displace it
    assert rm.begin_prefetch(later, protect={soon.key: 4}, target_rank=0) is not None
    assert not rm.is_resident(soon.key)


# ---------------------------------------------------------------------------
# end-to-end: the sweep the acceptance criterion runs on calibrated costs
# ---------------------------------------------------------------------------


def _multi_tenant_exposed(lookahead: int):
    """Synthetic-cost twin of benchmarks/table5: serve tenant pinned and
    flowing, background tenant cycling 4 roles through 2 free regions."""
    sched, lib, rm, led = _mk_sched(num_regions=3, lookahead=lookahead)
    serve = _mk_role(lib, 64, "serve_fc")
    rm.pin(serve)
    sizes = (8, 16, 32, 48)
    roles = [_mk_role(lib, n, f"r{n}") for n in sizes]
    qs = sched.add_queue(Queue(None, 4096, name="serve"))
    qb = sched.add_queue(Queue(None, 4096, name="opencl"))
    for _ in range(96):
        qs.dispatch(serve.key, _x(64), _x(64))
    for _ in range(3):                       # 3 cycles x 4 roles x 4-packet bursts
        for r, n in zip(roles, sizes):
            for _ in range(4):
                qb.dispatch(r.key, _x(n), _x(n))
    sched.run_until_idle()
    assert not any(e.kind == "error" for e in sched.event_log())
    return sched, rm, led


def test_exposed_reconfig_strictly_below_reactive_at_lookahead_4():
    reactive = _multi_tenant_exposed(0)[0].exposed_reconfig_s()
    sched4, rm4, led4 = _multi_tenant_exposed(4)
    assert sched4.exposed_reconfig_s() < reactive
    assert rm4.stats.prefetch_hits > 0
    assert led4.reconfig_split()["hidden_s"] > 0.0
    # deeper lookahead never regresses past the reactive baseline
    sched8 = _multi_tenant_exposed(8)[0]
    assert sched8.exposed_reconfig_s() <= reactive


def test_prefetching_schedule_is_deterministic_across_replays():
    def one_run():
        sched, rm, led = _multi_tenant_exposed(4)
        return [(e.t, e.brief()) for e in sched.event_log()]

    runs = [one_run() for _ in range(3)]
    assert all(r == runs[0] for r in runs[1:])


# ---------------------------------------------------------------------------
# the planner-side lookahead knob
# ---------------------------------------------------------------------------


def test_prefetch_policy_validation():
    assert PrefetchPolicy.of(None).lookahead == 0
    assert PrefetchPolicy.of(4).lookahead == 4
    assert PrefetchPolicy.of(PrefetchPolicy(2)).lookahead == 2
    with pytest.raises(ValueError):
        PrefetchPolicy(-1)


def test_simulate_lru_lookahead_zero_matches_serial_model():
    from repro.core import policy

    cost = policy.CostModel(
        reconfig_s=1.0, dispatch_s=0.0,
        exec_generic_s={"op": 0.25}, exec_fixed_s={"op": 0.25},
    )
    roles = [f"r{i % 3}" for i in range(12)]
    spec_of = {r: "generic" for r in roles}
    op_of = {r: "op" for r in roles}
    sim = policy.simulate_lru(roles, 2, cost, spec_of, op_of, repeats=1)
    assert sim.total_s == pytest.approx(sim.misses * 1.0 + 12 * 0.25)
    assert sim.exposed_s == pytest.approx(sim.misses * 1.0)
    assert sim.hidden_s == 0.0


def test_simulate_lru_lookahead_reduces_exposed_not_correctness():
    from repro.core import policy

    cost = policy.CostModel(
        reconfig_s=1.0, dispatch_s=0.0,
        exec_generic_s={"op": 0.5}, exec_fixed_s={"op": 0.5},
    )
    roles = [f"r{(i // 4) % 3}" for i in range(48)]   # bursty cyclic trace
    spec_of = {r: "generic" for r in roles}
    op_of = {r: "op" for r in roles}
    serial = policy.simulate_lru(roles, 2, cost, spec_of, op_of, repeats=2)
    ahead = policy.simulate_lru(
        roles, 2, cost, spec_of, op_of, repeats=2, lookahead=4
    )
    assert ahead.exposed_s < serial.exposed_s
    assert ahead.hidden_s > 0.0
    assert ahead.total_s <= serial.total_s
    assert ahead.exposed_s + ahead.hidden_s == pytest.approx(
        ahead.misses * cost.reconfig_s
    )


# ---------------------------------------------------------------------------
# regression: depth 1 must see past a same-role burst at the head
# ---------------------------------------------------------------------------


def test_depth1_prefetches_next_role_behind_same_role_burst():
    """Regression for the table5 ``lookahead1 == lookahead0`` symptom
    (``prefetch_issued=0``): the lookahead window used raw packet positions,
    so a burst of same-role packets at a stalled head filled the whole
    depth-1 window and the next role was never scanned.  Distance is now
    counted in distinct-role *groups*: while roleB's demand load stalls the
    queue, depth 1 must speculatively load roleC — the immediately-next role
    switch — even though its first packet sits at raw index >= 4."""

    def build(lookahead):
        sched, lib, rm, led = _mk_sched(num_regions=3, lookahead=lookahead)
        rb = _mk_role(lib, 8, "roleB")
        rc = _mk_role(lib, 16, "roleC")
        q = sched.add_queue(Queue(None, 64, name="B"))
        pkts = [q.dispatch(rb.key, _x(8), _x(8)) for _ in range(4)]
        pkts += [q.dispatch(rc.key, _x(16), _x(16)) for _ in range(4)]
        sched.run_until_idle()
        assert all(p.out.error is None for p in pkts)
        return sched, rm, led

    s1, rm1, led1 = build(1)
    briefs = [e.brief() for e in s1.event_log()]
    assert ("prefetch_start", "B", "roleC") in briefs
    assert rm1.stats.prefetch_issued == 1
    assert rm1.stats.prefetch_hits == 1
    assert s1.stats["B"].reconfig_hidden_s > 0.0

    # the reactive twin pays roleC's load fully exposed
    s0, rm0, led0 = build(0)
    assert rm0.stats.prefetch_issued == 0
    assert s1.stats["B"].reconfig_s < s0.stats["B"].reconfig_s
    assert (led1.reconfig_split()["exposed_s"]
            < led0.reconfig_split()["exposed_s"])

    # virtual clock: the schedule is a pure function of the trace
    s1b, _, _ = build(1)
    assert [(e.t, e.brief()) for e in s1b.event_log()] \
        == [(e.t, e.brief()) for e in s1.event_log()]
