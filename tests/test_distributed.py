"""Multi-device correctness: EP MoE vs local path, sharded train step vs
single-device, compressed psum under shard_map.

Runs in subprocesses with ``--xla_force_host_platform_device_count=4`` so the
rest of the suite keeps seeing one device.
"""

import subprocess
import sys

import pytest

# every case spawns a fresh interpreter that recompiles the model under
# --xla_force_host_platform_device_count=4 (~5-8 min each): tier-1 skips
# them via the `slow` marker; CI's non-blocking slow job runs them
pytestmark = pytest.mark.slow

COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import ARCHS, reduced
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.models import build_model, moe as moe_mod
from repro.models.params import init_params
from repro.train.step import make_train_step, moe_mesh_info
from repro.optim.adamw import OptConfig, opt_init
mesh = make_mesh((2, 2), ("data", "model"))
"""


def run_case(body: str) -> None:
    proc = subprocess.run(
        [sys.executable, "-c", COMMON + body],
        capture_output=True, text=True, timeout=600,
        # JAX_PLATFORMS pins backend discovery: without it jax probes for
        # TPU/GPU plugins for minutes on network-less CI containers
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"


def test_moe_ep_all_to_all_matches_local():
    run_case("""
cfg = reduced(ARCHS["deepseek-v3-671b"])
# top-k >= 4 selects the EP-all layout (rules.for_arch threshold)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=8, experts_per_token=4, capacity_factor=8.0))
m = cfg.moe
p = init_params(moe_mod.moe_specs(cfg), jax.random.key(0))
p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)

y_local, aux_local = moe_mod.apply_moe(p, x, cfg)

rules = ShardingRules.for_arch(cfg, mesh)
with jax.set_mesh(mesh):
    info = moe_mesh_info(cfg, rules)
    assert info.mode == "all", info.mode
    y_ep, aux_ep = jax.jit(
        lambda pp, xx: moe_mod.apply_moe(pp, xx, cfg, mesh_info=info)
    )(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local),
                           rtol=2e-4, atol=2e-4)
# capacity semantics differ (per-shard vs global), but with cf=8 nothing drops
assert float(aux_ep["dropped_frac"]) == 0.0
assert float(aux_local["dropped_frac"]) == 0.0
print("EP all_to_all OK")
""")


def test_moe_ep_tp_matches_local():
    run_case("""
cfg = reduced(ARCHS["llama4-maverick-400b-a17b"])
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=4, experts_per_token=1, capacity_factor=8.0))
p = init_params(moe_mod.moe_specs(cfg), jax.random.key(0))
p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
y_local, _ = moe_mod.apply_moe(p, x, cfg)
rules = ShardingRules.for_arch(cfg, mesh)
with jax.set_mesh(mesh):
    info = moe_mesh_info(cfg, rules)
    assert info.mode == "tp", info.mode
    y_ep, _ = jax.jit(
        lambda pp, xx: moe_mod.apply_moe(pp, xx, cfg, mesh_info=info)
    )(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local),
                           rtol=2e-4, atol=2e-4)
print("EP tp OK")
""")


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m", "hymba-1.5b"])
def test_sharded_train_step_matches_single_device(arch):
    run_case(f"""
cfg = reduced(ARCHS["{arch}"])
model = build_model(cfg)
opt = OptConfig(kind="adamw", lr=1e-3, warmup_steps=1, decay_steps=10)
rng = np.random.default_rng(0)
batch = {{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}}

# single device reference
params = init_params(model.param_specs(), jax.random.key(0))
params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
loss_ref, _ = model.loss(params, batch)

# sharded step on the 2x2 mesh
rules = ShardingRules.for_arch(cfg, mesh)
with jax.set_mesh(mesh):
    step, p_sh, o_sh, b_sh = make_train_step(model, opt, rules, global_batch=4,
                                             donate=False)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    opt_state = jax.tree.map(jax.device_put, opt_init(opt, params_s), o_sh)
    batch_s = {{k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}}
    new_p, new_o, metrics = step(params_s, opt_state, batch_s)
np.testing.assert_allclose(float(metrics["nll"]), float(loss_ref),
                           rtol=5e-4, atol=5e-4)
assert np.isfinite(float(metrics["grad_norm"]))
print("sharded train step OK", float(metrics["nll"]), float(loss_ref))
""")


def test_compressed_psum_in_shard_map():
    run_case("""
from jax.experimental.shard_map import shard_map
from repro.dist.collectives import compressed_psum

x = jax.random.normal(jax.random.key(0), (4, 64), jnp.float32)

def body(x_loc):
    y, res = compressed_psum(x_loc, ("data",))
    return y, res

with jax.set_mesh(mesh):
    y, res = shard_map(
        body, mesh=mesh,
        in_specs=(P(("data",), None),),
        out_specs=(P(None, None), P(("data",), None)),
        check_rep=False,
    )(x)
true_mean = np.asarray(x).reshape(2, 2, 64).mean(axis=0)  # mean over data axis
got = np.asarray(y)
# int8 quantization error is bounded by max|x|/127 per element
assert np.abs(got[:1] - true_mean[:1]).max() < np.abs(np.asarray(x)).max() / 64
print("compressed psum OK")
""")


def test_sequence_parallel_decode_matches_single_device():
    """Serving rules + kv_heads < TP triggers the shard_map SP decode path;
    generations must match the single-device reference exactly."""
    run_case("""
import repro.models.layers as L
import jax.numpy as jnp
L.COMPUTE_DTYPE = jnp.float32
cfg = reduced(ARCHS["yi-9b"])     # heads=4, kv=1 -> kv % model(2) != 0
assert cfg.num_kv_heads % 2 != 0
model = build_model(cfg)
params = init_params(model.param_specs(), jax.random.key(2))
params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
rng = np.random.default_rng(1)
S, EXTRA, CL = 8, 4, 16
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, S + EXTRA)), jnp.int32)

# single-device reference
lg_ref, cache = model.prefill(params, {"tokens": toks[:, :S]}, cache_len=CL)
refs = [np.asarray(lg_ref)]
for t in range(EXTRA):
    lg_ref, cache = model.decode_step(params, toks[:, S+t:S+t+1], cache)
    refs.append(np.asarray(lg_ref))

# sharded serving path
from repro.serve.engine import make_decode_step, make_prefill_step, cache_shardings
rules = ShardingRules.for_arch(cfg, mesh, serving=True)
with jax.set_mesh(mesh):
    pre, p_sh, b_sh = make_prefill_step(model, rules, global_batch=4, cache_len=CL)
    dec, _, c_sh, cache_tree = make_decode_step(model, rules, global_batch=4,
                                                cache_len=CL, donate_cache=False)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    lg, cache_s = pre(params_s, {"tokens": jax.device_put(toks[:, :S], b_sh["tokens"])})
    np.testing.assert_allclose(np.asarray(lg), refs[0], rtol=2e-4, atol=2e-4)
    cache_s = jax.tree.map(jax.device_put, cache_s, c_sh)
    tok_sh = NamedSharding(mesh, P("data", None))
    for t in range(EXTRA):
        lg, cache_s = dec(params_s,
                          jax.device_put(toks[:, S+t:S+t+1], tok_sh), cache_s)
        np.testing.assert_allclose(np.asarray(lg), refs[t+1], rtol=2e-4, atol=2e-4,
                                   err_msg=f"step {t}")
print("SP decode OK")
""")
