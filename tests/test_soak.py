"""Threaded (WallClock) scheduler soak: the ROADMAP open item.

Multi-producer stress against the *threaded* scheduler (``start()``), with
real reconfiguration offload — roles whose working set exceeds the region
count, so the background reconfig pool continuously loads/evicts real XLA
executables while producer threads keep submitting (singles, chained bursts,
and barriers).  Bounded runtime: every wait carries a timeout, and the
asserts are "no deadlock, no lost completion, no error", not timing.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.core.hsa import Queue, Scheduler, WallClock, call_packet, wait_all
from repro.core.hsa.queue import dispatch_packet
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary

TIMEOUT_S = 120.0          # hard bound: the test fails, not hangs, on deadlock
PRODUCERS = 3
PACKETS_PER_PRODUCER = 12


def _mk_role(lib, n, name):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), name=name))


def test_threaded_scheduler_soak_no_deadlock_no_lost_completion():
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    # 4 roles over 2 regions: every producer's role cycle keeps missing
    # residency, so reconfigurations run on the background pool throughout
    roles = [_mk_role(lib, n, f"soak_mm{n}") for n in (8, 12, 16, 24)]
    regions = RegionManager(2, ledger=led)
    sched = Scheduler(regions, lib, ledger=led, clock=WallClock(), lookahead=2)
    queues = [
        sched.add_queue(Queue(None, 256, name=f"prod{i}"))
        for i in range(PRODUCERS)
    ]
    sched.start(reconfig_workers=2)

    all_pkts: list = []
    pkts_lock = threading.Lock()
    errors: list = []

    def producer(idx: int) -> None:
        try:
            q = queues[idx]
            local = []
            prev = None
            for j in range(PACKETS_PER_PRODUCER):
                role = roles[(idx + j) % len(roles)]
                n = int(role.name.replace("soak_mm", ""))
                x = jnp.ones((n, n))
                if j % 4 == 3:
                    # every 4th packet: a chained 2-packet burst (one doorbell)
                    first = dispatch_packet(
                        role.key, x, x, producer=f"p{idx}",
                        deps=(prev.completion,) if prev is not None else (),
                    )
                    second = call_packet(
                        lambda v=n: v, producer=f"p{idx}",
                        deps=(first.completion,),
                    )
                    q.submit_burst([first, second])
                    local += [first, second]
                    prev = second
                else:
                    prev = q.dispatch(role.key, x, x, producer=f"p{idx}")
                    local.append(prev)
            with pkts_lock:
                all_pkts.extend(local)
        except BaseException as e:            # surface, don't hang the join
            errors.append(e)

    threads = [
        threading.Thread(target=producer, args=(i,), name=f"producer-{i}")
        for i in range(PRODUCERS)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=TIMEOUT_S)
        assert not t.is_alive(), "producer thread wedged"
    assert not errors, errors

    try:
        # one composite wait covers every completion signal in the soak
        assert wait_all(
            [p.completion for p in all_pkts], 0, timeout=TIMEOUT_S
        ), "deadlock or lost completion: signals never reached 0"
    finally:
        sched.stop()

    # no lost completions, no errors, and every kernel's result is real
    assert len(all_pkts) > PRODUCERS * PACKETS_PER_PRODUCER  # bursts add extras
    for p in all_pkts:
        assert p.completion.load() == 0
        assert p.out.error is None, p.out.error
        assert p.out.value is not None
    for p in all_pkts:
        if p.role_key is not None:
            n = p.args[0].shape[0]
            np.testing.assert_allclose(np.asarray(p.out.value)[0, 0], float(n))

    # the device really did reconfigure under load, on the offload pool
    assert sum(st.reconfigs for st in sched.stats.values()) > 0
    total = sum(st.dispatched + st.barriers for st in sched.stats.values())
    assert total == len(all_pkts)
    # stop() is idempotent and the worker is gone
    sched.stop()
    assert not sched.running
