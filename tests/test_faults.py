"""Deterministic fault injection + self-healing runtime.

Three layers of recovery, each tested on the virtual clock with exact
timestamps where the schedule is deterministic:

  - **scheduler** — transient exec faults retry in place with exponential
    backoff; permanent faults fail the packet; wedged launches are killed by
    the watchdog after their deadline window; a queue that faults K
    consecutive times is quarantined and its pending packets migrate to
    sibling queues.
  - **reconfig** — a failed region load retries through the abort_prefetch
    path instead of failing the head packet.
  - **engine** — a serve launch that dies to a FaultError parks its requests
    via the preemption machinery and resumes by re-prefill replay; completed
    streams are bitwise-identical to fault-free runs, and requests whose
    recovery budget is spent surface in ``ServeTruncated.failed``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.configs import ARCHS, reduced
from repro.core import ledger as ledger_mod
from repro.core.hsa import (
    FaultPlan,
    InjectedFault,
    InjectedLoadFault,
    PermanentFault,
    Queue,
    Scheduler,
    Signal,
    VirtualClock,
    WedgedLaunch,
    wait_all,
)
from repro.core.hsa.faults import FaultError
from repro.core.ledger import OverheadLedger
from repro.core.policy import RetryPolicy
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, ServeTruncated

COST = {"reconfig": 10.0, "exec": 1.0}


def _cost_model(kind, what, measured):
    return COST[kind]


def _mk_role(lib, n, name=None):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), name=name or f"mm{n}"))


def _mk_sched(num_regions=2, *, retry=None, faults=None, expected_exec_s=None,
              cost=_cost_model):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(num_regions, ledger=led)
    sched = Scheduler(
        rm, lib, ledger=led, clock=VirtualClock(), cost_model=cost,
        retry=retry, faults=faults, expected_exec_s=expected_exec_s,
    )
    return sched, lib, rm, led


def _x(n):
    return jnp.ones((n, n))


_RETRY = RetryPolicy(backoff_s=0.5, backoff_factor=2.0, max_backoff_s=8.0)


# ---------------------------------------------------------------------------
# FaultPlan / RetryPolicy units
# ---------------------------------------------------------------------------


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="exec_rate"):
        FaultPlan(exec_rate=1.5)
    with pytest.raises(ValueError, match="> 1"):
        FaultPlan(exec_rate=0.6, wedge_rate=0.5)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan().force("bogus")


def test_retry_policy_validation_and_coercion():
    assert RetryPolicy.of(None) is None
    pol = RetryPolicy()
    assert RetryPolicy.of(pol) is pol
    assert RetryPolicy.of(5).max_retries == 5
    assert pol.backoff(1) == pol.backoff_s
    assert pol.backoff(2) == pol.backoff_s * pol.backoff_factor
    assert pol.backoff(100) == pol.max_backoff_s          # capped
    assert pol.watchdog_deadline(1.0) == pol.watchdog_factor
    assert pol.watchdog_deadline(0.0) == pol.watchdog_floor_s
    with pytest.raises(ValueError, match="backoff_factor"):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="max_backoff_s"):
        RetryPolicy(backoff_s=2.0, max_backoff_s=1.0)


def test_forced_faults_consumed_in_order():
    plan = FaultPlan()
    plan.force("exec", "mm8", count=2)
    plan.force("wedge")
    assert isinstance(plan.draw_exec("mm16"), WedgedLaunch)   # mm8 no match
    assert isinstance(plan.draw_exec("mm8"), InjectedFault)
    assert isinstance(plan.draw_exec("mm8"), InjectedFault)
    assert plan.draw_exec("mm8") is None                      # all consumed
    assert [e.kind for e in plan.trace] == ["wedge", "exec", "exec"]
    assert all(e.forced for e in plan.trace)


def test_forced_count_fires_once_per_matching_attempt():
    plan = FaultPlan()
    plan.force("h2d", count=3)
    for _ in range(3):
        assert plan.draw_transfer("h2d", "kv[0]") is not None
    assert plan.draw_transfer("h2d", "kv[0]") is None         # count spent
    assert plan.draw_transfer("h2d", "kv[0]") is None         # stays spent
    assert len(plan.trace) == 3


def test_forced_kinds_interleave_independently():
    """force() entries of different kinds are consumed by their own draw
    sites in whatever order the runtime reaches them — an exec entry never
    absorbs a transfer or corruption draw and vice versa."""
    plan = FaultPlan()
    plan.force("exec", count=2)
    plan.force("h2d")
    plan.force("flip_page", count=2)
    plan.force("corrupt_transfer")

    # corruption draws consume only corruption entries, fail-stop untouched
    assert plan.draw_corruption("flip_page", ["page[3]", "page[7]"]) == 0
    assert plan.draw_corruption("flip_block", ["block[uid=1]"]) is None
    assert isinstance(plan.draw_exec("mm8"), InjectedFault)   # exec #1 intact
    assert plan.draw_corruption("corrupt_transfer", ["h2d kv[2]"]) == 0
    assert plan.draw_transfer("h2d", "kv[2]") is not None     # h2d intact
    assert isinstance(plan.draw_exec("mm8"), InjectedFault)   # exec #2
    assert plan.draw_corruption("flip_page", ["page[9]"]) == 0
    # every forced entry spent; all sites now draw clean
    assert plan.draw_exec("mm8") is None
    assert plan.draw_transfer("h2d", "kv[2]") is None
    assert plan.draw_corruption("flip_page", ["page[9]"]) is None
    kinds = [e.kind for e in plan.trace]
    assert kinds == ["flip_page", "exec", "corrupt_transfer", "h2d",
                     "exec", "flip_page"]
    assert all(e.forced for e in plan.trace)


def test_forced_corruption_respects_what_substring():
    plan = FaultPlan()
    plan.force("flip_page", "page[7]")
    # a target list without the match draws nothing and keeps the entry
    assert plan.draw_corruption("flip_page", ["page[3]", "page[5]"]) is None
    assert plan.draw_corruption("flip_page", ["page[3]", "page[7]"]) == 1
    assert plan.draw_corruption("flip_page", ["page[7]"]) is None  # consumed


def test_force_rejects_unknown_kind_and_bad_count():
    plan = FaultPlan()
    with pytest.raises(ValueError):
        plan.force("meteor")
    with pytest.raises(ValueError):
        plan.force("exec", count=0)
    with pytest.raises(ValueError):
        plan.draw_corruption("exec", ["page[1]"])   # not a corruption kind


# ---------------------------------------------------------------------------
# scheduler: retry / backoff / watchdog (exact virtual timestamps)
# ---------------------------------------------------------------------------


def test_transient_exec_fault_retries_with_exact_backoff():
    plan = FaultPlan()
    plan.force("exec", count=2)
    sched, lib, rm, led = _mk_sched(retry=_RETRY, faults=plan)
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()

    assert pkt.out.error is None
    np.testing.assert_allclose(np.asarray(pkt.out.value)[0, 0], 8.0)
    k = str(r8.key)
    assert [e.brief() for e in sched.event_log()] == [
        ("reconfig_start", "A", "mm8"),
        ("reconfig_end", "A", "mm8"),
        ("exec_start", "A", k),
        ("fault", "A", f"{k}!exec"),
        ("retry", "A", f"{k}#1"),
        ("exec_start", "A", k),
        ("fault", "A", f"{k}!exec"),
        ("retry", "A", f"{k}#2"),
        ("exec_start", "A", k),
        ("exec_end", "A", k),
    ]
    # backoff doubles: reconfig [0,10), attempts at 10, 11.5 (+0.5), 13.5 (+1.0)
    starts = [e.t for e in sched.event_log() if e.kind == "exec_start"]
    assert starts == [10.0, 11.5, 13.5]
    avail = led.availability_split()
    assert avail["faults"] == avail["exec_faults"] == 2
    assert avail["retries"] == 2
    assert avail["retry_backoff_s"] == 1.5
    assert avail["fault_s"] == 2.0                 # both lost attempts, 1s each
    assert avail["attempts"] == 3                  # 1 success + 2 faults
    assert avail["fault_rate"] == pytest.approx(2 / 3)


def test_permanent_fault_fails_packet_without_retry():
    plan = FaultPlan()
    plan.force("exec", permanent=True)
    sched, lib, rm, led = _mk_sched(retry=_RETRY, faults=plan)
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    bad = q.dispatch(r8.key, _x(8), _x(8))
    good = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()

    assert isinstance(bad.out.error, PermanentFault)
    assert bad.completion.load() == 0              # waiter released
    assert good.out.error is None                  # loop survived the fault
    assert led.availability_split()["permanent_faults"] == 1
    assert led.availability_split()["retries"] == 0


def test_retry_budget_exhausted_fails_packet():
    plan = FaultPlan()
    plan.force("exec", count=10)
    sched, lib, rm, led = _mk_sched(
        retry=RetryPolicy(max_retries=2, backoff_s=0.5, max_backoff_s=8.0),
        faults=plan,
    )
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()

    assert isinstance(pkt.out.error, InjectedFault)
    avail = led.availability_split()
    assert avail["faults"] == 3                    # initial + 2 retries, all lost
    assert avail["retries"] == 2


def test_wedged_launch_charged_watchdog_window_then_retried():
    plan = FaultPlan()
    plan.force("wedge")
    sched, lib, rm, led = _mk_sched(
        retry=_RETRY, faults=plan, expected_exec_s=1.0,
    )
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()

    assert pkt.out.error is None
    # exec_start at 10; the wedge occupies its whole watchdog window
    # (8 x expected 1.0s), is killed at 18, and the retry lands at 18.5
    fault = next(e for e in sched.event_log() if e.kind == "fault")
    assert fault.t == 18.0 and fault.what.endswith("!wedge")
    starts = [e.t for e in sched.event_log() if e.kind == "exec_start"]
    assert starts == [10.0, 18.5]
    avail = led.availability_split()
    assert avail["wedges"] == 1 and avail["exec_faults"] == 1
    assert avail["fault_s"] == 8.0


def test_wedge_without_retry_policy_fails_after_watchdog():
    plan = FaultPlan()
    plan.force("wedge")
    sched, lib, rm, led = _mk_sched(faults=plan, expected_exec_s=2.0)
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()
    assert isinstance(pkt.out.error, WedgedLaunch)
    fault = next(e for e in sched.event_log() if e.kind == "fault")
    assert fault.t == 10.0 + 16.0                  # fallback watchdog: 8 x 2.0


# ---------------------------------------------------------------------------
# reconfig: load faults retry through the abort path
# ---------------------------------------------------------------------------


def test_load_fault_retries_without_failing_head_packet():
    plan = FaultPlan()
    plan.force("load", count=1)
    sched, lib, rm, led = _mk_sched(retry=_RETRY, faults=plan)
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()

    assert pkt.out.error is None                   # head packet survived
    np.testing.assert_allclose(np.asarray(pkt.out.value)[0, 0], 8.0)
    briefs = [e.brief() for e in sched.event_log()]
    assert briefs.count(("reconfig_start", "A", "mm8")) == 2
    assert ("fault", "A", "mm8!load") in briefs
    assert ("retry", "A", "mm8#1") in briefs
    # failed load [0,10) + backoff 0.5 + reload [10.5,20.5) + exec
    second = [e.t for e in sched.event_log() if e.kind == "reconfig_start"][1]
    assert second == 10.5
    avail = led.availability_split()
    assert avail["load_faults"] == 1 and avail["retries"] == 1
    assert not rm.is_prefetching(r8.key)           # no leaked in-flight entry


def test_load_fault_budget_exhausted_surfaces_to_waiter():
    plan = FaultPlan()
    plan.force("load", count=10)
    sched, lib, rm, led = _mk_sched(
        retry=RetryPolicy(max_retries=1, backoff_s=0.5, max_backoff_s=8.0),
        faults=plan,
    )
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()
    assert isinstance(pkt.out.error, InjectedLoadFault)
    assert pkt.completion.load() == 0
    assert not rm.is_resident(r8.key)
    assert led.availability_split()["load_faults"] == 2


# ---------------------------------------------------------------------------
# quarantine: K consecutive faults migrate the queue's pending work
# ---------------------------------------------------------------------------


def test_quarantine_migrates_pending_to_sibling_queue():
    plan = FaultPlan()
    plan.force("exec", count=2)
    sched, lib, rm, led = _mk_sched(
        retry=RetryPolicy(max_retries=0, quarantine_after=2, backoff_s=0.5,
                          max_backoff_s=8.0),
        faults=plan,
    )
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))
    pkts = [qa.call(lambda i=i: i) for i in range(4)]
    sched.run_until_idle()

    # the first two attempts fault (max_retries=0: each fails its packet,
    # building the consecutive streak); the streak quarantines A and the
    # two still-pending packets migrate to B and complete there
    assert sched.quarantined_queues == frozenset({"A"})
    assert isinstance(pkts[0].out.error, InjectedFault)
    assert isinstance(pkts[1].out.error, InjectedFault)
    assert pkts[2].out.error is None and pkts[2].out.value == 2
    assert pkts[3].out.error is None and pkts[3].out.value == 3
    assert sched.stats["B"].dispatched == 2
    briefs = [e.brief() for e in sched.event_log()]
    assert ("quarantine", "A", "migrated[2]") in briefs
    avail = led.availability_split()
    assert avail["quarantines"] == 1 and avail["migrated_packets"] == 2

    # reinstate: A serves again
    sched.reinstate("A")
    assert sched.quarantined_queues == frozenset()
    ok = qa.call(lambda: 42)
    sched.run_until_idle()
    assert ok.out.value == 42 and sched.stats["A"].dispatched == 1


def test_lone_queue_is_never_quarantined():
    plan = FaultPlan()
    plan.force("exec", count=3)
    sched, lib, rm, led = _mk_sched(
        retry=RetryPolicy(max_retries=0, quarantine_after=2, backoff_s=0.5,
                          max_backoff_s=8.0),
        faults=plan,
    )
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkts = [q.call(lambda i=i: i) for i in range(4)]
    sched.run_until_idle()
    # nowhere to migrate: the lone queue keeps serving through its faults
    assert sched.quarantined_queues == frozenset()
    assert pkts[3].out.value == 3
    assert led.availability_split()["quarantines"] == 0


def test_drain_waits_for_migrated_packets():
    """drain(queue) must cover packets that were migrated off the queue."""
    plan = FaultPlan()
    plan.force("exec", count=2)
    sched, lib, rm, led = _mk_sched(
        retry=RetryPolicy(max_retries=0, quarantine_after=2, backoff_s=0.5,
                          max_backoff_s=8.0),
        faults=plan,
    )
    qa = sched.add_queue(Queue(None, 64, name="A"))
    sched.add_queue(Queue(None, 64, name="B"))
    pkts = [qa.call(lambda i=i: i) for i in range(4)]
    sched.drain(qa)
    # the two migrated packets completed on B before drain returned
    assert pkts[2].out.value == 2 and pkts[3].out.value == 3


# ---------------------------------------------------------------------------
# error propagation through dependency chains (signals carry errors)
# ---------------------------------------------------------------------------


def test_barrier_propagates_upstream_error():
    """A barrier over a failed packet's completion must observe the failure
    (the signal fires so waiters wake, but carries the error), and packets
    depending on the barrier must fail with the propagated error instead of
    executing on a missing result."""
    sched, lib, rm, led = _mk_sched()
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    bad = q.dispatch(r8.key, _x(4), _x(4))         # wrong shapes: exec error
    bar = q.barrier([bad.completion])
    dep = q.dispatch(r8.key, _x(8), _x(8), deps=[bar.completion])
    sched.run_until_idle()

    assert bad.out.error is not None
    assert bar.completion.load() == 0              # barrier still fires...
    briefs = [e.brief() for e in sched.event_log()]
    assert ("barrier", "A", "and[1]!error") in briefs   # ...but logs the error
    assert dep.out.error is bad.out.error          # propagated, not executed
    assert dep.out.value is None


def test_kernel_dep_on_failed_packet_does_not_execute():
    sched, lib, rm, led = _mk_sched()
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    bad = q.dispatch(r8.key, _x(4), _x(4))
    dep = q.dispatch(r8.key, _x(8), _x(8), deps=[bad.completion])
    ok = q.dispatch(r8.key, _x(8), _x(8))          # independent: must run
    sched.run_until_idle()
    assert dep.out.error is bad.out.error
    assert ok.out.error is None
    # the dependent kernel never reached the compute engine
    k = str(r8.key)
    execs = [e for e in sched.event_log() if e.kind == "exec_start"]
    assert len(execs) == 2                         # bad + ok, never dep


# ---------------------------------------------------------------------------
# signal timed waits on the injectable clock
# ---------------------------------------------------------------------------


def test_signal_timed_wait_on_virtual_clock():
    clk = VirtualClock()
    sig = Signal(1, name="s", clock=clk)
    assert sig.wait_eq(0, timeout=2.5) is False
    assert clk.now() == 2.5                        # advanced, never slept
    sig.store(0)
    assert sig.wait_eq(0, timeout=2.5) is True
    assert clk.now() == 2.5                        # satisfied wait: no time


def test_wait_all_shares_one_virtual_deadline():
    clk = VirtualClock()
    a, b = Signal(0, clock=clk), Signal(1, clock=clk)
    assert wait_all([a, b], timeout=1.0) is False
    assert clk.now() == 1.0
    b.store(0)
    assert wait_all([a, b], timeout=1.0) is True
    assert clk.now() == 1.0


# ---------------------------------------------------------------------------
# determinism: seeded fault schedules replay bit-for-bit
# ---------------------------------------------------------------------------


def test_fault_schedule_deterministic_across_replays():
    def one_run():
        plan = FaultPlan(seed=7, exec_rate=0.2, load_rate=0.15, wedge_rate=0.1)
        sched, lib, rm, led = _mk_sched(
            retry=RetryPolicy(backoff_s=0.25, max_backoff_s=4.0), faults=plan,
        )
        r8, r16 = _mk_role(lib, 8), _mk_role(lib, 16)
        qa = sched.add_queue(Queue(None, 64, name="A"))
        qb = sched.add_queue(Queue(None, 64, name="B"))
        for i in range(6):
            qa.dispatch((r8 if i % 2 else r16).key,
                        *((_x(8), _x(8)) if i % 2 else (_x(16), _x(16))))
            qb.dispatch(r8.key, _x(8), _x(8))
        sched.run_until_idle()
        return (
            [(e.t, e.brief()) for e in sched.event_log()],
            [(ev.kind, ev.what, ev.permanent) for ev in plan.trace],
        )

    runs = [one_run() for _ in range(3)]
    assert runs[1] == runs[0] and runs[2] == runs[0]
    assert len(runs[0][1]) > 0, "seed injected no faults: test is vacuous"


# ---------------------------------------------------------------------------
# engine: fault-parked requests resume with bitwise-identical streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


def _hsa_engine(model, params, *, faults=None, sched_retry=None,
                eng_retry=None, fusion=1, temperature=0.0, chunk=None,
                slots=4):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(4, ledger=led)
    sched = Scheduler(rm, lib, ledger=led, clock=VirtualClock(),
                      retry=sched_retry, faults=faults)
    q = sched.add_queue(Queue(None, 256, name="serve"))
    eng = ServeEngine(model, params, batch_slots=slots, max_len=32,
                      paged=True, page_size=8, decode_fusion=fusion,
                      temperature=temperature, seed=0, hsa_queue=q,
                      hsa_scheduler=sched, prefill_chunk=chunk,
                      retry=eng_retry)
    return eng, sched, led


_REQS = [([1, 2, 3], 6), ([4, 5], 5), ([7, 8, 9, 10], 4)]


def _run(eng, reqs=_REQS):
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=10_000), key=lambda r: r.uid)
    return [r.generated for r in done], done


@pytest.mark.parametrize("temperature,fusion", [(0.0, 1), (0.7, 2)])
def test_decode_fault_recovery_bitwise_identical(engine_model, temperature,
                                                 fusion):
    """A decode launch that dies to a fault parks every live request and
    resumes them by re-prefill replay — completed token streams must match
    the fault-free run bit for bit (greedy and seeded temperature)."""
    _, model, params = engine_model
    eng0, _, _ = _hsa_engine(model, params, temperature=temperature,
                             fusion=fusion)
    base, _ = _run(eng0)

    plan = FaultPlan()
    plan.force("exec", "decode_fused")             # one transient decode fault
    eng, sched, led = _hsa_engine(model, params, temperature=temperature,
                                  fusion=fusion, faults=plan,
                                  eng_retry=RetryPolicy())
    streams, done = _run(eng)
    assert streams == base
    assert len(done) == len(_REQS) and all(r.done for r in done)
    assert any(r.fault_recoveries > 0 for r in done)
    assert eng.allocator.free_pages == eng.allocator.total_pages
    avail = led.availability_split()
    assert avail["faults"] >= 1 and avail["recoveries"] >= 1
    assert avail["failed_requests"] == 0
    assert led.stat(ledger_mod.RECOVER).count >= 1
    assert avail["recovery_recompute_tokens"] > 0  # replay priced, not hidden


def test_scheduler_retry_absorbs_fault_below_engine(engine_model):
    """With a scheduler RetryPolicy the transient fault never reaches the
    engine at all: no parks, no replay, identical streams."""
    _, model, params = engine_model
    eng0, _, _ = _hsa_engine(model, params)
    base, _ = _run(eng0)
    plan = FaultPlan()
    plan.force("exec", "decode_fused")
    eng, sched, led = _hsa_engine(
        model, params, faults=plan,
        sched_retry=RetryPolicy(backoff_s=1e-4, max_backoff_s=1e-2),
    )
    streams, done = _run(eng)
    assert streams == base
    assert eng.preemptions == 0                    # absorbed before the engine
    avail = led.availability_split()
    assert avail["faults"] == 1 and avail["retries"] == 1


def test_prefill_fault_requeues_request(engine_model):
    _, model, params = engine_model
    eng0, _, _ = _hsa_engine(model, params)
    base, _ = _run(eng0)
    plan = FaultPlan()
    plan.force("exec", "prefill", count=1)
    eng, sched, led = _hsa_engine(model, params, faults=plan,
                                  eng_retry=RetryPolicy())
    streams, done = _run(eng)
    assert streams == base
    assert done[0].fault_recoveries == 1           # first prefill was the hit


def test_chunked_prefill_fault_aborts_to_queue(engine_model):
    _, model, params = engine_model
    eng0, _, _ = _hsa_engine(model, params, chunk=2)
    base, _ = _run(eng0)
    plan = FaultPlan()
    plan.force("exec", "prefill_chunk", count=1)
    eng, sched, led = _hsa_engine(model, params, chunk=2, faults=plan,
                                  eng_retry=RetryPolicy())
    streams, done = _run(eng)
    assert streams == base
    assert any(r.fault_recoveries > 0 for r in done)
    assert eng.allocator.free_pages == eng.allocator.total_pages


# ---------------------------------------------------------------------------
# ServeTruncated: fault-killed requests are classified `failed`
# ---------------------------------------------------------------------------


def test_fault_killed_request_classified_failed_not_retried(engine_model):
    """A request whose recovery budget is spent is permanently failed: it
    lands in ``ServeTruncated.failed`` (distinct from pending/parked/
    rejected), carries its fatal error, and ``run_to_completion`` raises as
    soon as live work drains instead of looping retries.  The forced fault
    is single-shot, so a forbidden retry would *succeed* and turn the raise
    into a normal return — the raise itself proves no retry happened."""
    _, model, params = engine_model
    plan = FaultPlan()
    plan.force("exec", "decode_fused", permanent=True)
    eng, sched, led = _hsa_engine(
        model, params, slots=1, faults=plan,
        eng_retry=RetryPolicy(max_request_recoveries=0),
    )
    eng.submit([1, 2, 3], max_new_tokens=6)        # dies to the forced fault
    eng.submit([4, 5], max_new_tokens=4)           # must still complete
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion(max_steps=10_000)
    err = ei.value
    assert [r.uid for r in err.failed] == [1]
    assert isinstance(err.failed[0].failed, FaultError)
    assert not err.failed[0].done
    assert err.pending == [] and err.parked == [] and err.rejected == []
    assert [r.uid for r in err.done] == [2]        # serving continued
    assert len(err.done[0].generated) == 4
    assert eng.failed_requests[0].uid == 1
    assert eng.allocator.free_pages == eng.allocator.total_pages
    assert led.availability_split()["failed_requests"] == 1


def test_fault_recovery_budget_then_failed(engine_model):
    """Each fault-park consumes budget; one past ``max_request_recoveries``
    fails the request instead of parking it again."""
    _, model, params = engine_model
    plan = FaultPlan()
    plan.force("exec", "decode_fused", permanent=True, count=2)
    eng, sched, led = _hsa_engine(
        model, params, slots=1, faults=plan,
        eng_retry=RetryPolicy(max_request_recoveries=1),
    )
    eng.submit([1, 2, 3], max_new_tokens=6)
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion(max_steps=10_000)
    req = ei.value.failed[0]
    assert req.fault_recoveries == 2               # one park + one fatal
    avail = led.availability_split()
    assert avail["failed_requests"] == 1


# ---------------------------------------------------------------------------
# seeded fault soak (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fault_soak_10k_steps(engine_model):
    """10k-step-bounded soak under a seeded FaultPlan with live traffic and
    a foreign role-dispatching tenant (so load faults fire too): every
    request completes and every stream is bitwise-identical to the
    fault-free run."""
    _, model, params = engine_model
    rng = np.random.default_rng(20260808)
    reqs = []
    for _ in range(80):
        p = [int(t) for t in rng.integers(1, 100, size=int(rng.integers(1, 8)))]
        reqs.append((p, int(rng.integers(2, 10))))

    def run(plan):
        eng, sched, led = _hsa_engine(
            model, params, fusion=2, faults=plan,
            sched_retry=RetryPolicy(backoff_s=1e-4, max_backoff_s=1e-2,
                                    quarantine_after=0),
            eng_retry=RetryPolicy(max_request_recoveries=5),
        )
        tenant = sched.add_queue(Queue(None, 256, name="tenant"))
        role = _mk_role(sched.library, 8, "tenant-role")
        done, i = [], 0
        for step in range(10_000):
            if i < len(reqs) and rng.random() < 0.5:
                p, m = reqs[i]
                eng.submit(p, max_new_tokens=m)
                i += 1
            if step % 7 == 0:
                tenant.dispatch(role.key, _x(8), _x(8))
            done += eng.step()
            if i >= len(reqs) and not (eng._active or eng._queue
                                       or eng._prefilling
                                       or eng.parked_requests):
                break
        while i < len(reqs):
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        done += eng.run_to_completion(max_steps=100_000)
        streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
        return streams, led

    # note: rng drives the submit schedule; reseed so both runs see the
    # same arrivals
    base, _ = run(None)
    rng = np.random.default_rng(20260808)
    rng.integers(1, 100, size=0)                   # keep construction aligned
    for _ in range(80):
        rng.integers(1, 100, size=int(rng.integers(1, 8)))
        rng.integers(2, 10)
    plan = FaultPlan(seed=3, exec_rate=0.02, load_rate=0.05, wedge_rate=0.01)
    faulty, led = run(plan)
    assert faulty == base
    assert len(faulty) == len(reqs)
    avail = led.availability_split()
    assert avail["faults"] > 0, "seed injected no faults: soak is vacuous"
    assert avail["failed_requests"] == 0


# ---------------------------------------------------------------------------
# transfer faults: the tiered pool's DMA failure mode
# ---------------------------------------------------------------------------


def test_fault_plan_transfer_validation_and_force():
    from repro.core.hsa.faults import InjectedTransferFault

    with pytest.raises(ValueError, match="transfer_rate"):
        FaultPlan(transfer_rate=-0.1)
    plan = FaultPlan()
    with pytest.raises(ValueError, match="d2h|h2d"):
        plan.draw_transfer("sideways", "kv[uid=1]")
    plan.force("d2h", "uid=7")
    assert plan.draw_transfer("h2d", "kv[uid=7]") is None   # wrong direction
    err = plan.draw_transfer("d2h", "kv[uid=7]")
    assert isinstance(err, InjectedTransferFault)
    assert plan.draw_transfer("d2h", "kv[uid=7]") is None   # forced: consumed
    assert plan.trace[-1].kind == "d2h" and plan.trace[-1].forced


def test_fault_plan_transfer_rate_deterministic():
    a = FaultPlan(seed=5, transfer_rate=0.5)
    b = FaultPlan(seed=5, transfer_rate=0.5)
    seq_a = [a.draw_transfer("h2d", "x") is not None for _ in range(32)]
    seq_b = [b.draw_transfer("h2d", "x") is not None for _ in range(32)]
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    always = FaultPlan(transfer_rate=1.0)
    assert all(always.draw_transfer("d2h", "x") is not None
               for _ in range(4))


def _park_resume_run(model, params, plan):
    """Deterministic snapshot park: 3 decode steps, preempt uid 0, drain."""
    from repro.core.policy import PreemptionPolicy

    eng = ServeEngine(
        model, params, batch_slots=2, max_len=32, paged=True, page_size=8,
        pool_pages=8,
        preemption=PreemptionPolicy(snapshot_threshold_tokens=1),
        ledger=OverheadLedger(), faults=plan,
    )
    victim = eng.submit([1, 2, 3], max_new_tokens=8)
    eng.submit([4, 5], max_new_tokens=8)
    done = []
    for _ in range(3):
        done += eng.step()
    eng.preempt(victim)                   # past threshold: snapshot-mode park
    done += eng.run_to_completion(max_steps=10_000)
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    return streams, eng


@pytest.mark.parametrize("kind,expect_spills", [("d2h", 0), ("h2d", 1)])
def test_transfer_fault_falls_back_to_replay(engine_model, kind,
                                             expect_spills):
    """A faulted D2H spill parks its victim by re-prefill replay instead of
    snapshot; a faulted H2D refill demotes the parked snapshot to replay —
    either way the stream is bitwise-identical and the arena stays clean."""
    _, model, params = engine_model
    base, eng0 = _park_resume_run(model, params, None)
    assert eng0.spills == 1 and eng0.demotions == 0

    plan = FaultPlan()
    plan.force(kind)
    streams, eng = _park_resume_run(model, params, plan)
    assert streams == base
    assert eng.transfer_faults == 1
    assert eng.demotions == 1             # fault degraded resume to replay
    assert eng.spills == expect_spills
    assert len(plan.trace) == 1 and plan.trace[0].kind == kind
    assert eng.allocator.free_pages == eng.allocator.total_pages
    assert not eng.arena.entries()
    split = eng.ledger.spill_split()
    assert split["transfer_faults"] == 1
    assert split["replay_fallback_tokens"] > 0
