"""PR 10: prefix sharing via refcounted pages + scrubber fairness fixes.

Four concerns, one file:

1.  **Refcounted allocator** — `share` / `free`-to-zero ordering, hard
    errors on misuse, quarantine-of-a-shared-page preconditions, and a
    seeded churn leak check with the tiling + refcount-conservation
    invariants asserted every step.
2.  **Prefix keys and index** — the rolling page-granular digest chain
    (key equality <=> token-history equality) and the first-wins,
    no-references-held `PrefixIndex`.
3.  **Engine semantics** — shared-prefix streams bitwise-identical to
    no-sharing runs (greedy + seeded temperature) across decode_fusion x
    prefill_chunk x preemption x spill x 5% corruption with zero escapes;
    admission charging only unshared pages; parked snapshots excluding
    shared pages; quarantine of a shared page parking *every* reader;
    resume re-attach and the CoW demotion when a prefix evaporates.
4.  **Scrubber regressions** — the three PR 10 bugfixes: arena-scan
    starvation, device-cursor drift under stamp/release churn, and
    stamped-only coverage accounting; plus a seeded fairness property.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (populates GLOBAL_REGISTRY)
from repro.configs import ARCHS, reduced
from repro.core.hsa import FaultPlan, VirtualClock
from repro.core.ledger import OverheadLedger
from repro.core.policy import (
    AdmissionPolicy,
    IntegrityPolicy,
    PreemptionPolicy,
    PrefixPolicy,
    RetryPolicy,
)
from repro.models import build_model
from repro.models.params import init_params
from repro.serve import paged as paged_mod
from repro.serve.engine import RESUME_REPREFILL, RESUME_SNAPSHOT, ServeEngine
from repro.serve.paged import (
    PageAllocator,
    PrefixIndex,
    flip_page,
    pages_for,
    prefix_page_keys,
)


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


# ---------------------------------------------------------------------------
# refcounted PageAllocator
# ---------------------------------------------------------------------------


def test_allocator_share_and_free_to_zero_ordering():
    a = PageAllocator(8)
    p = a.allocate(1, 3)
    a.share(p[0], 2)
    a.share(p[0], 3)
    assert a.refcount(p[0]) == 3
    assert a.owners_of(p[0]) == {1, 2, 3}
    assert a.shared_pages == 1
    assert a.stats().shares == 2
    # owner 1 lets go of everything: only the unshared pages release
    rel = a.free(1, p)
    assert set(rel) == set(p[1:])
    assert a.refcount(p[0]) == 2
    a.check_invariants()
    # intermediate reader: still no release
    assert a.free(2, [p[0]]) == []
    assert a.refcount(p[0]) == 1
    # last reader out returns the page to the free list
    assert a.free(3, [p[0]]) == [p[0]]
    assert a.refcount(p[0]) == 0
    assert a.free_pages == a.total_pages
    a.check_invariants()


def test_allocator_share_misuse_is_hard_error():
    a = PageAllocator(8)
    p = a.allocate(1, 1)[0]
    with pytest.raises(ValueError, match="already holds"):
        a.share(p, 1)                            # double-share by holder
    a.share(p, 2)
    with pytest.raises(ValueError, match="already holds"):
        a.share(p, 2)                            # double-share by reader
    with pytest.raises(ValueError, match="scratch"):
        a.share(paged_mod.TRASH_PAGE, 3)
    free_page = a.allocate(9, 1)[0]
    a.free(9, [free_page])
    with pytest.raises(ValueError, match="free"):
        a.share(free_page, 3)
    a.quarantine(free_page)
    with pytest.raises(ValueError, match="quarantined"):
        a.share(free_page, 3)
    with pytest.raises(ValueError, match="belongs to"):
        a.free(3, [p])                           # foreign free
    a.check_invariants()


def test_allocator_quarantine_shared_page_needs_every_reader_gone():
    a = PageAllocator(8)
    p = a.allocate(1, 1)[0]
    a.share(p, 2)
    with pytest.raises(ValueError, match="release every reader"):
        a.quarantine(p)
    a.free(1, [p])
    with pytest.raises(ValueError, match="release every reader"):
        a.quarantine(p)                          # one reader still holds it
    a.free(2, [p])
    a.quarantine(p)
    assert p not in a.allocate(3, a.free_pages)  # never re-issued
    a.check_invariants()


def test_allocator_refcount_churn_leak_check():
    rng = np.random.default_rng(7)
    a = PageAllocator(32)
    held: dict[int, list[int]] = {}              # uid -> pages it holds
    uid = 0
    for _ in range(500):
        r = rng.random()
        if r < 0.4 and a.free_pages:
            uid += 1
            held[uid] = a.allocate(
                uid, min(a.free_pages, int(rng.integers(1, 4)))
            )
        elif r < 0.7 and len(held) >= 2:
            src, dst = rng.choice(list(held), size=2, replace=False)
            src, dst = int(src), int(dst)
            cands = [p for p in held[src] if dst not in a.owners_of(p)]
            if cands:
                p = int(rng.choice(cands))
                a.share(p, dst)
                held[dst].append(p)
        elif held:
            victim = int(rng.choice(list(held)))
            a.free(victim, held.pop(victim))
        a.check_invariants()
    for owner, pages in held.items():
        a.free(owner, pages)
    a.check_invariants()
    assert a.free_pages == a.total_pages         # no leaked references


# ---------------------------------------------------------------------------
# prefix keys + index
# ---------------------------------------------------------------------------


def test_prefix_page_keys_chain_commits_to_history():
    ps = 4
    a = list(range(12))
    keys = prefix_page_keys(a, ps)
    assert len(keys) == 3                        # full pages only
    assert prefix_page_keys(a + [99], ps) == keys            # partial page
    assert prefix_page_keys(a, ps, max_pages=2) == keys[:2]
    # same page-0 tokens, diverging page 1: chain splits from page 1 on
    b = a[:4] + [77] + a[5:]
    kb = prefix_page_keys(b, ps)
    assert kb[0] == keys[0]
    assert kb[1] != keys[1] and kb[2] != keys[2]
    # the chain commits to *order* across page boundaries
    c = a[4:8] + a[:4] + a[8:]
    assert prefix_page_keys(c, ps)[1] != keys[1]
    assert prefix_page_keys([], ps) == []


def test_prefix_index_first_wins_drop_and_recycle():
    idx = PrefixIndex()
    k1, k2 = prefix_page_keys(list(range(8)), 4)
    assert idx.publish(k1, 5)
    assert not idx.publish(k1, 6)                # first-wins
    assert idx.get(k1) == 5 and len(idx) == 1
    idx.drop_page(5)
    assert idx.get(k1) is None and len(idx) == 0
    idx.drop_page(5)                             # idempotent
    # a recycled page now holding a different prefix evicts its old key
    assert idx.publish(k1, 7)
    assert idx.publish(k2, 7)
    assert idx.get(k1) is None and idx.get(k2) == 7
    assert idx.pages() == {7}


def test_prefix_policy_validation_and_of():
    assert PrefixPolicy.of(None) is None
    assert PrefixPolicy.of(False) is None
    pol = PrefixPolicy.of(True)
    assert pol == PrefixPolicy()
    assert PrefixPolicy.of(pol) is pol
    with pytest.raises(ValueError, match="min_prefix_pages"):
        PrefixPolicy(min_prefix_pages=0)
    with pytest.raises(ValueError, match="max_refs"):
        PrefixPolicy(max_refs=1)
    with pytest.raises(TypeError):
        PrefixPolicy.of(3)


def test_prefix_requires_paged(engine_model):
    cfg, model, params = engine_model
    with pytest.raises(ValueError, match="requires paged"):
        ServeEngine(model, params, batch_slots=2, max_len=32, prefix=True)


def test_prefix_split_empty_ledger_all_zero():
    sp = OverheadLedger().prefix_split()
    assert sp["hit_rate"] == 0.0                 # no lookups: no division
    assert all(v == 0.0 for v in sp.values())


# ---------------------------------------------------------------------------
# engine: bitwise identity + sharing semantics
# ---------------------------------------------------------------------------

_PS = 4  # engine page size everywhere below


def _shared_requests(rng, n, personas=2):
    """Requests drawn over ``personas`` shared 2-page system prompts plus a
    private suffix — the few-personas x many-users traffic shape."""
    prefixes = [
        [int(t) for t in rng.integers(1, 100, size=2 * _PS + 1)]
        for _ in range(personas)
    ]
    out = []
    for _ in range(n):
        pre = prefixes[int(rng.integers(0, personas))]
        suf = [int(t) for t in rng.integers(1, 100,
                                            size=int(rng.integers(1, 6)))]
        out.append((pre + suf, int(rng.integers(2, 10))))
    return out


def _dense_reference(model, params, reqs, *, temperature=0.0):
    eng = ServeEngine(model, params, batch_slots=len(reqs), max_len=32,
                      temperature=temperature, seed=0)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=100_000),
                  key=lambda r: r.uid)
    return [r.generated for r in done]


def _prefix_engine(model, params, *, prefix=True, faults=None, integrity=None,
                   temperature=0.0, fusion=1, chunk=None, spill=False,
                   pool_pages=48, slots=4, recoveries=64):
    kw = {}
    if chunk is not None:
        kw["prefill_chunk"] = chunk
    return ServeEngine(
        model, params, batch_slots=slots, max_len=32, paged=True,
        page_size=_PS, pool_pages=pool_pages, decode_fusion=fusion,
        temperature=temperature, seed=0, prefix=prefix,
        ledger=OverheadLedger(),
        retry=RetryPolicy(max_request_recoveries=recoveries),
        clock=VirtualClock(), step_time_model=lambda p, d: 1e-3,
        transfer_bandwidth_bytes_s=64e6,
        admission=AdmissionPolicy(growth_reserve=0.5),
        preemption=PreemptionPolicy(
            snapshot_threshold_tokens=2 if spill else 10**9
        ),
        host_budget_bytes=(1 << 20) if spill else None,
        faults=faults, integrity=integrity, **kw,
    )


def _churn(model, params, *, steps, n_requests, seed, preempt_p=0.2,
           resume_p=0.2, submit_p=0.6, **ekw):
    rng = np.random.default_rng(seed)
    reqs = _shared_requests(rng, n_requests)
    eng = _prefix_engine(model, params, **ekw)
    done, i = [], 0
    for _ in range(steps):
        if i < len(reqs) and rng.random() < submit_p:
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        if eng._active and rng.random() < preempt_p:
            uid = int(rng.choice([r.uid for r in eng._active.values()]))
            eng.preempt(uid)
        if eng.parked_requests and rng.random() < resume_p:
            uid = int(rng.choice([r.uid for r in eng.parked_requests]))
            eng.resume(uid)
        done += eng.step()
        eng.allocator.check_invariants()
        eng.arena.check_invariants()
    while i < len(reqs):
        p, m = reqs[i]
        eng.submit(p, max_new_tokens=m)
        i += 1
    done += eng.run_to_completion(max_steps=100_000)
    eng.allocator.check_invariants()
    eng.arena.check_invariants()
    streams = [r.generated for r in sorted(done, key=lambda r: r.uid)]
    assert len(streams) == len(reqs)
    return streams, reqs, eng


@pytest.mark.parametrize("fusion,chunk,spill,temperature", [
    (1, None, False, 0.0),       # greedy, plain prefill, device-only
    (4, None, True, 0.0),        # fused decode, spill tier live
    (1, 4, True, 0.0),           # chunked prefill + spill
    (4, 4, True, 0.7),           # everything on, seeded temperature
])
def test_prefix_churn_streams_identical_under_corruption(
        engine_model, fusion, chunk, spill, temperature):
    cfg, model, params = engine_model
    plan = FaultPlan(seed=29, corrupt_rate=0.05)
    streams, reqs, eng = _churn(
        model, params, steps=60, n_requests=10, seed=21, faults=plan,
        integrity=IntegrityPolicy(scrub_pages_per_step=2),
        fusion=fusion, chunk=chunk, spill=spill, temperature=temperature,
    )
    ref = _dense_reference(model, params, reqs, temperature=temperature)
    assert streams == ref                        # bitwise, per request
    sp = eng.ledger.integrity_split()
    assert sp["escaped"] == 0
    assert sp["detected"] <= sp["corruptions"]


@pytest.mark.parametrize("chunk", [None, 2])
def test_prefix_sharing_saves_pages_and_ledger_agrees(engine_model, chunk):
    cfg, model, params = engine_model
    rng = np.random.default_rng(3)
    reqs = _shared_requests(rng, 8, personas=1)
    eng = _prefix_engine(model, params, chunk=chunk)
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    done = sorted(eng.run_to_completion(max_steps=100_000),
                  key=lambda r: r.uid)
    assert [r.generated for r in done] == _dense_reference(model, params, reqs)
    assert eng.prefix_hits > 0
    assert eng.prefix_pages_saved >= 2 * eng.prefix_hits  # 2-page persona
    sp = eng.ledger.prefix_split()
    assert sp["prefix_lookups"] == eng.prefix_lookups
    assert sp["prefix_hits"] == eng.prefix_hits
    assert sp["pages_saved"] == eng.prefix_pages_saved
    assert sp["peak_shared_pages"] >= 2
    assert sp["hit_rate"] == eng.prefix_hits / eng.prefix_lookups
    assert sp["shared_pages"] == 0.0             # all released at drain
    eng.allocator.check_invariants()


def test_admission_charges_only_unshared_pages(engine_model):
    """Pool too small for two private copies of a long prompt, big enough
    for one copy + a shared attach: without sharing the second request
    must wait for the first to finish; with sharing they run together."""
    cfg, model, params = engine_model
    prompt = list(range(1, 17))                  # 4 pages at ps=4
    reqs = [(prompt, 6), (prompt, 6)]

    def overlap(prefix):
        eng = _prefix_engine(model, params, prefix=prefix, pool_pages=9,
                             slots=2)
        for p, m in reqs:
            eng.submit(p, max_new_tokens=m)
        both, steps = 0, 0
        while (eng._queue or eng._active or eng._prefilling
               or eng._parked):
            eng.step()
            eng.allocator.check_invariants()
            both = max(both, len(eng._active))
            steps += 1
            assert steps < 10_000
        return both

    assert overlap(prefix=False) == 1
    assert overlap(prefix=True) == 2


def test_quarantine_of_shared_page_parks_every_reader(engine_model):
    cfg, model, params = engine_model
    prompt = list(range(1, 14))                  # 3 full pages + partial
    eng = _prefix_engine(model, params,
                         integrity=IntegrityPolicy(scrub_pages_per_step=8))
    done = []
    for _ in range(3):
        eng.submit(prompt, max_new_tokens=12)
    for _ in range(2):                           # all three prefilled + shared
        done += eng.step()
    shared = [p for p in range(1, eng.allocator.num_pages)
              if eng.allocator.refcount(p) > 1]
    assert shared
    victim = shared[0]
    readers = eng.allocator.owners_of(victim)
    assert len(readers) == 3                     # one publisher + two sharers
    eng._cache["segments"] = flip_page(eng._cache["segments"], victim)
    done += eng.step()                           # read-verify/scrub detects
    assert eng.corruptions_detected >= 1
    assert eng.pages_quarantined == 1
    assert victim not in eng._prefix_index.pages()
    # no reader still maps the quarantined page — every one was parked
    # through RESUME_REPREFILL (or already resumed onto fresh pages)
    assert all(victim not in eng.allocator.pages_of(u) for u in readers)
    assert eng.cow_copies == len(readers) - 1    # extra readers = CoW cost
    done += eng.run_to_completion(max_steps=100_000)
    done.sort(key=lambda r: r.uid)
    assert all(r.fault_recoveries >= 1 for r in done)  # every reader re-ran
    ref = _dense_reference(model, params, [(prompt, 12)] * 3)
    assert [r.generated for r in done] == ref    # recovery is invisible
    assert eng.ledger.integrity_split()["escaped"] == 0
    eng.allocator.check_invariants()


def test_parked_snapshot_excludes_shared_pages(engine_model):
    cfg, model, params = engine_model
    prompt = list(range(1, 14))                  # 3 full pages shared cap
    eng = _prefix_engine(model, params, spill=True)
    done = []
    for _ in range(2):
        eng.submit(prompt, max_new_tokens=10)
    for _ in range(3):
        done += eng.step()
    slot, req = next(
        (s, r) for s, r in eng._active.items() if eng._slot_shared[s] > 0
    )
    shared = int(eng._slot_shared[slot])
    assert shared == (len(prompt) - 1) // _PS
    pos = int(eng._pos[slot])
    eng.preempt(req.uid)
    entry = next(e for e in eng._parked if e.req.uid == req.uid)
    assert entry.mode == RESUME_SNAPSHOT
    assert entry.shared_pages == shared
    keep = pages_for(pos, _PS)
    # the arena holds only the private tail: (keep - shared) pages of bytes
    assert eng.arena.bytes_of(req.uid) == (
        (keep - shared) * _PS * eng._token_bytes
    )
    # the shared pages stayed resident under the publisher's refs
    assert all(eng.allocator.refcount(p) >= 1
               for p in eng._prefix_index.pages())
    steps = 0
    while any(e.req.uid == req.uid for e in eng._parked):
        done += eng.step()
        steps += 1
        assert steps < 1000
    assert eng.cow_copies == 0                   # prefix was still resident
    done += eng.run_to_completion(max_steps=100_000)
    done.sort(key=lambda r: r.uid)
    ref = _dense_reference(model, params, [(prompt, 10)] * 2)
    assert [r.generated for r in done] == ref
    eng.allocator.check_invariants()


def test_resume_with_evaporated_prefix_demotes_to_replay(engine_model):
    """Park a sharer as a snapshot (prefix pages excluded), then release
    every other reader so the shared pages — and their index entries —
    evaporate.  The sharer's resume cannot re-attach what its snapshot
    never held: it must demote to replay (the CoW moment), and the stream
    must still come out bitwise-identical."""
    cfg, model, params = engine_model
    prompt = list(range(1, 14))
    reqs = [(prompt, 8), (prompt, 8)]
    eng = _prefix_engine(model, params, spill=True)
    done = []
    for p, m in reqs:
        eng.submit(p, max_new_tokens=m)
    for _ in range(2):
        done += eng.step()
    slot, sharer = next(
        (s, r) for s, r in eng._active.items() if eng._slot_shared[s] > 0
    )
    eng.preempt(sharer.uid)                      # snapshot excludes prefix
    entry = next(e for e in eng._parked if e.req.uid == sharer.uid)
    assert entry.mode == RESUME_SNAPSHOT and entry.shared_pages > 0
    # park the publisher too: its release drops the last reference on the
    # shared pages, and with them the index entries
    publisher = next(iter(eng._active.values()))
    eng.preempt(publisher.uid)
    assert len(eng._prefix_index) == 0           # the prefix evaporated
    ok = eng._try_resume(entry, slot)            # sharer first, directly
    assert ok
    assert entry.mode == RESUME_REPREFILL        # demoted, not restored
    assert eng.demotions == 1
    assert eng.cow_copies == 1                   # the CoW moment, counted
    assert eng.ledger.prefix_split()["cow_copies"] == 1.0
    done += eng.run_to_completion(max_steps=100_000)
    done.sort(key=lambda r: r.uid)
    assert [r.generated for r in done] == _dense_reference(model, params,
                                                           reqs)
    eng.allocator.check_invariants()


# ---------------------------------------------------------------------------
# scrubber regressions (the three PR 10 bugfixes)
# ---------------------------------------------------------------------------


def _arena_only_engine(model, params, *, budget):
    """Spill engine with every request parked as a snapshot: arena entries
    are the only scrub targets (released device pages drop their stamps)."""
    eng = _prefix_engine(
        model, params, prefix=False, spill=True,
        integrity=IntegrityPolicy(scrub_pages_per_step=budget,
                                  verify_reads=False),
        slots=5, pool_pages=64,
    )
    for i in range(5):
        eng.submit([1 + i, 2, 3, 4, 5], max_new_tokens=8)
    for _ in range(2):
        eng.step()
    for uid in [r.uid for r in eng._active.values()]:
        eng.preempt(uid)
    assert not eng._page_digests                 # device stamps all dropped
    stamped = [u for u in eng.arena.entries()
               if eng.arena.digest_of(u) is not None]
    assert len(stamped) == 5
    return eng, stamped


def test_scrub_arena_rotation_covers_every_entry(engine_model):
    """Regression (starvation): with budget < entries, the old scan began
    at entries()[0] every step and never reached the tail."""
    cfg, model, params = engine_model
    budget = 2
    eng, stamped = _arena_only_engine(model, params, budget=budget)
    seen: list[int] = []
    real = eng.arena.verify
    eng.arena.verify = lambda uid: (seen.append(uid), real(uid))[1]
    for _ in range(math.ceil(len(stamped) / budget)):
        eng._scrub_step()
    assert set(seen) == set(stamped)             # tail entries audited too
    assert len(seen) == math.ceil(len(stamped) / budget) * budget


def test_scrub_device_cursor_keyed_on_page_id_under_churn(engine_model,
                                                          monkeypatch):
    """Regression (cursor drift): the cursor was an index into the sorted
    stamp list, so stamping a page below it skipped targets and releasing
    one double-scanned.  Keyed on the last-scanned page id, every page
    that stays stamped is re-hashed within ceil(T/budget) steps no matter
    how membership churns around it."""
    cfg, model, params = engine_model
    budget = 2
    eng = _prefix_engine(
        model, params, prefix=False,
        integrity=IntegrityPolicy(scrub_pages_per_step=budget,
                                  verify_reads=False),
    )
    eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
    eng.step()                                   # builds the pool + stamps
    real = paged_mod.page_digest

    def digest(p):                               # stamps bypass the recorder
        return real(eng._cache["segments"], p)

    # survivors: stamped before the rotation and never released during it
    eng._page_digests.clear()
    survivors = [11, 13, 15, 17, 19, 21]
    for p in survivors:
        eng._page_digests[p] = digest(p)
    eng._scrub_cursor = (0, 10)                  # rotation starts at 11
    # (stamp, release) churn applied before each scrub step — always at
    # ids *behind* the cursor, the exact membership shifts that made the
    # old index-based cursor skip ahead or rescan
    schedule = [(1, None), (12, 1), (14, 12)]

    scans: list[int] = []
    monkeypatch.setattr(
        paged_mod, "page_digest",
        lambda segs, p: (scans.append(p), real(segs, p))[1],
    )
    assert math.ceil(len(survivors) / budget) == len(schedule)
    for stamp, release in schedule:
        eng._page_digests[stamp] = digest(stamp)
        if release is not None:
            del eng._page_digests[release]
        eng._scrub_step()
    assert scans == survivors                    # no skip, no double-scan


def test_scrub_targets_count_only_stamped_entries(engine_model):
    """Regression (coverage accounting): unstamped arena entries were
    counted in the denominator the scrub loop never audits."""
    cfg, model, params = engine_model
    eng = _prefix_engine(
        model, params, prefix=False,
        integrity=IntegrityPolicy(scrub_pages_per_step=4,
                                  verify_reads=False),
    )
    if eng.arena.block_bytes is None:
        eng.arena.configure(1 << 12)
    data = {"k": np.arange(16, dtype=np.float32)}
    eng.arena.store(101, data, 64, digest=paged_mod.tree_digest(data))
    eng.arena.store(102, data, 64)               # unstamped: never audited
    eng._scrub_step()
    sp = eng.ledger.integrity_split()
    assert sp["scrub_targets"] == 1.0            # only the stamped entry
    assert sp["scrubbed_blocks"] == 1.0
    assert sp["scrub_coverage"] == 1.0           # honest: audited / auditable


def test_scrub_fairness_under_seeded_churn(engine_model):
    """Property: freeze any churned engine state and ceil(T/budget) scrub
    steps audit every stamped device page *and* arena block exactly once
    per rotation (no skip, no double-scan)."""
    cfg, model, params = engine_model
    budget = 3
    rng = np.random.default_rng(17)
    reqs = _shared_requests(rng, 8)
    eng = _prefix_engine(
        model, params, spill=True,
        integrity=IntegrityPolicy(scrub_pages_per_step=budget,
                                  verify_reads=False),
    )
    i = 0
    for step in range(12):                       # seeded churn, then freeze
        if i < len(reqs) and rng.random() < 0.6:
            p, m = reqs[i]
            eng.submit(p, max_new_tokens=m)
            i += 1
        if eng._active and rng.random() < 0.3:
            eng.preempt(int(rng.choice([r.uid
                                        for r in eng._active.values()])))
        eng.step()
        eng.allocator.check_invariants()
    # park one straggler without stepping: the frozen state must hold
    # stamped targets in *both* tiers for the rotation to interleave
    assert eng._active
    eng.preempt(int(min(r.uid for r in eng._active.values())))
    pages = set(eng._page_digests)
    blocks = {u for u in eng.arena.entries()
              if eng.arena.digest_of(u) is not None}
    assert pages and blocks
    total = len(pages) + len(blocks)
    page_scans: list[int] = []
    block_scans: list[int] = []
    real_pd = paged_mod.page_digest
    real_v = eng.arena.verify
    paged_mod.page_digest = (
        lambda segs, p: (page_scans.append(p), real_pd(segs, p))[1]
    )
    eng.arena.verify = lambda u: (block_scans.append(u), real_v(u))[1]
    try:
        for _ in range(math.ceil(total / budget)):
            eng._scrub_step()
    finally:
        paged_mod.page_digest = real_pd
        eng.arena.verify = real_v
    assert set(page_scans) == pages
    assert set(block_scans) == blocks
    # one full rotation + the wrap remainder: nothing scanned 3+ times
    from collections import Counter
    counts = Counter([("p", p) for p in page_scans]
                     + [("b", b) for b in block_scans])
    assert max(counts.values()) <= 2
