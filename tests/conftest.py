"""Shared test configuration.

Optional-dependency shim: the suite's property tests use `hypothesis` when it
is installed.  On minimal containers without it, a deterministic mini
implementation (seeded RNG, fixed example counts) is registered under the
same module names, so the property tests still *run* — with less adversarial
generation — instead of failing at collection.
"""

from __future__ import annotations


import sys
import types

import numpy as np


class _Unsatisfied(Exception):
    """Raised by stub assume() to discard one generated example."""


def _install_hypothesis_stub() -> None:
    class Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise _Unsatisfied
            return Strategy(draw)

    def integers(min_value=0, max_value=100):
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def booleans():
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(seq):
        pool = list(seq)
        return Strategy(lambda rng: pool[int(rng.integers(0, len(pool)))])

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return Strategy(draw)

    def tuples(*strats):
        return Strategy(lambda rng: tuple(s.example(rng) for s in strats))

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            # NB: no functools.wraps — pytest must see a zero-arg signature,
            # not the wrapped test's drawn parameters (they are not fixtures)
            def wrapper():
                max_examples = getattr(wrapper, "_stub_max_examples", 25)
                rng = np.random.default_rng(0xC0FFEE)
                ran = 0
                for _ in range(max_examples * 4):
                    if ran >= max_examples:
                        break
                    try:
                        extra = [s.example(rng) for s in arg_strats]
                        kw = {k: s.example(rng) for k, s in kw_strats.items()}
                        fn(*extra, **kw)
                        ran += 1
                    except _Unsatisfied:
                        continue
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.is_hypothesis_stub = True
            return wrapper
        return deco

    def settings(max_examples=25, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st = types.ModuleType("hypothesis.strategies")
    for name, fn in (
        ("integers", integers), ("booleans", booleans), ("floats", floats),
        ("sampled_from", sampled_from), ("lists", lists), ("tuples", tuples),
    ):
        setattr(st, name, fn)
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:                      # pragma: no cover - env dependent
    _install_hypothesis_stub()
