"""Unit + property tests for the paper's core: registry, dispatch, regions,
roles, ledger, planner, HSA runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.kernels  # noqa: F401
from repro.core import dispatch, ledger as ledger_mod, policy
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.registry import (
    FIXED_WEIGHT,
    GENERIC,
    GLOBAL_REGISTRY,
    KernelImpl,
    KernelRegistry,
)
from repro.core.roles import ONLINE, PRESYNTHESIZED, Role, RoleLibrary
from repro.core.hsa import (
    Agent,
    Executor,
    Queue,
    QueueFullError,
    Signal,
    hsa_init,
    hsa_shut_down,
    run_packet_sync,
)


# ---------------------------------------------------------------------------
# registry + dispatch
# ---------------------------------------------------------------------------


def test_registry_resolution_prefers_source_order():
    reg = KernelRegistry()
    reg.register(KernelImpl(op="f", device_kind="any", source="reference", fn=lambda x: x))
    reg.register(KernelImpl(op="f", device_kind="tpu", source="pallas", fn=lambda x: x + 1))
    assert reg.resolve("f", "tpu", ("pallas", "reference")).source == "pallas"
    assert reg.resolve("f", "tpu", ("xla", "reference")).source == "reference"
    with pytest.raises(KeyError):
        reg.resolve("f", "tpu", ("xla",))


def test_registry_priority_within_source():
    reg = KernelRegistry()
    reg.register(KernelImpl(op="f", device_kind="any", source="xla", fn=lambda: 1,
                            name="a", priority=0))
    reg.register(KernelImpl(op="f", device_kind="any", source="xla", fn=lambda: 2,
                            name="b", priority=5))
    assert reg.resolve("f", "any", ("xla",)).name == "b"


def test_registry_duplicate_rejected_unless_override():
    reg = KernelRegistry()
    impl = KernelImpl(op="f", device_kind="any", source="xla", fn=lambda: 1, name="a")
    reg.register(impl)
    with pytest.raises(ValueError):
        reg.register(impl)
    reg.register(impl, allow_override=True)


def test_transparent_dispatch_policy_switch():
    """The paper's headline: same call, different backend, same numerics."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)), jnp.float32)
    with dispatch.use(prefer=("reference",)):
        a = dispatch.op("matmul", x, w)
    with dispatch.use(prefer=("xla", "reference")):
        b = dispatch.op("matmul", x, w)
    with dispatch.use(prefer=("pallas", "xla", "reference"), interpret=True):
        c = dispatch.op("matmul", x, w)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_dispatch_trace_records_sequence():
    trace = dispatch.DispatchTrace()
    x = jnp.ones((8, 8))
    with dispatch.use(prefer=("xla", "reference"), trace=trace):
        dispatch.op("matmul", x, x)
        dispatch.op("rmsnorm", x, jnp.ones((8,)))
        dispatch.op("matmul", x, x)
    assert trace.op_counts() == {"matmul": 2, "rmsnorm": 1}


def test_dispatch_context_memoizes_resolution():
    """Hot trace loops resolve each (op, specialization) once per context."""
    calls = []

    class Counting(KernelRegistry):
        def resolve(self, *a, **kw):
            calls.append(1)
            return super().resolve(*a, **kw)

    creg = Counting()
    creg.register(KernelImpl(op="f", device_kind="any", source="xla", fn=lambda x: x))
    with dispatch.use(registry=creg, prefer=("xla",)) as ctx:
        a = ctx.resolve("f")
        b = ctx.resolve("f")
        c = ctx.resolve("f", specialization=None)
    assert a is b is c
    assert len(calls) == 1


def test_dispatch_memo_invalidated_by_late_registration():
    """A registration after the first resolve must not serve a stale impl."""
    reg = KernelRegistry()
    reg.register(KernelImpl(op="f", device_kind="any", source="xla",
                            fn=lambda x: x, name="old", priority=0))
    with dispatch.use(registry=reg, prefer=("xla",)) as ctx:
        assert ctx.resolve("f").name == "old"
        reg.register(KernelImpl(op="f", device_kind="any", source="xla",
                                fn=lambda x: x + 1, name="new", priority=9))
        assert ctx.resolve("f").name == "new"     # version bump busts the memo


def test_registry_version_monotone():
    reg = KernelRegistry()
    v0 = reg.version
    impl = KernelImpl(op="f", device_kind="any", source="xla", fn=lambda: 0)
    reg.register(impl)
    v1 = reg.version
    snap = reg.snapshot()
    reg.clear()
    v2 = reg.version
    reg.restore(snap)
    v3 = reg.version
    assert v0 < v1 < v2 < v3


def test_dispatch_inside_jit_is_trace_time():
    """Resolution happens at trace time: the jitted program is policy-baked."""
    calls = []
    reg = KernelRegistry()

    def noisy(x):
        calls.append(1)
        return x * 2

    reg.register(KernelImpl(op="dbl", device_kind="any", source="xla", fn=noisy))

    @jax.jit
    def f(x):
        with dispatch.use(registry=reg, prefer=("xla",)):
            return dispatch.op("dbl", x)

    f(jnp.ones(4))
    n_after_trace = len(calls)
    f(jnp.ones(4))  # cached: no re-dispatch
    assert len(calls) == n_after_trace == 1


# ---------------------------------------------------------------------------
# roles + regions (partial reconfiguration)
# ---------------------------------------------------------------------------


def _mk_role(lib, n=16, name_suffix="", source=PRESYNTHESIZED):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), source=source, name=f"mm{n}{name_suffix}"))


def test_role_synthesize_then_load_then_unload():
    lib = RoleLibrary(ledger=OverheadLedger())
    r = _mk_role(lib, 16)
    assert not r.resident
    r.synthesize()
    assert r.synthesis_s is not None and not r.resident
    out = r(jnp.ones((16, 16)), jnp.ones((16, 16)))
    assert r.resident and r.load_count == 1
    np.testing.assert_allclose(np.asarray(out)[0, 0], 16.0)
    r.unload()
    assert not r.resident


def test_online_role_synthesizes_lazily():
    lib = RoleLibrary(ledger=OverheadLedger())
    r = _mk_role(lib, 8, source=ONLINE)
    assert r.synthesis_s is None
    r.load()
    assert r.synthesis_s is not None


def test_lru_eviction_order():
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    roles = [_mk_role(lib, n) for n in (8, 16, 32)]
    rm = RegionManager(2, ledger=led)
    rm.ensure_resident(roles[0])
    rm.ensure_resident(roles[1])
    assert rm.ensure_resident(roles[0]).hit          # refresh LRU position of 0
    res = rm.ensure_resident(roles[2])               # evicts 1 (least recent)
    assert not res.hit and res.evicted == roles[1].key
    assert rm.is_resident(roles[0].key) and not rm.is_resident(roles[1].key)
    assert not roles[1].resident                      # eviction unloaded it
    assert rm.stats.evictions == 1


def test_pinned_roles_survive_eviction():
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    roles = [_mk_role(lib, n) for n in (8, 16, 32)]
    rm = RegionManager(2, ledger=led)
    rm.pin(roles[0])
    rm.ensure_resident(roles[1])
    rm.ensure_resident(roles[2])                      # must evict 1, not pinned 0
    assert rm.is_resident(roles[0].key)
    with pytest.raises(RuntimeError):
        rm2 = RegionManager(1, ledger=led)
        rm2.pin(roles[0])
        rm2.ensure_resident(roles[1])


def test_reconfig_recorded_in_ledger_only_on_miss():
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    r = _mk_role(lib, 8)
    rm = RegionManager(2, ledger=led)
    rm.ensure_resident(r)
    rm.ensure_resident(r)
    rm.ensure_resident(r)
    assert led.stat(ledger_mod.RECONFIG).count == 1
    assert rm.stats.hits == 2 and rm.stats.misses == 1


@settings(max_examples=50, deadline=None)
@given(
    budget=st.integers(min_value=1, max_value=6),
    seq=st.lists(st.integers(min_value=0, max_value=7), min_size=1, max_size=60),
)
def test_property_lru_never_exceeds_budget_and_hits_iff_resident(budget, seq):
    """Invariant: residency <= budget; a lookup hits iff the key was resident."""
    from collections import OrderedDict

    cost = policy.CostModel(
        reconfig_s=1.0, dispatch_s=0.0,
        exec_generic_s={"op": 0.0}, exec_fixed_s={"op": 0.0},
    )
    roles = [(f"r{i}") for i in seq]
    spec_of = {r: GENERIC for r in roles}
    op_of = {r: "op" for r in roles}
    sim = policy.simulate_lru(roles, budget, cost, spec_of, op_of, repeats=1)

    # independent model
    resident: OrderedDict = OrderedDict()
    hits = misses = 0
    for r in roles:
        if r in resident:
            hits += 1
            resident.move_to_end(r)
        else:
            misses += 1
            if len(resident) >= budget:
                resident.popitem(last=False)
            resident[r] = None
        assert len(resident) <= budget
    assert sim.hits == hits and sim.misses == misses
    assert sim.total_s == pytest.approx(misses * 1.0)


# ---------------------------------------------------------------------------
# role planner (paper §IV trade-off)
# ---------------------------------------------------------------------------


def _cost(reconfig_ms=5.0):
    return policy.CostModel(
        reconfig_s=reconfig_ms * 1e-3,
        dispatch_s=10e-6,
        exec_generic_s={"fc": 100e-6},
        exec_fixed_s={"fc": 50e-6},
    )


def test_planner_prefers_generic_under_tight_budget():
    trace = [policy.Invocation("fc", i) for i in range(16)]
    plan = policy.plan_roles(trace, budget=2, cost=_cost())
    assert plan.assignment["fc"] == GENERIC
    assert plan.predicted.hit_rate == 1.0


def test_planner_prefers_fixed_weight_with_ample_regions():
    trace = [policy.Invocation("fc", i) for i in range(16)]
    plan = policy.plan_roles(trace, budget=32, cost=_cost())
    assert plan.assignment["fc"] == FIXED_WEIGHT


def test_planner_breakeven_moves_with_reconfig_cost():
    """Cheap reconfig -> specialization wins even when thrashing."""
    trace = [policy.Invocation("fc", i) for i in range(16)]
    plan_cheap = policy.plan_roles(trace, budget=2, cost=_cost(reconfig_ms=0.001))
    assert plan_cheap.assignment["fc"] == FIXED_WEIGHT


@settings(max_examples=25, deadline=None)
@given(
    budget=st.integers(min_value=1, max_value=40),
    n_layers=st.integers(min_value=1, max_value=24),
)
def test_property_planner_never_worse_than_all_generic(budget, n_layers):
    trace = [policy.Invocation("fc", i) for i in range(n_layers)]
    cost = _cost()
    plan = policy.plan_roles(trace, budget=budget, cost=cost)
    all_generic = policy.simulate_lru(
        policy.role_sequence(trace, {"fc": GENERIC}), budget, cost,
        {("fc", GENERIC): GENERIC}, {("fc", GENERIC): "fc"},
    )
    assert plan.predicted.total_s <= all_generic.total_s + 1e-12


# ---------------------------------------------------------------------------
# HSA runtime
# ---------------------------------------------------------------------------


def test_signal_semantics():
    s = Signal(2)
    assert s.load() == 2
    s.decrement()
    assert not s.wait_eq(0, timeout=0.01)
    s.decrement()
    assert s.wait_eq(0, timeout=0.1)


def test_queue_ring_and_overflow():
    agent = Agent.discover()[0]
    q = Queue(agent, size=2)
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    r = _mk_role(lib, 8)
    q.dispatch(r.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    q.dispatch(r.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    with pytest.raises(QueueFullError):
        q.dispatch(r.key, jnp.ones((8, 8)), jnp.ones((8, 8)))
    assert q.pending() == 2


def test_hsa_end_to_end_dispatch_and_barrier():
    hsa_shut_down()
    sys_ = hsa_init(num_regions=2, ledger=OverheadLedger())
    try:
        lib = sys_.library
        r = _mk_role(lib, 16)
        lib.synthesize_all()
        agent = sys_.default_agent
        q, ex = sys_.queue_of(agent), sys_.executor_of(agent)
        x = jnp.ones((16, 16))
        p1 = q.dispatch(r.key, x, x, producer="tf")
        p2 = q.dispatch(r.key, x, x, producer="opencl")   # multi-producer
        bar = q.barrier([p1.completion, p2.completion])
        ex.drain(q)
        assert bar.completion.wait_eq(0, timeout=1.0)
        np.testing.assert_allclose(np.asarray(p2.out.value)[0, 0], 16.0)
        assert sys_.ledger.stat(ledger_mod.DISPATCH).count == 2
        assert sys_.ledger.stat(ledger_mod.RECONFIG).count == 1   # second was a hit
    finally:
        hsa_shut_down()


def test_hsa_background_executor():
    hsa_shut_down()
    sys_ = hsa_init(num_regions=2, ledger=OverheadLedger())
    try:
        lib = sys_.library
        r = _mk_role(lib, 8)
        agent = sys_.default_agent
        q, ex = sys_.queue_of(agent), sys_.executor_of(agent)
        ex.start(q)
        pkts = [q.dispatch(r.key, jnp.ones((8, 8)), jnp.ones((8, 8))) for _ in range(5)]
        for p in pkts:
            assert p.completion.wait_eq(0, timeout=5.0)
            np.testing.assert_allclose(np.asarray(p.out.value)[0, 0], 8.0)
    finally:
        hsa_shut_down()


def test_executor_surfaces_kernel_errors():
    hsa_shut_down()
    sys_ = hsa_init(num_regions=2, ledger=OverheadLedger())
    try:
        lib = sys_.library
        r = _mk_role(lib, 8)
        agent = sys_.default_agent
        q, ex = sys_.queue_of(agent), sys_.executor_of(agent)
        pkt = q.dispatch(r.key, jnp.ones((4, 4)), jnp.ones((4, 4)))  # wrong shape
        with pytest.raises(Exception):
            run_packet_sync(ex, q, pkt)
    finally:
        hsa_shut_down()
