"""Fused multi-token decode: bitwise equivalence across fusion depths,
packet-count amortization through the HSA queue, and truncation reporting.

The acceptance bar: ``decode_fusion=K`` must produce token streams
bitwise-identical to K=1 for both greedy and seeded-temperature sampling —
fusion is a pure launch-overhead optimization, never a sampling change.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.policy import FusionPolicy
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, ServeTruncated


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


PROMPTS = [[3, 14, 15, 92], [7, 8], [1, 2, 3, 4, 5, 6], [42]]


def _generate(model, params, *, fusion, temperature=0.0, slots=2,
              max_new=7, seed=0, prompts=PROMPTS):
    eng = ServeEngine(model, params, batch_slots=slots, max_len=32,
                      decode_fusion=fusion, temperature=temperature, seed=seed)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = sorted(eng.run_to_completion(), key=lambda r: r.uid)
    return [r.generated for r in done]


def test_fused_greedy_bitwise_identical_across_depths(engine_model):
    _, model, params = engine_model
    base = _generate(model, params, fusion=1)
    for k in (2, 3, 4, 8):
        assert _generate(model, params, fusion=k) == base, f"fusion={k}"
    assert all(len(g) == 7 for g in base)


def test_fused_temperature_bitwise_identical_across_depths(engine_model):
    """Seeded temperature sampling: the per-request fold_in PRNG stream makes
    the draw independent of fusion depth AND admission timing (slot recycling
    shifts when requests join; with 4 requests over 2 slots the second wave
    admits at different steps under different K)."""
    _, model, params = engine_model
    base = _generate(model, params, fusion=1, temperature=0.7, seed=3)
    for k in (2, 4, 8):
        got = _generate(model, params, fusion=k, temperature=0.7, seed=3)
        assert got == base, f"fusion={k}"
    # different seed, different streams (the knob is live)
    assert _generate(model, params, fusion=4, temperature=0.7, seed=4) != base


def test_fused_decode_amortizes_hsa_packets(engine_model):
    """Routing through the HSA queue: K=4 must spend ~4x fewer decode packets
    (and ~4x less submit+grant+wait overhead) for the same token stream."""
    from repro.core.hsa import Queue, Scheduler, VirtualClock
    from repro.core.ledger import OverheadLedger
    from repro.core.reconfig import RegionManager
    from repro.core.roles import RoleLibrary

    _, model, params = engine_model

    def run(k):
        led = OverheadLedger()
        lib = RoleLibrary(ledger=led)
        sched = Scheduler(RegionManager(2, ledger=led), lib, ledger=led,
                          clock=VirtualClock())
        q = sched.add_queue(Queue(None, 256, name="serve"))
        eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                          decode_fusion=k, hsa_queue=q, hsa_scheduler=sched)
        eng.submit([3, 14, 15, 92], max_new_tokens=9)
        (req,) = eng.run_to_completion()
        return req.generated, sched.queue_report()["serve"]["dispatched"], led

    gen1, pkts1, led1 = run(1)
    gen4, pkts4, led4 = run(4)
    assert gen4 == gen1
    # 8 decode tokens after prefill: 8 decode launches at K=1, 2 at K=4
    # (plus the same prefill/fixup packets in both)
    assert pkts1 - pkts4 == 6
    split1, split4 = led1.dispatch_split(), led4.dispatch_split()
    assert split4["submit_n"] < split1["submit_n"]
    assert split4["wait_n"] < split1["wait_n"]


def test_fusion_policy_drives_engine(engine_model):
    """A FusionPolicy-driven engine serves correctly and matches the static
    greedy stream (policy only changes K, never tokens)."""
    _, model, params = engine_model
    base = _generate(model, params, fusion=1)
    got = _generate(model, params,
                    fusion=FusionPolicy(max_fusion=8, min_fusion=1))
    assert got == base


def test_fused_partial_final_launch_splices_exactly(engine_model):
    """max_new_tokens not divisible by K: the final launch's surplus steps are
    masked and the host splices exactly the remaining budget."""
    _, model, params = engine_model
    for max_new in (1, 2, 5):
        a = _generate(model, params, fusion=1, max_new=max_new,
                      prompts=[[5, 6, 7]], slots=1)
        b = _generate(model, params, fusion=4, max_new=max_new,
                      prompts=[[5, 6, 7]], slots=1)
        assert a == b
        assert len(a[0]) == max_new


def test_run_to_completion_raises_on_truncation(engine_model):
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=1, max_len=32)
    eng.submit([1, 2, 3], max_new_tokens=10)
    eng.submit([4, 5], max_new_tokens=10)
    with pytest.raises(ServeTruncated) as ei:
        eng.run_to_completion(max_steps=2)
    err = ei.value
    assert len(err.done) == 0 and len(err.pending) == 2
    # in-flight generation survives in the report, and serving can resume
    assert len(err.pending[0].generated) >= 1
    done = eng.run_to_completion()
    assert len(done) == 2 and all(len(r.generated) == 10 for r in done)
