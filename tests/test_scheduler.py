"""Deterministic tests for the async multi-queue HSA scheduler.

Everything here runs on the virtual clock: no wall-clock sleeps, no threads,
no flakes.  Durations come from a fixed cost model, so tests assert *exact*
event orders and timestamps; determinism itself is asserted by replaying
identical workloads and comparing full event logs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.kernels  # noqa: F401
from repro.core import ledger as ledger_mod
from repro.core.hsa import (
    Queue,
    Scheduler,
    SchedulerDeadlock,
    Signal,
    VirtualClock,
)
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.registry import GLOBAL_REGISTRY
from repro.core.roles import Role, RoleLibrary

COST = {"reconfig": 10.0, "exec": 1.0}


def _cost_model(kind, what, measured):
    return COST[kind]


def _mk_role(lib, n, name=None):
    impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return lib.add(Role(impl, (a, a), name=name or f"mm{n}"))


def _mk_sched(num_regions=2, policy="round_robin", cost=_cost_model, seed=0):
    led = OverheadLedger()
    lib = RoleLibrary(ledger=led)
    rm = RegionManager(num_regions, ledger=led)
    sched = Scheduler(
        rm, lib, ledger=led, clock=VirtualClock(), cost_model=cost,
        policy=policy, seed=seed,
    )
    return sched, lib, rm, led


def _x(n):
    return jnp.ones((n, n))


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------


def test_virtual_clock_is_monotonic_and_sleep_free():
    clk = VirtualClock()
    assert clk.now() == 0.0
    clk.advance(2.5)
    clk.sleep(1.5)                    # an advance, not a wall wait
    assert clk.now() == 4.0
    clk.advance_to(3.0)               # never goes backwards
    assert clk.now() == 4.0
    with pytest.raises(ValueError):
        clk.advance(-1.0)


# ---------------------------------------------------------------------------
# interleaving semantics
# ---------------------------------------------------------------------------


def test_barrier_serializes_dependents_exact_order():
    sched, lib, rm, led = _mk_sched()
    r8, r16 = _mk_role(lib, 8), _mk_role(lib, 16)
    qa = sched.add_queue(Queue(None, 64, name="A"))

    p1 = qa.dispatch(r8.key, _x(8), _x(8))
    p2 = qa.dispatch(r8.key, _x(8), _x(8))
    bar = qa.barrier([p1.completion, p2.completion])
    p3 = qa.dispatch(r16.key, _x(16), _x(16), deps=[bar.completion])
    sched.run_until_idle()

    briefs = [e.brief() for e in sched.event_log()]
    assert briefs == [
        ("reconfig_start", "A", "mm8"),
        ("reconfig_end", "A", "mm8"),
        ("exec_start", "A", str(r8.key)),
        ("exec_end", "A", str(r8.key)),
        ("exec_start", "A", str(r8.key)),
        ("exec_end", "A", str(r8.key)),
        ("barrier", "A", "and[2]"),
        ("reconfig_start", "A", "mm16"),
        ("reconfig_end", "A", "mm16"),
        ("exec_start", "A", str(r16.key)),
        ("exec_end", "A", str(r16.key)),
    ]
    # dependent kernel strictly after the barrier; barrier after both deps
    bar_t = next(e.t for e in sched.event_log() if e.kind == "barrier")
    first_p3 = next(e for e in sched.event_log() if e.what == str(r16.key))
    assert bar_t == 12.0 and first_p3.t >= bar_t
    assert p3.out.error is None
    np.testing.assert_allclose(np.asarray(p3.out.value)[0, 0], 16.0)


def test_independent_queue_progresses_during_reconfig_stall():
    """While queue A's role loads (t=0..10), queue B's resident work runs."""
    sched, lib, rm, led = _mk_sched()
    ra, rb = _mk_role(lib, 8, "roleA"), _mk_role(lib, 16, "roleB")
    rm.ensure_resident(rb)                        # B starts resident
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))

    qa.dispatch(ra.key, _x(8), _x(8))
    for _ in range(3):
        qb.dispatch(rb.key, _x(16), _x(16))
    sched.run_until_idle()

    log = sched.event_log()
    a_reconfig = next(e for e in log if e.kind == "reconfig_start" and e.queue == "A")
    a_exec = next(e for e in log if e.kind == "exec_start" and e.queue == "A")
    b_execs = [e for e in log if e.kind == "exec_start" and e.queue == "B"]
    # B's three kernels all launch inside A's stall window [0, 10)
    assert a_reconfig.t == 0.0
    assert [e.t for e in b_execs] == [0.0, 1.0, 2.0]
    assert all(e.t < 10.0 for e in b_execs)
    assert a_exec.t == 10.0                       # A resumes exactly at stall end
    # stall accounting went to A only
    assert sched.stats["A"].reconfig_s == 10.0
    assert sched.stats["B"].reconfig_s == 0.0


def test_sync_baseline_reconfig_blocks_device():
    """overlap_reconfig=False: the same workload serializes, device idles."""
    def build(overlap):
        sched, lib, rm, led = _mk_sched()
        sched.overlap_reconfig = overlap
        ra, rb = _mk_role(lib, 8, "roleA"), _mk_role(lib, 16, "roleB")
        rm.ensure_resident(rb)
        qa = sched.add_queue(Queue(None, 64, name="A"))
        qb = sched.add_queue(Queue(None, 64, name="B"))
        qa.dispatch(ra.key, _x(8), _x(8))
        for _ in range(3):
            qb.dispatch(rb.key, _x(16), _x(16))
        sched.run_until_idle()
        return sched.timeline()

    t_async, t_sync = build(True), build(False)
    assert t_async["busy_s"] == t_sync["busy_s"] == 4.0
    assert t_async["makespan_s"] < t_sync["makespan_s"]
    assert t_async["idle_fraction"] < t_sync["idle_fraction"]


def test_doorbell_wakeups_not_lost_on_reentrant_submit():
    """Work submitted *during* another packet's execution is still picked up."""
    sched, lib, rm, led = _mk_sched()
    q = sched.add_queue(Queue(None, 64, name="A"))
    seen = []

    def chained(depth):
        seen.append(depth)
        if depth < 5:
            q.call(chained, depth + 1)          # submit from inside execution
        return depth

    q.call(chained, 0)
    completed = sched.run_until_idle()
    assert seen == [0, 1, 2, 3, 4, 5]
    assert completed == 6
    assert q.pending() == 0
    assert q.doorbell.load() == 6                # every submit rang the doorbell


def test_cross_queue_dependency_orders_execution():
    sched, lib, rm, led = _mk_sched()
    r8 = _mk_role(lib, 8)
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))
    pa = qa.dispatch(r8.key, _x(8), _x(8))
    pb = qb.dispatch(r8.key, _x(8), _x(8), deps=[pa.completion])
    sched.run_until_idle()
    log = sched.event_log()
    end_a = next(e for e in log if e.kind == "exec_end" and e.queue == "A")
    start_b = next(e for e in log if e.kind == "exec_start" and e.queue == "B")
    assert start_b.t >= end_a.t
    assert pb.out.error is None


def test_unsatisfiable_dependency_raises_deadlock():
    sched, lib, rm, led = _mk_sched()
    q = sched.add_queue(Queue(None, 64, name="A"))
    never = Signal(1, name="never")
    q.barrier([never])
    with pytest.raises(SchedulerDeadlock):
        sched.run_until_idle()


def test_weighted_policy_grants_proportional_slots():
    sched, lib, rm, led = _mk_sched(policy="weighted")
    q_hi = sched.add_queue(Queue(None, 64, name="hi", weight=2))
    q_lo = sched.add_queue(Queue(None, 64, name="lo", weight=1))
    for _ in range(4):
        q_hi.call(lambda: 1)
        q_hi.call(lambda: 1)
        q_lo.call(lambda: 1)
    sched.run_until_idle()
    order = [e.queue for e in sched.event_log() if e.kind == "exec_start"]
    assert order == ["hi", "hi", "lo"] * 4       # 2:1 grant pattern, exactly


def test_event_log_deterministic_across_replays():
    """Same seed + same workload => bit-identical event logs, 5 runs."""
    def one_run():
        sched, lib, rm, led = _mk_sched(policy="random", seed=123)
        r8, r16, r32 = _mk_role(lib, 8), _mk_role(lib, 16), _mk_role(lib, 32)
        qa = sched.add_queue(Queue(None, 64, name="A"))
        qb = sched.add_queue(Queue(None, 64, name="B"))
        for i in range(6):
            qa.dispatch((r8 if i % 2 else r16).key,
                        *( (_x(8), _x(8)) if i % 2 else (_x(16), _x(16)) ))
            qb.dispatch(r32.key, _x(32), _x(32))
        sched.run_until_idle()
        return [(e.t, e.brief()) for e in sched.event_log()]

    runs = [one_run() for _ in range(5)]
    assert all(r == runs[0] for r in runs[1:])


def test_per_queue_ledger_breakdown_attributed():
    sched, lib, rm, led = _mk_sched()
    r8, r16 = _mk_role(lib, 8), _mk_role(lib, 16)
    qa = sched.add_queue(Queue(None, 64, name="A"))
    qb = sched.add_queue(Queue(None, 64, name="B"))
    qa.dispatch(r8.key, _x(8), _x(8))
    qa.dispatch(r8.key, _x(8), _x(8))
    qb.dispatch(r16.key, _x(16), _x(16))
    sched.run_until_idle()

    bd = led.queue_breakdown()
    assert bd["A"][ledger_mod.DISPATCH].count == 2
    assert bd["A"][ledger_mod.RECONFIG].count == 1    # second dispatch was a hit
    assert bd["B"][ledger_mod.DISPATCH].count == 1
    assert bd["B"][ledger_mod.RECONFIG].count == 1
    assert bd["A"][ledger_mod.WAIT].count == 2
    # scheduler-side report agrees on packet counts
    rep = sched.queue_report()
    assert rep["A"]["dispatched"] == 2 and rep["B"]["dispatched"] == 1


def test_reconfig_failure_surfaces_in_packet():
    """All regions pinned: the load can never succeed — the error must land in
    the packet's result box, not execute the role outside region management."""
    sched, lib, rm, led = _mk_sched(num_regions=1)
    pinned, other = _mk_role(lib, 8, "pinned"), _mk_role(lib, 16, "other")
    sched.regions.pin(pinned)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(other.key, _x(16), _x(16))
    sched.run_until_idle()
    assert isinstance(pkt.out.error, RuntimeError)
    assert "pinned" in str(pkt.out.error)
    assert pkt.completion.load() == 0                 # waiter is released
    assert not sched.regions.is_resident(other.key)   # cap never violated
    assert not other.resident


def test_eviction_between_stall_and_exec_restalls_with_accounting():
    """If the just-loaded role is evicted again before the packet executes
    (another tenant thrashing the regions), the packet re-stalls with proper
    reconfig events instead of reloading invisibly at exec time."""
    sched, lib, rm, led = _mk_sched(num_regions=1)
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    pkt = q.dispatch(r8.key, _x(8), _x(8))

    ev = sched.step()                              # begins the first stall
    assert ev.kind == "reconfig_start"
    rm.flush()                                     # foreign eviction mid-flight
    sched.run_until_idle()

    starts = [e for e in sched.event_log() if e.kind == "reconfig_start"]
    assert len(starts) == 2                        # stall happened twice, visibly
    assert pkt.out.error is None
    np.testing.assert_allclose(np.asarray(pkt.out.value)[0, 0], 8.0)
    assert sched.stats["A"].reconfigs == 2
    assert led.stat(ledger_mod.RECONFIG).count == 2


def test_errors_surface_without_killing_the_loop():
    sched, lib, rm, led = _mk_sched()
    r8 = _mk_role(lib, 8)
    q = sched.add_queue(Queue(None, 64, name="A"))
    bad = q.dispatch(r8.key, _x(4), _x(4))       # wrong shapes
    good = q.dispatch(r8.key, _x(8), _x(8))
    sched.run_until_idle()
    assert bad.out.error is not None
    assert good.out.error is None
    assert good.completion.load() == 0
    np.testing.assert_allclose(np.asarray(good.out.value)[0, 0], 8.0)


# ---------------------------------------------------------------------------
# RegionManager LRU properties, driven through the scheduler on a virtual clock
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    budget=st.integers(min_value=1, max_value=4),
    seq=st.lists(st.integers(min_value=0, max_value=5), min_size=1, max_size=40),
)
def test_property_scheduler_lru_matches_reference_model(budget, seq):
    """Dispatching a random role sequence through the scheduler reproduces a
    textbook LRU: hits/misses/evictions and final residency order."""
    from collections import OrderedDict

    sched, lib, rm0, led = _mk_sched(num_regions=budget)
    sizes = [8, 16, 24, 32, 40, 48]
    roles = [_mk_role(lib, sizes[i], f"r{i}") for i in range(6)]
    q = sched.add_queue(Queue(None, 2048, name="A"))

    for i in seq:
        n = sizes[i]
        q.dispatch(roles[i].key, _x(n), _x(n))
    sched.run_until_idle()

    # reference LRU
    model: OrderedDict = OrderedDict()
    hits = misses = evictions = 0
    for i in seq:
        k = roles[i].key
        if k in model:
            hits += 1
            model.move_to_end(k)
        else:
            misses += 1
            if len(model) >= budget:
                model.popitem(last=False)
                evictions += 1
            model[k] = None
        assert len(model) <= budget

    assert sched.regions.stats.hits == hits
    assert sched.regions.stats.misses == misses
    assert sched.regions.stats.evictions == evictions
    assert sched.regions.resident_keys() == list(model.keys())
    lookups = sched.regions.stats.lookups
    assert lookups == len(seq)
    assert sched.regions.stats.hit_rate == (hits / lookups if lookups else 0.0)


@settings(max_examples=20, deadline=None)
@given(
    seq=st.lists(st.integers(min_value=0, max_value=3), min_size=1, max_size=30),
)
def test_property_pinned_roles_never_evicted_under_load(seq):
    sched, lib, rm0, led = _mk_sched(num_regions=2)
    sizes = [8, 16, 24, 32]
    roles = [_mk_role(lib, sizes[i], f"r{i}") for i in range(4)]
    pinned = roles[0]
    sched.regions.pin(pinned)
    q = sched.add_queue(Queue(None, 2048, name="A"))

    for i in seq:
        n = sizes[i]
        q.dispatch(roles[i].key, _x(n), _x(n))
    sched.run_until_idle()

    assert sched.regions.is_resident(pinned.key)
    assert pinned.resident
    assert len(sched.regions) <= 2
