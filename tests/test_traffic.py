"""Live-traffic serving: chunked prefill, engine-clock latency accounting,
and mid-flight submission.

The acceptance bars (ISSUE PR-6):

  - chunked prefill is a *scheduling* change, never a numerics change —
    token streams bitwise-identical to the whole-prompt engine across
    ``prefill_chunk`` x ``decode_fusion``, dense and paged, greedy and
    seeded temperature;
  - per-request timestamps ride the engine clock monotonically
    (``arrival_t <= first_token_t <= finish_t``);
  - the ledger's TTFT/TPOT quantiles match a hand-computed oracle on a
    deterministic virtual-clock trace;
  - ``submit()`` while ``run_to_completion`` is mid-flight lands at the
    next step boundary and is never misclassified as rejected — under a
    real feeder thread (WallClock) and deterministically (VirtualClock).
"""

import math
import threading
import time

import jax
import pytest

from repro.configs import ARCHS, reduced
from repro.core.hsa.clock import VirtualClock
from repro.core.ledger import OverheadLedger
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(11))
    return cfg, model, params


# one prompt long enough to span several chunks, plus shorts whose second
# wave admits at different steps under different chunk/fusion settings
PROMPTS = [list(range(3, 23)), [7, 8], [1, 2, 3, 4, 5, 6], [42]]


def _step_time(prefill_tokens: int, decode_tokens: int) -> float:
    return 1e-3 + 1e-4 * prefill_tokens + 5e-5 * decode_tokens


def _generate(model, params, *, chunk, fusion, paged=False, temperature=0.0,
              seed=0, max_new=6, prompts=PROMPTS):
    eng = ServeEngine(
        model, params, batch_slots=2, max_len=64, decode_fusion=fusion,
        temperature=temperature, seed=seed, paged=paged, page_size=16,
        prefill_chunk=chunk,
    )
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = sorted(eng.run_to_completion(), key=lambda r: r.uid)
    return [r.generated for r in done]


# ---------------------------------------------------------------------------
# bitwise identity: chunked == whole-prompt, every config
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("temperature,seed", [(0.0, 0), (0.7, 3)],
                         ids=["greedy", "temp"])
def test_chunked_streams_bitwise_identical(engine_model, paged, temperature,
                                           seed):
    _, model, params = engine_model
    base = _generate(model, params, chunk=None, fusion=1, paged=paged,
                     temperature=temperature, seed=seed)
    assert any(base), "baseline generated nothing"
    for chunk, fusion in ((4, 1), (4, 4), (16, 4)):
        got = _generate(model, params, chunk=chunk, fusion=fusion,
                        paged=paged, temperature=temperature, seed=seed)
        assert got == base, f"chunk={chunk} fusion={fusion} paged={paged}"


def test_chunked_actually_chunks(engine_model):
    """The identity test must not pass vacuously: a 20-token prompt under
    chunk=4 really streams through the chunk path (traced at least once)."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=64,
                      decode_fusion=1, prefill_chunk=4)
    eng.submit(PROMPTS[0], max_new_tokens=2)
    eng.run_to_completion()
    assert eng.chunk_traces >= 1


# ---------------------------------------------------------------------------
# engine clock: timestamps and ledger quantiles
# ---------------------------------------------------------------------------


def _replay(model, params, trace, *, chunk, ledger=None):
    """Feed ``[(arrival_s, prompt, max_new), ...]`` through a virtual-clock
    engine; the completed requests, uid-sorted."""
    clock = VirtualClock()
    eng = ServeEngine(
        model, params, batch_slots=2, max_len=64, decode_fusion=2,
        prefill_chunk=chunk, clock=clock, step_time_model=_step_time,
        ledger=ledger,
    )
    i, done = 0, []
    while True:
        while i < len(trace) and trace[i][0] <= clock.now():
            t_a, p, m = trace[i]
            eng.submit(p, max_new_tokens=m, arrival_t=t_a)
            i += 1
        if not (eng._active or eng._prefilling or eng._queue or eng._parked):
            if i >= len(trace):
                break
            clock.advance_to(trace[i][0])
            continue
        done += eng.step()
    return sorted(done, key=lambda r: r.uid)


TRACE = [
    (0.000, list(range(3, 23)), 5),
    (0.001, [7, 8], 4),
    (0.004, [1, 2, 3, 4, 5, 6], 3),
    (0.030, [42], 6),
    (0.031, [9, 9, 9], 1),       # single-token: TPOT divisor clamps at 1
    (0.090, [5, 4, 3, 2], 4),
]


def test_timestamps_monotone_per_request(engine_model):
    _, model, params = engine_model
    done = _replay(model, params, TRACE, chunk=4)
    assert len(done) == len(TRACE)
    for req, (t_a, _, m) in zip(done, TRACE):
        assert req.arrival_t == t_a
        assert req.first_token_t is not None and req.finish_t is not None
        assert req.arrival_t <= req.first_token_t <= req.finish_t
        assert len(req.generated) == m
        # a request whose remaining budget exceeds one fused launch (k=2 in
        # _replay) cannot finish in its first-token step: strictly later
        if m - 1 > 2:
            assert req.first_token_t < req.finish_t


def _oracle_quantile(samples, q):
    """The ledger's empirical quantile: sorted window, ceil-index."""
    s = sorted(samples)
    idx = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
    return s[idx]


def test_traffic_split_matches_hand_computed_oracle(engine_model):
    _, model, params = engine_model
    led = OverheadLedger()
    done = _replay(model, params, TRACE, chunk=4, ledger=led)

    ttft = [r.first_token_t - r.arrival_t for r in done]
    tpot = [(r.finish_t - r.first_token_t) / max(1, len(r.generated) - 1)
            for r in done]
    split = led.traffic_split()
    assert split["ttft_n"] == split["tpot_n"] == float(len(done))
    assert split["ttft_mean_s"] == pytest.approx(sum(ttft) / len(ttft))
    assert split["tpot_mean_s"] == pytest.approx(sum(tpot) / len(tpot))
    for q, name in ((0.5, "p50"), (0.99, "p99")):
        assert split[f"ttft_{name}_s"] == pytest.approx(
            _oracle_quantile(ttft, q)), name
        assert split[f"tpot_{name}_s"] == pytest.approx(
            _oracle_quantile(tpot, q)), name
    # virtual clock: every latency is a schedule property, so a second
    # replay reproduces the numbers bit-for-bit
    led2 = OverheadLedger()
    _replay(model, params, TRACE, chunk=4, ledger=led2)
    assert led2.traffic_split() == split


# ---------------------------------------------------------------------------
# mid-flight submission: feeder thread (WallClock) and deterministic variant
# ---------------------------------------------------------------------------


def test_midflight_submit_wallclock_feeder_thread(engine_model):
    """submit() from a feeder thread while run_to_completion is mid-flight:
    the late requests are admitted at a step boundary and finish — never
    lost, never misclassified as rejected.  The first step's jit compile
    spans hundreds of ms, so a 50 ms feeder delay lands safely mid-flight."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=64,
                      decode_fusion=2, prefill_chunk=4)
    first = [eng.submit(p, max_new_tokens=12) for p in PROMPTS[:2]]
    late: list[int] = []

    def feeder():
        time.sleep(0.05)
        for p in PROMPTS[2:]:
            late.append(eng.submit(p, max_new_tokens=4))

    th = threading.Thread(target=feeder)
    th.start()
    done = eng.run_to_completion()     # must also drain the feeder's requests
    th.join()
    got = sorted(r.uid for r in done)
    assert got == sorted(first + late)
    by_uid = {r.uid: r for r in done}
    assert all(len(by_uid[u].generated) == 12 for u in first)
    assert all(len(by_uid[u].generated) == 4 for u in late)


def test_concurrent_submit_uids_unique(engine_model):
    """The uid counter and queue are shared with feeder threads: concurrent
    submits must never mint duplicate uids or drop queue entries."""
    _, model, params = engine_model
    eng = ServeEngine(model, params, batch_slots=2, max_len=64)
    uids: list[int] = []
    lock = threading.Lock()

    def feeder():
        mine = [eng.submit([1, 2, 3], max_new_tokens=1) for _ in range(8)]
        with lock:
            uids.extend(mine)

    threads = [threading.Thread(target=feeder) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(uids) == 32 and len(set(uids)) == 32
    assert len(eng._queue) == 32


def test_midflight_submit_virtualclock_deterministic(engine_model):
    """Deterministic variant: submit between step() calls on the virtual
    clock.  The late request is queued (not rejected), admitted at the very
    next step boundary, and stamps its backdated arrival."""
    _, model, params = engine_model
    eng = ServeEngine(
        model, params, batch_slots=2, max_len=64, decode_fusion=2,
        paged=True, page_size=16, prefill_chunk=4,
        clock=VirtualClock(), step_time_model=_step_time,
    )
    first = [eng.submit(PROMPTS[0], max_new_tokens=8),
             eng.submit(PROMPTS[1], max_new_tokens=8)]
    done = eng.step()                   # both admitted, mid-flight now
    t_mid = eng.clock.now()
    late = eng.submit(PROMPTS[2], max_new_tokens=3, arrival_t=t_mid)
    assert any(r.uid == late for r in eng._queue), "late submit not queued"
    for _ in range(200):
        done += eng.step()
        if {r.uid for r in done} == set(first) | {late}:
            break
    else:
        pytest.fail(f"late request never completed: {[r.uid for r in done]}")
    req = next(r for r in done if r.uid == late)
    assert req.arrival_t == t_mid
    assert t_mid <= req.first_token_t <= req.finish_t
    assert len(req.generated) == 3
