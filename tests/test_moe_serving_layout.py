"""Serving-layout MoE validation: the ``expert_ff``-over-data guess, dry-run.

ROADMAP open item (PR 1): when serving an MoE whose expert count cannot
cover the full mesh, ``ShardingRules.for_arch`` shards experts over
"model" and the expert FFN dim over "data" — reconstructed as a
best-effort guess.  These cases validate it against a real dry-run (the
``launch/dryrun.py`` path: lower + compile ``make_decode_step`` under the
production shardings) and against the single-device numerics.  Verdict:
the rule is RIGHT — partial-f contributions land in the widened psum
(``psum_axes = ("model",) + ff``) and decode matches the local path
exactly; the ROADMAP note is closed accordingly.
"""

import subprocess
import sys

import pytest

# fresh interpreter per case (multi-device XLA compile, minutes): slow job
pytestmark = pytest.mark.slow

COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import ARCHS, reduced
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_mesh
from repro.models import build_model, moe as moe_mod
from repro.models.params import init_params, abstract_params
from repro.train.step import moe_mesh_info
mesh = make_mesh((2, 2), ("data", "model"))
# 6 experts cannot cover the 4-chip mesh -> serving rules must pick the
# E-over-model / f-over-data layout
cfg = reduced(ARCHS["llama4-maverick-400b-a17b"])
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=6, experts_per_token=1, capacity_factor=8.0))
rules = ShardingRules.for_arch(cfg, mesh, serving=True)
assert rules.logical_to_physical["expert_ff"] == ("data",), rules.logical_to_physical
assert rules.logical_to_physical["expert"] == ("model",)
"""


def run_case(body: str) -> None:
    proc = subprocess.run(
        [sys.executable, "-c", COMMON + body],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"


def test_serving_ff_over_data_moe_matches_local():
    """The f-sharded serving MoE (tokens replicated, partial-f psum over
    ("model", "data")) must reproduce the single-device expert math."""
    run_case("""
info_check = moe_mesh_info(cfg, rules, for_decode=True)
assert info_check.mode == "tp" and info_check.psum_axes == ("model", "data"), (
    info_check.mode, info_check.psum_axes)

p = init_params(moe_mod.moe_specs(cfg), jax.random.key(0))
p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
x = jax.random.normal(jax.random.key(1), (4, 1, cfg.d_model), jnp.float32)
y_local, _ = moe_mod.apply_moe(p, x, cfg, dropless=True)
with jax.set_mesh(mesh):
    info = moe_mesh_info(cfg, rules, for_decode=True)
    y_s, _ = jax.jit(
        lambda pp, xx: moe_mod.apply_moe(pp, xx, cfg, mesh_info=info,
                                         dropless=True)
    )(p, x)
np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_local),
                           rtol=2e-4, atol=2e-4)
print("serving ff-over-data MoE matches local OK")
""")


def test_serving_ff_over_data_decode_step_compiles():
    """The full production decode step (launch/dryrun.py's decode cell)
    lowers and compiles under the f-sharded serving layout — the 'real
    dry-run' the ROADMAP asked for."""
    run_case("""
from repro.serve.engine import make_decode_step
model = build_model(cfg)
p_abs = abstract_params(model.param_specs())
with jax.set_mesh(mesh):
    step, p_sh, c_sh, cache_tree = make_decode_step(
        model, rules, global_batch=4, cache_len=32)
    tokens = jax.ShapeDtypeStruct((4, 1), jnp.int32)
    compiled = step.lower(p_abs, tokens, cache_tree).compile()
ma = compiled.memory_analysis()
assert ma.argument_size_in_bytes > 0
# expert params really are f-sharded over data: wg [E, d, f] -> P over
# ("model", None, "data")
import jax.tree_util as jtu
def find(tree, *names):
    for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
        keys = [getattr(p, "key", None) for p in path]
        if all(n in keys for n in names):
            return leaf
    raise KeyError(names)
wg_sh = find(p_sh, "moe", "wg")
spec = wg_sh.spec          # leading axis is the scanned layer stack
assert tuple(spec)[-3:] == ("model", None, "data"), spec
print("decode step compiled under ff-over-data layout OK")
""")
