"""Deterministic synthetic token pipeline — stateless-resumable, sharded.

Production posture: every batch is a pure function of (seed, step), so a
restarted or elastically-rescaled job regenerates exactly the token stream it
would have seen — no data-loader state in checkpoints, no skew after failover
(the property real pipelines get from deterministic sampling over a fixed
corpus index).

The synthetic stream is a mixture of Zipfian unigrams and shifted-repeat
structure so models actually have something learnable (copy heads / induction
patterns emerge within a few hundred steps on the quickstart config).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_period: int = 64           # induction structure
    repeat_prob: float = 0.5


class SyntheticTokens:
    """Batch factory: ``batch_at(step)`` is pure and O(batch) to compute."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precompute the Zipf CDF once (vocab can be 200k)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** cfg.zipf_a
        self._cdf = np.cumsum(probs / probs.sum())

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xDA7A])
        )
        u = rng.random((cfg.global_batch, cfg.seq_len))
        tokens = np.searchsorted(self._cdf, u).astype(np.int32)
        # overlay shifted-repeat structure: second half repeats the first at
        # period offsets, giving induction heads something to learn
        rep = rng.random((cfg.global_batch, 1)) < cfg.repeat_prob
        p = cfg.repeat_period
        if cfg.seq_len >= 2 * p:
            src = tokens[:, :p]
            reps = np.tile(src, (1, cfg.seq_len // p + 1))[:, : cfg.seq_len]
            tokens = np.where(rep, reps, tokens)
        tokens = np.clip(tokens, 0, cfg.vocab_size - 1)
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_iterator(
    cfg: DataConfig,
    *,
    start_step: int = 0,
    sharding=None,
    prefetch: int = 1,
):
    """Iterator of device-put batches with one-step lookahead prefetch.

    ``sharding`` (a NamedSharding for [B, S]) places each host batch directly
    into its sharded device layout; prefetch overlaps host generation with the
    device step (the standard input-pipeline/compute overlap).
    """
    src = SyntheticTokens(cfg)

    def put(batch):
        if sharding is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, sharding) for k, v in batch.items()}

    def gen():
        import collections

        queue: collections.deque = collections.deque()
        step = start_step
        for _ in range(max(1, prefetch)):
            queue.append(put(src.batch_at(step)))
            step += 1
        while True:
            yield queue.popleft()
            queue.append(put(src.batch_at(step)))
            step += 1

    return gen()
