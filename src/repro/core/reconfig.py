"""Region manager: bounded kernel residency with prefetch-aware LRU eviction.

The FPGA in the paper exposes a fixed number of reconfigurable regions; when a
dispatched kernel's role is not loaded, the runtime reconfigures a region,
evicting the least-recently-used role if all regions are occupied.  The TPU
analogue manages a bounded set of device-loaded executables (program + weight
residency).  ``ensure_resident`` is the single choke point the HSA executor
calls before every kernel launch; it records reconfiguration costs in the
overhead ledger (paper Table II row 2).

Beyond plain LRU, a region slot can be in two additional states that the
lookahead scheduler (:mod:`repro.core.hsa.scheduler`) drives:

  - *prefetching* — a speculative load issued ahead of demand is in flight.
    The slot is occupied but the role is not yet usable; it cannot be chosen
    as an eviction victim (you cannot reprogram a region mid-bitstream).
  - *reserved* — the role was loaded on behalf of a packet already sitting in
    a queue (refcounted).  Reserved roles are skipped by the victim search so
    a prefetched region is still hot when its packet is finally granted.

Victim selection is tiered: prefer roles that are neither pinned, reserved,
nor *protected* (referenced by a packet inside the scheduler's lookahead
window — an approximate Bélády oracle read straight off the queues); fall
back to protected, then to reserved (wasting the prefetch) under demand
pressure; pinned roles are never evicted.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import AbstractSet, Any, Callable, Iterator, Mapping

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.roles import Role, RoleKey

# ``protect`` accepted by the eviction paths: a set of keys (all equally
# urgent) or a mapping key -> first-use distance (lower = demanded sooner),
# which lets the fallback tier evict the role needed furthest in the future.
# A zero-arg callable returning either is evaluated only if eviction is
# actually needed, so residency *hits* never pay for the window scan.
Protection = Mapping[RoleKey, int] | AbstractSet[RoleKey]

# region-slot states reported by RegionManager.state()
RESIDENT = "resident"
PREFETCHING = "prefetching"
RESERVED = "reserved"

_EMPTY: frozenset = frozenset()


@dataclasses.dataclass
class ResidencyStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    prefetch_issued: int = 0
    prefetch_hits: int = 0       # demand lookups served by a prefetched load
    prefetch_wasted: int = 0     # prefetched but evicted/flushed before use

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


@dataclasses.dataclass
class ResidencyResult:
    role: Role
    hit: bool
    evicted: RoleKey | None = None
    reconfig_s: float = 0.0


def region_image_digest(role: Role) -> bytes:
    """Digest identifying the bitstream image that *should* occupy a region
    after loading ``role`` — the reconfiguration analogue of a page digest.
    Derived from the role's identity (name, key, source): the simulation's
    stand-in for hashing the partial bitstream itself."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((role.name, role.key, role.source)).encode())
    return h.digest()


def _stale_image_digest(expected: bytes) -> bytes:
    """What a stale/corrupted load leaves in the region: definitely not
    ``expected``."""
    return hashlib.blake2b(b"stale:" + expected, digest_size=16).digest()


class RegionManager:
    """LRU-managed residency over ``num_regions`` slots.

    Pinned roles are exempt from eviction (the paper's static shell services —
    e.g. a DMA engine — correspond to pinned entries).
    """

    def __init__(
        self,
        num_regions: int,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        corrupt_hook: Callable[[str], bool] | None = None,
        verify_images: bool = True,
    ) -> None:
        if num_regions < 1:
            raise ValueError("need at least one region")
        self.num_regions = num_regions
        self.ledger = ledger
        self.stats = ResidencyStats()
        # fault injection: called with the role name before every load
        # attempt; raising (FaultError) models the load aborting mid-flight
        # (see repro.core.hsa.faults.FaultPlan.load_hook)
        self.fault_hook: Callable[[str], None] | None = None
        # silent-corruption injection: called with the role name after a
        # load completes; True means the region received a stale image
        # (see FaultPlan.stale_region_hook)
        self.corrupt_hook = corrupt_hook
        # verify the region-image digest after every load (and again at
        # complete_prefetch) so a stale reconfiguration is caught before
        # any packet executes against it; IntegrityPolicy.verify_regions
        # turns this off for escape-accounting experiments
        self.verify_images = verify_images
        self._image_digests: dict[RoleKey, bytes] = {}
        self._escape_reported: set[RoleKey] = set()
        self._resident: "OrderedDict[RoleKey, Role]" = OrderedDict()  # LRU: oldest first
        self._pinned: set[RoleKey] = set()
        self._prefetching: dict[RoleKey, Role] = {}   # speculative loads in flight
        self._reserved: dict[RoleKey, int] = {}       # refcount of queued demand
        self._fresh: set[RoleKey] = set()             # prefetched, not yet demanded
        # the scheduler's reconfig worker and exec path may race: one choke lock
        import threading

        self._lock = threading.RLock()

    # -- core protocol -------------------------------------------------------

    def ensure_resident(
        self,
        role: Role,
        *,
        queue: str | None = None,
        protect: "Protection | Callable[[], Protection]" = _EMPTY,
    ) -> ResidencyResult:
        """Demand path: make ``role`` usable now, evicting if necessary.

        ``protect`` keys (roles demanded by packets inside the scheduler's
        lookahead window) are only evicted when there is no other victim.
        """
        with self._lock:
            key = role.key
            if key in self._resident:
                self._resident.move_to_end(key)
                self.stats.hits += 1
                self._note_use(key)
                self._note_image_use(role)
                return ResidencyResult(role=role, hit=True)

            self.stats.misses += 1
            evicted: RoleKey | None = None
            if self._slots_used() >= self.num_regions:
                if callable(protect):
                    protect = protect()
                evicted = self._evict_one(protect=protect, speculative=False)
                if evicted is None:
                    raise RuntimeError(
                        f"all {self.num_regions} regions pinned or loading; "
                        f"cannot load {role.name}"
                    )

            dt = self._load(role, queue=queue, evicted=evicted, prefetch=False)
            self._resident[key] = role
            self._note_use(key)
            # the demanding packet executes against this image next — with
            # verification off, a stale load escapes right here
            self._note_image_use(role)
            return ResidencyResult(role=role, hit=False, evicted=evicted, reconfig_s=dt)

    def touch(self, key: RoleKey) -> bool:
        """Refresh LRU position without a stats lookup (scheduler exec path:
        the preceding stall already accounted this packet's lookup).
        Returns False when the role was evicted again in the meantime."""
        with self._lock:
            if key not in self._resident:
                return False
            self._resident.move_to_end(key)
            self._note_use(key)
            return True

    # -- prefetch state machine ------------------------------------------------

    def begin_prefetch(
        self,
        role: Role,
        *,
        queue: str | None = None,
        protect: Protection = _EMPTY,
        target_rank: int | None = None,
    ) -> ResidencyResult | None:
        """Speculatively load ``role`` ahead of demand.

        Best-effort: returns None when the role is already resident/loading or
        when making space would evict a pinned, reserved, or window-protected
        role (speculation never steals a region demand is about to use).
        ``target_rank`` is the prefetched role's own first-use distance: a
        protected victim demanded strictly *later* than that may still be
        displaced (the Bélády argument cuts both ways).  Raises RuntimeError
        only when the miss is structural — every region is pinned — so the
        caller can surface it rather than retry forever.  The loaded role is
        *reserved* (refcount) until a demand lookup consumes it, and
        *prefetching* until :meth:`complete_prefetch`.
        """
        with self._lock:
            key = role.key
            if key in self._resident or key in self._prefetching:
                return None
            evicted: RoleKey | None = None
            if self._slots_used() >= self.num_regions:
                evicted = self._evict_one(
                    protect=protect, speculative=True, target_rank=target_rank
                )
                if evicted is None:
                    if len(self._pinned & set(self._resident)) >= self.num_regions:
                        raise RuntimeError(
                            f"all {self.num_regions} regions pinned; "
                            f"cannot prefetch {role.name}"
                        )
                    return None                  # transient: reserved/loading slots

            dt = self._load(role, queue=queue, evicted=evicted, prefetch=True)
            self._prefetching[key] = role
            self._reserved[key] = self._reserved.get(key, 0) + 1
            self.stats.prefetch_issued += 1
            return ResidencyResult(role=role, hit=False, evicted=evicted, reconfig_s=dt)

    def complete_prefetch(self, key: RoleKey, *, fresh: bool = True) -> bool:
        """Transition ``prefetching`` -> ``resident`` (MRU).  ``fresh=False``
        when a demand miss already joined the in-flight load (the join counted
        the prefetch hit; don't count it again at first touch).  Returns False
        when the in-flight entry was flushed meanwhile."""
        with self._lock:
            role = self._prefetching.pop(key, None)
            if role is None:
                return False
            if self.verify_images:
                # re-check the image that sat in the region while the
                # prefetch was in flight — a stale image is dropped like an
                # aborted prefetch (demand reloads, and re-verifies)
                expected = region_image_digest(role)
                if self._image_digests.get(key, expected) != expected:
                    role.unload()
                    self._release(key)
                    self._image_digests.pop(key, None)
                    self.stats.prefetch_wasted += 1
                    self.ledger.record_integrity_detection(via="region")
                    return False
            self._resident[key] = role
            self._resident.move_to_end(key)
            if fresh:
                self._fresh.add(key)
            return True

    def abort_prefetch(self, key: RoleKey) -> None:
        """Drop an in-flight prefetch (load failed or scheduler gave up)."""
        with self._lock:
            role = self._prefetching.pop(key, None)
            if role is not None:
                role.unload()
                self._release(key)
                self._image_digests.pop(key, None)
                self.stats.prefetch_wasted += 1

    def note_prefetch_join(self, key: RoleKey) -> None:
        """A demand miss joined an in-flight prefetch instead of double-loading."""
        with self._lock:
            self.stats.prefetch_hits += 1

    def is_prefetching(self, key: RoleKey) -> bool:
        with self._lock:
            return key in self._prefetching

    def state(self, key: RoleKey) -> str | None:
        with self._lock:
            if key in self._prefetching:
                return PREFETCHING
            if key in self._resident:
                return RESERVED if self._reserved.get(key) else RESIDENT
            return None

    # -- internals -------------------------------------------------------------

    def _slots_used(self) -> int:
        return len(self._resident) + len(self._prefetching)

    def _load(self, role: Role, *, queue, evicted, prefetch: bool) -> float:
        import time

        if self.fault_hook is not None:
            self.fault_hook(role.name)
        t0 = time.perf_counter_ns()
        role.load()
        dt = (time.perf_counter_ns() - t0) * 1e-9
        self.ledger.record(
            ledger_mod.RECONFIG, dt, role=role.name, evicted=str(evicted),
            source=role.source, queue=queue, prefetch=prefetch,
        )
        # the load returned cleanly — but did the region receive the right
        # image?  The corrupt hook models a stale/corrupted partial
        # bitstream surviving the DMA; verification catches it here, before
        # the role is ever published as resident/prefetched.
        expected = region_image_digest(role)
        loaded = expected
        if self.corrupt_hook is not None and self.corrupt_hook(role.name):
            loaded = _stale_image_digest(expected)
            self.ledger.record_corruption(kind="stale_region")
        if self.verify_images:
            self.ledger.record_verified_region()
            if loaded != expected:
                # deferred import: repro.core.hsa pulls the scheduler, which
                # imports this module back — resolvable only at call time
                from repro.core.hsa.faults import StaleRegionImage
                role.unload()
                self.ledger.record_integrity_detection(via="region")
                raise StaleRegionImage(
                    f"stale region image after load: {role.name}"
                )
        self._image_digests[role.key] = loaded
        self._escape_reported.discard(role.key)
        return dt

    def _note_use(self, key: RoleKey) -> None:
        if key in self._fresh:
            self._fresh.discard(key)
            self.stats.prefetch_hits += 1
        self._release(key)

    def _note_image_use(self, role: Role) -> None:
        """With verification off, a demand hit on a stale image is the
        moment corruption escapes (a packet is about to execute against
        the wrong bitstream); count it once per stale load."""
        if self.verify_images:
            return
        key = role.key
        stored = self._image_digests.get(key)
        if (stored is not None and key not in self._escape_reported
                and stored != region_image_digest(role)):
            self._escape_reported.add(key)
            self.ledger.record_escape()

    def _release(self, key: RoleKey) -> None:
        n = self._reserved.get(key, 0)
        if n > 1:
            self._reserved[key] = n - 1
        elif n:
            del self._reserved[key]

    def _evict_one(
        self,
        protect: Protection = _EMPTY,
        *,
        speculative: bool = False,
        target_rank: int | None = None,
    ) -> RoleKey | None:
        """Tiered victim search:

        (1) neither pinned, reserved, nor protected — LRU (oldest first);
        (2) protected but unreserved — the role demanded *furthest* in the
            future wins (Bélády fallback; plain LRU when ``protect`` carries
            no distances); a speculative caller only reaches this tier with a
            ``target_rank`` and may only displace roles demanded strictly
            later than its own target;
        (3) reserved (the prefetch is wasted) — LRU; demand only.

        Pinned roles are never evicted.
        """
        victim_key: RoleKey | None = None
        rank_of = protect.get if isinstance(protect, Mapping) else (
            lambda _k, _d=0: 0
        )
        for tier in (0, 1, 2):
            if speculative and (tier > 1 or (tier == 1 and target_rank is None)):
                break
            best: tuple[int, RoleKey] | None = None
            for key in self._resident:          # oldest-first iteration order
                if key in self._pinned:
                    continue
                if tier < 2 and self._reserved.get(key):
                    continue
                if tier == 0:
                    if key in protect:
                        continue
                    best = (0, key)             # LRU: first unprotected wins
                    break
                if tier == 1 and key not in protect:
                    continue                    # tier 0 already rejected it
                rank = rank_of(key, 0) if tier == 1 else 0
                if speculative and rank <= (target_rank or 0):
                    continue                    # demanded sooner than the target
                if best is None or rank > best[0]:
                    best = (rank, key)          # furthest first use; tie -> LRU
            if best is not None:
                victim_key = best[1]
                break
        if victim_key is None:
            return None
        victim = self._resident.pop(victim_key)
        victim.unload()
        self._image_digests.pop(victim_key, None)
        self._escape_reported.discard(victim_key)
        self.stats.evictions += 1
        if self._reserved.pop(victim_key, 0) or victim_key in self._fresh:
            self._fresh.discard(victim_key)
            self.stats.prefetch_wasted += 1
        return victim_key

    # -- management ------------------------------------------------------------

    def pin(self, role: Role) -> None:
        with self._lock:                 # no eviction window between load and pin
            self.ensure_resident(role)
            self._pinned.add(role.key)

    def unpin(self, key: RoleKey) -> None:
        with self._lock:
            self._pinned.discard(key)

    def flush(self) -> None:
        with self._lock:
            self.stats.prefetch_wasted += len(self._fresh) + len(self._prefetching)
            for role in self._resident.values():
                role.unload()
            for role in self._prefetching.values():
                role.unload()
            self._resident.clear()
            self._prefetching.clear()
            self._pinned.clear()
            self._reserved.clear()
            self._fresh.clear()
            self._image_digests.clear()
            self._escape_reported.clear()

    @property
    def pinned_count(self) -> int:
        with self._lock:
            return len(self._pinned)

    def resident_keys(self) -> list[RoleKey]:
        with self._lock:
            return list(self._resident.keys())

    def is_resident(self, key: RoleKey) -> bool:
        with self._lock:
            return key in self._resident

    def __len__(self) -> int:
        return self._slots_used()

    def __iter__(self) -> Iterator[Role]:
        return iter(self._resident.values())


# ---------------------------------------------------------------------------
# transfer engine: the DMA timeline between the page-pool tiers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Transfer:
    """One D2H spill or H2D refill on the transfer engine's timeline.

    ``start_t``/``ready_t`` are engine-clock stamps: the DMA begins when
    the (single) engine frees up and completes ``duration_s`` later, so
    back-to-back transfers queue exactly like region loads on the
    reconfiguration engine.  ``error`` is set instead when the fault plan
    aborted the attempt — the caller falls back (replay) rather than wait.
    """

    kind: str                  # "d2h" | "h2d"
    what: str                  # transfer tag, e.g. "kv[uid=3]"
    nbytes: int
    start_t: float = 0.0
    ready_t: float = 0.0
    duration_s: float = 0.0
    error: Exception | None = None
    waited: bool = False
    # integrity: the payload tree riding the DMA and its source digest.
    # A corrupt_transfer draw replaces ``payload`` with a byte-flipped
    # *copy* (the source tier keeps its clean bytes) and sets
    # ``corrupted`` — the engine's ground truth for escape accounting
    # when verification is off.
    payload: Any = None
    digest: bytes | None = None
    corrupted: bool = False


class TransferEngine:
    """Single-engine DMA timeline for tier spills (D2H) and refills (H2D).

    The reconfiguration engine's twin, one level down the memory
    hierarchy: region loads move *kernels* into bounded device residency,
    this engine moves *cold KV pages* between the bounded device pool and
    the budgeted host arena.  Durations are bandwidth-priced
    (``nbytes / bandwidth_bytes_s``) on the injectable clock, so on a
    ``VirtualClock`` every overlap question — did the refill hide behind
    decode, or did the resume stall on it? — is a deterministic assertion.

    Attribution mirrors the reconfig exposed/hidden split: ``wait`` charges
    the caller only the *exposed* residue (``ready_t - now``, clipped at 0)
    and books the rest as hidden — the part the ahead-of-need pump
    overlapped with compute.  A d2h spill is never waited on (the gather
    already made the host copy; the timeline cost only delays later
    refills queued behind it), so its full duration rides the SPILL
    category at issue time.

    A fault plan with ``transfer_rate`` (or forced ``"d2h"``/``"h2d"``
    faults) aborts attempts at issue: the engine is held for
    ``fault_backoff_s`` (the abort/backoff window), the ledger prices the
    fault, and the returned :class:`Transfer` carries ``error`` for the
    caller's fallback path.
    """

    def __init__(self, *, bandwidth_bytes_s: float = 8e9,
                 clock=None, ledger: OverheadLedger = GLOBAL_LEDGER,
                 faults=None, fault_backoff_s: float = 1e-3,
                 integrity=None) -> None:
        if bandwidth_bytes_s <= 0:
            raise ValueError(
                f"bandwidth_bytes_s must be > 0, got {bandwidth_bytes_s}"
            )
        if fault_backoff_s < 0:
            raise ValueError(
                f"fault_backoff_s must be >= 0, got {fault_backoff_s}"
            )
        if clock is None:
            from repro.core.hsa.clock import WallClock
            clock = WallClock()
        self.bandwidth_bytes_s = bandwidth_bytes_s
        self.clock = clock
        self.ledger = ledger
        self.faults = faults
        self.fault_backoff_s = fault_backoff_s
        self.integrity = integrity   # IntegrityPolicy | None
        if faults is not None:
            faults.bind_clock(clock)
        self._free_t = clock.now()
        self.issued = 0
        self.completed = 0
        self.faulted = 0
        self.cancelled = 0
        self.bytes_moved = 0

    def issue(self, kind: str, what: str, nbytes: int, *,
              payload: Any = None, digest: bytes | None = None) -> Transfer:
        """Queue one transfer on the engine timeline; returns immediately.

        The transfer's ``ready_t`` accounts for the engine being busy with
        earlier transfers.  On an injected fault the engine backs off and
        the returned transfer carries ``error`` instead of a timeline.

        ``payload``/``digest`` ride the transfer for the integrity layer: a
        ``corrupt_transfer`` draw byte-flips a *copy* of the payload (the
        source tier stays clean), and — when ``integrity.verify_transfers``
        — a d2h payload is digest-checked here at issue (spills complete at
        issue and are never waited), an h2d payload at :meth:`wait`."""
        if kind not in ("d2h", "h2d"):
            raise ValueError(f"transfer kind must be d2h|h2d, got {kind!r}")
        if nbytes < 0:
            raise ValueError(f"nbytes must be >= 0, got {nbytes}")
        now = self.clock.now()
        if self.faults is not None:
            err = self.faults.draw_transfer(kind, what)
            if err is not None:
                self.faulted += 1
                self._free_t = max(self._free_t, now) + self.fault_backoff_s
                self.ledger.record(ledger_mod.FAULT, 0.0, what=what,
                                   kind=kind)
                self.ledger.record(ledger_mod.RETRY, self.fault_backoff_s,
                                   what=what)
                self.ledger.record_fault(kind=kind)
                return Transfer(kind, what, nbytes, error=err)
        dur = nbytes / self.bandwidth_bytes_s
        start = max(now, self._free_t)
        ready = start + dur
        self._free_t = ready
        self.issued += 1
        self.bytes_moved += nbytes
        xfer = Transfer(kind, what, nbytes, start, ready, dur,
                        payload=payload, digest=digest)
        if (self.faults is not None and payload is not None
                and self.faults.draw_corruption(
                    "corrupt_transfer", [what]) is not None):
            from repro.serve.paged import flip_tree
            xfer.payload = flip_tree(payload)
            xfer.corrupted = True
            self.ledger.record_corruption(kind="corrupt_transfer")
        if kind == "d2h":
            self.completed += 1          # never waited: done at ready_t
            self.ledger.record(ledger_mod.SPILL, dur, what=what)
            self.ledger.record_spill(nbytes=nbytes)
            err = self._verify_payload(xfer)
            if err is not None:
                xfer.error = err
        return xfer

    def _verify_payload(self, xfer: Transfer) -> Exception | None:
        """Digest-check a transfer's delivered payload; returns the
        :class:`CorruptPayload` to surface (None = clean or unverifiable)."""
        if (self.integrity is None or not self.integrity.verify_transfers
                or xfer.payload is None or xfer.digest is None):
            return None
        self.ledger.record_verified_transfer()
        from repro.serve.paged import tree_digest
        if tree_digest(xfer.payload) == xfer.digest:
            return None
        from repro.core.hsa.faults import CorruptPayload
        self.ledger.record_integrity_detection(via="transfer")
        return CorruptPayload(
            f"{xfer.kind} payload digest mismatch: {xfer.what}"
        )

    def wait(self, xfer: Transfer) -> float:
        """Block on a refill until its DMA completes; returns the *exposed*
        seconds (virtual clocks are advanced by exactly that residue).

        Records the refill's duration plus its exposed/hidden attribution;
        waiting twice on the same transfer is a hard error (the bytes were
        already consumed).  When the engine carries an
        ``IntegrityPolicy(verify_transfers=True)``, the delivered payload
        is digest-checked after the DMA completes — a mismatch raises
        :class:`CorruptPayload` (the time was spent; the bytes are not
        trusted)."""
        if xfer.error is not None:
            raise xfer.error
        if xfer.waited:
            raise ValueError(f"transfer {xfer.what} already waited on")
        xfer.waited = True
        now = self.clock.now()
        exposed = max(0.0, xfer.ready_t - now)
        if exposed and getattr(self.clock, "virtual", False):
            self.clock.advance(exposed)
        hidden = max(0.0, xfer.duration_s - exposed)
        if xfer.kind == "h2d":
            self.completed += 1
            self.ledger.record(ledger_mod.REFILL, xfer.duration_s,
                               what=xfer.what)
            self.ledger.record(ledger_mod.REFILL_EXPOSED, exposed,
                               what=xfer.what)
            self.ledger.record(ledger_mod.REFILL_HIDDEN, hidden,
                               what=xfer.what)
            self.ledger.record_refill(nbytes=xfer.nbytes)
            err = self._verify_payload(xfer)
            if err is not None:
                xfer.error = err
                raise err
        return exposed

    def cancel(self, xfer: Transfer) -> None:
        """Drop an in-flight refill (its target was demoted to replay).
        The timeline slot is already spent — cancellation only stops the
        exposed/hidden accounting from ever being charged."""
        if xfer.error is None and not xfer.waited:
            self.cancelled += 1
