"""Region manager: bounded kernel residency with LRU eviction.

The FPGA in the paper exposes a fixed number of reconfigurable regions; when a
dispatched kernel's role is not loaded, the runtime reconfigures a region,
evicting the least-recently-used role if all regions are occupied.  The TPU
analogue manages a bounded set of device-loaded executables (program + weight
residency).  ``ensure_resident`` is the single choke point the HSA executor
calls before every kernel launch; it records reconfiguration costs in the
overhead ledger (paper Table II row 2).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterator

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.roles import Role, RoleKey


@dataclasses.dataclass
class ResidencyStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


@dataclasses.dataclass
class ResidencyResult:
    role: Role
    hit: bool
    evicted: RoleKey | None = None
    reconfig_s: float = 0.0


class RegionManager:
    """LRU-managed residency over ``num_regions`` slots.

    Pinned roles are exempt from eviction (the paper's static shell services —
    e.g. a DMA engine — correspond to pinned entries).
    """

    def __init__(
        self,
        num_regions: int,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
    ) -> None:
        if num_regions < 1:
            raise ValueError("need at least one region")
        self.num_regions = num_regions
        self.ledger = ledger
        self.stats = ResidencyStats()
        self._resident: "OrderedDict[RoleKey, Role]" = OrderedDict()  # LRU: oldest first
        self._pinned: set[RoleKey] = set()
        # the scheduler's reconfig worker and exec path may race: one choke lock
        import threading

        self._lock = threading.RLock()

    # -- core protocol -------------------------------------------------------

    def ensure_resident(self, role: Role, *, queue: str | None = None) -> ResidencyResult:
        with self._lock:
            key = role.key
            if key in self._resident:
                self._resident.move_to_end(key)
                self.stats.hits += 1
                return ResidencyResult(role=role, hit=True)

            self.stats.misses += 1
            evicted: RoleKey | None = None
            if len(self._resident) >= self.num_regions:
                evicted = self._evict_one()
                if evicted is None:
                    raise RuntimeError(
                        f"all {self.num_regions} regions pinned; cannot load {role.name}"
                    )

            import time

            t0 = time.perf_counter_ns()
            role.load()
            dt = (time.perf_counter_ns() - t0) * 1e-9
            self.ledger.record(
                ledger_mod.RECONFIG, dt, role=role.name, evicted=str(evicted),
                source=role.source, queue=queue,
            )
            self._resident[key] = role
            return ResidencyResult(role=role, hit=False, evicted=evicted, reconfig_s=dt)

    def touch(self, key: RoleKey) -> bool:
        """Refresh LRU position without a stats lookup (scheduler exec path:
        the preceding stall already accounted this packet's lookup).
        Returns False when the role was evicted again in the meantime."""
        with self._lock:
            if key not in self._resident:
                return False
            self._resident.move_to_end(key)
            return True

    def _evict_one(self) -> RoleKey | None:
        for key in self._resident:          # oldest-first iteration order
            if key not in self._pinned:
                victim = self._resident.pop(key)
                victim.unload()
                self.stats.evictions += 1
                return key
        return None

    # -- management ------------------------------------------------------------

    def pin(self, role: Role) -> None:
        with self._lock:                 # no eviction window between load and pin
            self.ensure_resident(role)
            self._pinned.add(role.key)

    def unpin(self, key: RoleKey) -> None:
        with self._lock:
            self._pinned.discard(key)

    def flush(self) -> None:
        with self._lock:
            for role in self._resident.values():
                role.unload()
            self._resident.clear()
            self._pinned.clear()

    def resident_keys(self) -> list[RoleKey]:
        with self._lock:
            return list(self._resident.keys())

    def is_resident(self, key: RoleKey) -> bool:
        with self._lock:
            return key in self._resident

    def __len__(self) -> int:
        return len(self._resident)

    def __iter__(self) -> Iterator[Role]:
        return iter(self._resident.values())
