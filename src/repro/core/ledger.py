"""Overhead ledger — reproduces the accounting structure of paper Table II.

The paper decomposes the cost of transparent acceleration into exactly three
categories:

  ===================  =====================  =============================
  category             occurrence             FPGA meaning -> TPU meaning
  ===================  =====================  =============================
  device/kernel setup  once                   runtime+driver init, kernel
                                              registration -> hsa_init(),
                                              registry build, AOT synthesis
  reconfiguration      if not configured      partial bitstream load ->
                                              program/weights residency miss
  dispatch latency     every dispatch         AQL packet -> kernel launch
  ===================  =====================  =============================

All entries are *measured* wall times (perf_counter_ns), never simulated
constants.  ``table()`` renders the Table II layout; benchmarks/table2 uses it.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Any, Iterator

import contextlib

SETUP = "setup"
RECONFIG = "reconfig"
DISPATCH = "dispatch"
EXEC = "exec"                 # kernel execution proper (not in Table II, kept for Table III)
WAIT = "wait"                 # queue residency: submit -> launch grant (scheduler)

# Table II row 3 ("dispatch latency"), split along the packet round trip.
# One kernel invocation through the HSA layer costs the producer a full
# submit -> doorbell -> grant -> completion-wait cycle; fused multi-token
# decode and burst AQL submission amortize exactly these three host-side
# legs, so they are ledgered separately (DISPATCH keeps the legacy
# launch-call measurement for Table II continuity):
#
#   dispatch_submit  producer writes the packet(s) + rings the doorbell
#                    (one doorbell per *burst*: submit_burst divides the
#                    measured cost over its N packets)
#   dispatch_grant   scheduler host time from picking the packet up to the
#                    launch call returning (the grant leg of the round trip)
#   dispatch_wait    producer blocks on the completion signal(s) (one
#                    wait_all over a burst divides over its N packets)
DISPATCH_SUBMIT = "dispatch_submit"
DISPATCH_GRANT = "dispatch_grant"
DISPATCH_WAIT = "dispatch_wait"

# Table II row 2, split by whether the load stalled a queue.  RECONFIG keeps
# the *measured* load time (recorded by RegionManager at the choke point);
# the scheduler additionally attributes each load's schedule time as
# *exposed* (the issuing queue sat stalled) or *hidden* (overlapped with
# compute by the lookahead prefetcher).  exposed + hidden reconstructs the
# scheduler-clock reconfiguration total; driving exposed toward zero is the
# prefetch pipeline's whole point.
RECONFIG_EXPOSED = "reconfig_exposed"
RECONFIG_HIDDEN = "reconfig_hidden"

# Overcommitted paged serving (Table I "overcommit" row): the host time spent
# reclaiming a victim's KV pages (park, incl. the optional snapshot gather)
# and bringing a parked request back (resume: snapshot restore, or the
# re-prefill's extra prefill — the *replayed decode* rides the normal decode
# categories and is accounted as recompute_tokens, not time, because it is
# indistinguishable from useful work at the launch level).
PREEMPT_PARK = "preempt_park"
PREEMPT_RESUME = "preempt_resume"

# Serving latency under live traffic (the table9 SLO metrics).  One TTFT
# sample per request (arrival -> first generated token, engine clock) and one
# TPOT sample per request (mean inter-token time over its decode phase).
# Both ride the same bounded quantile windows as dispatch_wait, so
# ``quantile()`` gives the recent p50/p99 a feeder-facing SLO check wants —
# not an all-time mean that a warmup spike poisons forever.
TTFT = "ttft"
TPOT = "tpot"

# Fault tolerance (the self-healing runtime's availability accounting).
# FAULT is the schedule time an attempt lost to an injected/real failure
# (a wedged launch charges its whole watchdog window); RETRY is backoff
# delay spent between attempts; RECOVER is engine-clock time from a
# request's fault-park to its successful resume (MTTR samples).
FAULT = "fault"
RETRY = "retry"
RECOVER = "recover"

# Tiered KV page pool (the host-arena second tier).  SPILL is the D2H DMA
# time parking a snapshot into the arena (engine-timeline: it never stalls
# compute — the gather already happened, only later refills queue behind
# it).  REFILL is the H2D DMA duration bringing a snapshot back; like
# reconfiguration it splits into *exposed* (the resume step sat stalled on
# the transfer) vs *hidden* (the ahead-of-need pump issued it early enough
# to overlap decode) — driving exposed toward zero is what the refill
# lookahead exists for.
SPILL = "spill"
REFILL = "refill"
REFILL_EXPOSED = "refill_exposed"
REFILL_HIDDEN = "refill_hidden"

# Data integrity (silent-corruption detection).  SCRUB is host time the
# step-driven background audit spends re-hashing cold device pages and
# parked arena blocks — the audit-overhead numerator integrity_split()
# grades against total step time.
SCRUB = "scrub"

CATEGORIES = (SETUP, RECONFIG, RECONFIG_EXPOSED, RECONFIG_HIDDEN, DISPATCH,
              DISPATCH_SUBMIT, DISPATCH_GRANT, DISPATCH_WAIT, EXEC, WAIT,
              PREEMPT_PARK, PREEMPT_RESUME, TTFT, TPOT,
              FAULT, RETRY, RECOVER,
              SPILL, REFILL, REFILL_EXPOSED, REFILL_HIDDEN,
              SCRUB)

OCCURRENCE = {
    SETUP: "once",
    RECONFIG: "if not configured",
    RECONFIG_EXPOSED: "if not configured",
    RECONFIG_HIDDEN: "if not configured",
    DISPATCH: "every dispatch",
    DISPATCH_SUBMIT: "every dispatch",
    DISPATCH_GRANT: "every dispatch",
    DISPATCH_WAIT: "every dispatch",
    EXEC: "every dispatch",
    WAIT: "every dispatch",
    PREEMPT_PARK: "on pool pressure",
    PREEMPT_RESUME: "per resume",
    TTFT: "per request",
    TPOT: "per request",
    FAULT: "on fault",
    RETRY: "per retry",
    RECOVER: "per recovery",
    SPILL: "on spill",
    REFILL: "per refill",
    REFILL_EXPOSED: "per refill",
    REFILL_HIDDEN: "per refill",
    SCRUB: "per scrub pass",
}


@dataclasses.dataclass
class Entry:
    category: str
    seconds: float
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Stat:
    count: int = 0
    total_s: float = 0.0
    min_s: float = math.inf
    max_s: float = 0.0

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total_s += seconds
        self.min_s = min(self.min_s, seconds)
        self.max_s = max(self.max_s, seconds)

    @property
    def mean_us(self) -> float:
        return (self.total_s / self.count) * 1e6 if self.count else 0.0


#: bounded per-(producer, category) sample window backing ``quantile()`` —
#: large enough for a stable p99, small enough to track regime changes
#: (the feedback FusionPolicy wants "recent contention", not all-time).
QUANTILE_WINDOW = 256


class OverheadLedger:
    """Thread-safe accumulator of measured runtime overheads."""

    _PREEMPT_ZERO = {
        "preemptions": 0.0, "resumes": 0.0, "pages_reclaimed": 0.0,
        "recompute_tokens": 0.0, "snapshot_resumes": 0.0,
        "reprefill_resumes": 0.0, "snapshot_bytes": 0.0,
    }

    _FAULT_ZERO = {
        "faults": 0.0, "exec_faults": 0.0, "load_faults": 0.0,
        "wedges": 0.0, "permanent_faults": 0.0, "transfer_faults": 0.0,
        "retries": 0.0,
        "quarantines": 0.0, "migrated_packets": 0.0,
        "recoveries": 0.0, "failed_requests": 0.0,
        "recovery_recompute_tokens": 0.0, "mttr_total_s": 0.0,
    }

    _SPILL_ZERO = {
        "spills": 0.0, "refills": 0.0, "spill_bytes": 0.0,
        "refill_bytes": 0.0, "demotions": 0.0, "demoted_bytes": 0.0,
        "replay_fallback_tokens": 0.0,
        "host_used_bytes": 0.0, "host_peak_bytes": 0.0,
        "host_budget_bytes": math.inf,   # inf = unbounded / no budget set
    }

    _INTEGRITY_ZERO = {
        "corruptions": 0.0,
        "corrupt_pages": 0.0, "corrupt_blocks": 0.0,
        "corrupt_transfers": 0.0, "stale_regions": 0.0,
        "detected": 0.0,
        "detected_scrub": 0.0, "detected_read": 0.0,
        "detected_transfer": 0.0, "detected_region": 0.0,
        "integrity_recoveries": 0.0,
        "scrubbed_pages": 0.0, "scrubbed_blocks": 0.0,
        "scrub_targets": 0.0,
        "quarantined_pages": 0.0,
        "verified_transfers": 0.0, "verified_regions": 0.0,
        "escaped": 0.0,   # corruption that influenced a sampled token
    }

    _CORRUPTION_KEY = {
        "flip_page": "corrupt_pages", "flip_block": "corrupt_blocks",
        "corrupt_transfer": "corrupt_transfers",
        "stale_region": "stale_regions",
    }

    _PREFIX_ZERO = {
        "prefix_lookups": 0.0, "prefix_hits": 0.0,
        "shared_pages": 0.0,        # gauge: pages with refcount > 1 now
        "peak_shared_pages": 0.0,
        "pages_saved": 0.0,         # private prompt-page allocations avoided
        "cow_copies": 0.0,          # re-prefills forced by the CoW paths
    }

    def __init__(self, keep_entries: bool = False) -> None:
        self._lock = threading.Lock()
        self._stats: dict[str, Stat] = {c: Stat() for c in CATEGORIES}
        self._entries: list[Entry] | None = [] if keep_entries else None
        self._by_queue: dict[str, dict[str, Stat]] = {}
        self._by_producer: dict[str, dict[str, Stat]] = {}
        # (producer|None, category) -> ring of recent samples
        self._recent: dict[tuple[str | None, str], deque[float]] = {}
        self._memory: dict[str, dict[str, float]] = {}
        self._preempt: dict[str, float] = dict(self._PREEMPT_ZERO)
        self._fault: dict[str, float] = dict(self._FAULT_ZERO)
        self._spill: dict[str, float] = dict(self._SPILL_ZERO)
        self._integrity: dict[str, float] = dict(self._INTEGRITY_ZERO)
        self._prefix: dict[str, float] = dict(self._PREFIX_ZERO)

    def record(self, category: str, seconds: float, **meta: Any) -> None:
        if category not in self._stats:
            raise ValueError(f"unknown ledger category {category!r}")
        with self._lock:
            self._stats[category].add(seconds)
            self._recent.setdefault(
                (None, category), deque(maxlen=QUANTILE_WINDOW)
            ).append(seconds)
            if "queue" in meta and meta["queue"] is not None:
                per_q = self._by_queue.setdefault(str(meta["queue"]), {})
                per_q.setdefault(category, Stat()).add(seconds)
            if "producer" in meta and meta["producer"] is not None:
                producer = str(meta["producer"])
                per_p = self._by_producer.setdefault(producer, {})
                per_p.setdefault(category, Stat()).add(seconds)
                self._recent.setdefault(
                    (producer, category), deque(maxlen=QUANTILE_WINDOW)
                ).append(seconds)
            if self._entries is not None:
                self._entries.append(Entry(category, seconds, meta))

    def quantile(self, category: str, q: float,
                 producer: str | None = None) -> float | None:
        """Empirical quantile over the recent sample window (None if empty).

        ``producer=`` restricts to that producer's samples — the feedback
        :class:`~repro.core.policy.FusionPolicy` reads the p99 of *foreign*
        producers' ``dispatch_wait`` here to decide how hard serving may
        lean on the shared device.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            window = self._recent.get((producer, category))
            if not window:
                return None
            ordered = sorted(window)
        idx = min(len(ordered) - 1, int(math.ceil(q * len(ordered))) - 1)
        return ordered[max(0, idx)]

    def producers(self) -> list[str]:
        with self._lock:
            return sorted(self._by_producer)

    @contextlib.contextmanager
    def timed(self, category: str, **meta: Any) -> Iterator[None]:
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            self.record(category, (time.perf_counter_ns() - t0) * 1e-9, **meta)

    def stat(self, category: str) -> Stat:
        with self._lock:
            return dataclasses.replace(self._stats[category])

    def entries(self) -> list[Entry]:
        with self._lock:
            return list(self._entries or ())

    def queue_breakdown(self) -> dict[str, dict[str, Stat]]:
        """Per-queue stats for entries recorded with ``queue=`` meta
        (the scheduler's wait/exec/reconfig attribution)."""
        with self._lock:
            return {
                q: {c: dataclasses.replace(s) for c, s in per_q.items()}
                for q, per_q in self._by_queue.items()
            }

    def producer_breakdown(self) -> dict[str, dict[str, Stat]]:
        """Per-producer stats for entries recorded with ``producer=`` meta —
        the dispatch_submit/grant/wait split Table II's invocation row
        decomposes into, attributed to whoever pays it (the TF serving
        engine, an OpenCL-style tenant, ...)."""
        with self._lock:
            return {
                p: {c: dataclasses.replace(s) for c, s in per_p.items()}
                for p, per_p in self._by_producer.items()
            }

    def reset(self) -> None:
        with self._lock:
            self._stats = {c: Stat() for c in CATEGORIES}
            self._by_queue = {}
            self._by_producer = {}
            self._recent = {}
            self._memory = {}
            self._preempt = dict(self._PREEMPT_ZERO)
            self._fault = dict(self._FAULT_ZERO)
            self._spill = dict(self._SPILL_ZERO)
            self._integrity = dict(self._INTEGRITY_ZERO)
            self._prefix = dict(self._PREFIX_ZERO)
            if self._entries is not None:
                self._entries = []

    # -- memory accounting (Table I utilization) -----------------------------

    def record_memory(self, *, reserved_bytes: float, used_bytes: float,
                      label: str = "kv_cache") -> None:
        """Record a point-in-time memory split for ``label``.

        ``reserved_bytes`` is the capacity held against *admitted* requests
        (dense: live slots × max_len rows; paged: mapped pages) —
        reservation, not physical allocation: an idle slot or free page is
        available capacity, not stranded.  ``used_bytes`` is the portion
        actually carrying cached tokens.  The difference is **stranded** —
        reserved capacity no other request can use, the quantity the paged
        cache exists to crush.  Latest values and peaks are kept per label.
        """
        if used_bytes > reserved_bytes + 1e-9:
            raise ValueError(
                f"used {used_bytes} > reserved {reserved_bytes} for {label!r}"
            )
        with self._lock:
            m = self._memory.setdefault(label, {
                "reserved_bytes": 0.0, "used_bytes": 0.0,
                "stranded_bytes": 0.0, "peak_reserved_bytes": 0.0,
                "peak_stranded_bytes": 0.0, "samples": 0.0,
            })
            m["reserved_bytes"] = float(reserved_bytes)
            m["used_bytes"] = float(used_bytes)
            m["stranded_bytes"] = float(reserved_bytes - used_bytes)
            m["peak_reserved_bytes"] = max(m["peak_reserved_bytes"],
                                           float(reserved_bytes))
            m["peak_stranded_bytes"] = max(m["peak_stranded_bytes"],
                                           float(reserved_bytes - used_bytes))
            m["samples"] += 1.0

    def record_host_memory(self, *, used_bytes: float,
                           budget_bytes: float | None = None) -> None:
        """Record a point-in-time host-arena occupancy sample (the page
        pool's second tier).  ``budget_bytes=None`` means unbounded and is
        reported as ``inf`` — distinguishable from a genuine zero budget
        (a valid configuration: every park demotes to replay)."""
        budget = math.inf if budget_bytes is None else float(budget_bytes)
        if used_bytes > budget + 1e-9:
            raise ValueError(
                f"host used {used_bytes} > budget {budget} — the arena "
                "crossed its hard ceiling"
            )
        with self._lock:
            self._spill["host_used_bytes"] = float(used_bytes)
            self._spill["host_peak_bytes"] = max(
                self._spill["host_peak_bytes"], float(used_bytes)
            )
            self._spill["host_budget_bytes"] = budget

    def memory_split(self, label: str = "kv_cache") -> dict[str, float]:
        """Reserved vs used vs stranded bytes for ``label`` (Table I row).

        ``utilization`` = used / reserved of the latest sample (1.0 when
        nothing is reserved: an empty pool strands nothing).  The host-tier
        rows (``host_used_bytes`` / ``host_peak_bytes`` /
        ``host_budget_bytes``) ride along so one call prices both tiers of
        the page pool.
        """
        with self._lock:
            m = dict(self._memory.get(label, {}))
            host = {k: self._spill[k] for k in
                    ("host_used_bytes", "host_peak_bytes",
                     "host_budget_bytes")}
        if not m:
            m = {"reserved_bytes": 0.0, "used_bytes": 0.0,
                 "stranded_bytes": 0.0, "peak_reserved_bytes": 0.0,
                 "peak_stranded_bytes": 0.0, "samples": 0.0}
        m["utilization"] = (
            m["used_bytes"] / m["reserved_bytes"] if m["reserved_bytes"] else 1.0
        )
        m.update(host)
        return m

    # -- overcommit accounting (Table I "overcommit" row) --------------------

    def record_preemption(self, *, pages_reclaimed: int,
                          snapshot_bytes: int = 0) -> None:
        """One victim parked: its pages went back to the pool; a snapshot
        park additionally copied ``snapshot_bytes`` of KV to the host."""
        with self._lock:
            self._preempt["preemptions"] += 1.0
            self._preempt["pages_reclaimed"] += float(pages_reclaimed)
            self._preempt["snapshot_bytes"] += float(snapshot_bytes)

    def record_resume(self, *, mode: str, recompute_tokens: int = 0) -> None:
        """One parked request resumed.  ``recompute_tokens`` is the wasted
        work of the re-prefill path (prompt recompute + generated-token
        replay); a snapshot resume wastes none."""
        with self._lock:
            self._preempt["resumes"] += 1.0
            self._preempt["recompute_tokens"] += float(recompute_tokens)
            key = ("snapshot_resumes" if mode == "snapshot"
                   else "reprefill_resumes")
            self._preempt[key] += 1.0

    def overcommit_split(self) -> dict[str, float]:
        """Preemption counters + timings for the Table I "overcommit" row.

        ``preemption_rate`` is preemptions per recorded launch
        (``dispatch_wait`` samples — only populated when serving routes
        through an HSA queue).  ``launches`` is exposed alongside so a rate
        of 0.0 from an unwired ledger is distinguishable from a genuinely
        preemption-free run; consumers wanting the raw count read
        ``preemptions``.  ``snapshot_bytes`` is *net* of demotions: a
        snapshot demoted to replay gives its bytes back (see
        :meth:`record_demotion`), so a demote-then-re-park cycle does not
        double-count."""
        with self._lock:
            out = dict(self._preempt)
            out["park_s"] = self._stats[PREEMPT_PARK].total_s
            out["resume_s"] = self._stats[PREEMPT_RESUME].total_s
            launches = self._stats[DISPATCH_WAIT].count
        out["launches"] = float(launches)
        out["preemption_rate"] = (
            out["preemptions"] / launches if launches else 0.0
        )
        return out

    # -- tiered-pool accounting (host arena spill/refill) --------------------

    def record_spill(self, *, nbytes: int) -> None:
        """One snapshot spilled D2H into the host arena (DMA seconds ride
        the SPILL category via ``record``)."""
        with self._lock:
            self._spill["spills"] += 1.0
            self._spill["spill_bytes"] += float(nbytes)

    def record_refill(self, *, nbytes: int) -> None:
        """One snapshot refilled H2D out of the arena (duration and its
        exposed/hidden split ride REFILL / REFILL_EXPOSED / REFILL_HIDDEN)."""
        with self._lock:
            self._spill["refills"] += 1.0
            self._spill["refill_bytes"] += float(nbytes)

    def record_demotion(self, *, bytes_freed: int,
                        replay_tokens: int) -> None:
        """One parked snapshot demoted to re-prefill replay: its arena bytes
        went back to the budget and ``replay_tokens`` of recompute were
        accepted in exchange.  The freed bytes also come *off* the
        overcommit ``snapshot_bytes`` counter — a demoted snapshot no longer
        holds host memory, and a later re-park of the same request must not
        count its bytes twice."""
        with self._lock:
            self._spill["demotions"] += 1.0
            self._spill["demoted_bytes"] += float(bytes_freed)
            self._spill["replay_fallback_tokens"] += float(replay_tokens)
            self._preempt["snapshot_bytes"] = max(
                0.0, self._preempt["snapshot_bytes"] - float(bytes_freed)
            )

    def spill_split(self) -> dict[str, float]:
        """Tiered-pool counters + timings (the table11 view).

        Byte flows (spill/refill/demoted), host occupancy vs budget, the
        replay tokens demotions cost, and the refill time split into exposed
        (a resume stalled on the DMA) vs hidden (the lookahead pump issued
        it early enough to overlap decode).  ``refill_hidden_frac`` is
        hidden / (hidden + exposed), 0.0 when no refills ran."""
        with self._lock:
            out = dict(self._spill)
            out["spill_s"] = self._stats[SPILL].total_s
            out["refill_s"] = self._stats[REFILL].total_s
            out["refill_exposed_s"] = self._stats[REFILL_EXPOSED].total_s
            out["refill_hidden_s"] = self._stats[REFILL_HIDDEN].total_s
            out["transfer_faults"] = self._fault["transfer_faults"]
        split = out["refill_exposed_s"] + out["refill_hidden_s"]
        out["refill_hidden_frac"] = (
            out["refill_hidden_s"] / split if split else 0.0
        )
        return out

    # -- availability accounting (fault injection + self-healing) ------------

    def record_fault(self, *, kind: str, permanent: bool = False) -> None:
        """One failed attempt.  ``kind`` is ``"exec"``, ``"load"``,
        ``"wedge"``, or a tier-transfer kind ``"d2h"`` / ``"h2d"`` (a wedge
        is counted as an exec-class fault too — it is a launch that never
        completed).  ``permanent`` marks faults the retry policy is
        forbidden to absorb."""
        if kind not in ("exec", "load", "wedge", "d2h", "h2d"):
            raise ValueError(f"unknown fault kind {kind!r}")
        with self._lock:
            self._fault["faults"] += 1.0
            if kind == "load":
                self._fault["load_faults"] += 1.0
            elif kind in ("d2h", "h2d"):
                self._fault["transfer_faults"] += 1.0
            else:
                self._fault["exec_faults"] += 1.0
                if kind == "wedge":
                    self._fault["wedges"] += 1.0
            if permanent:
                self._fault["permanent_faults"] += 1.0

    def record_retry(self) -> None:
        """One retry attempt issued after a fault (backoff seconds ride the
        RETRY category via ``record``)."""
        with self._lock:
            self._fault["retries"] += 1.0

    def record_quarantine(self, *, migrated: int) -> None:
        """One queue quarantined; ``migrated`` pending packets moved to
        sibling queues."""
        with self._lock:
            self._fault["quarantines"] += 1.0
            self._fault["migrated_packets"] += float(migrated)

    def record_recovery(self, *, mttr_s: float = 0.0,
                        recompute_tokens: int = 0,
                        failed: bool = False) -> None:
        """One request-level recovery outcome.  A successful recovery samples
        ``mttr_s`` (engine clock, fault-park -> resumed) and the re-prefill
        replay's wasted ``recompute_tokens``; ``failed=True`` counts a
        request whose recovery budget ran out instead."""
        with self._lock:
            if failed:
                self._fault["failed_requests"] += 1.0
            else:
                self._fault["recoveries"] += 1.0
                self._fault["mttr_total_s"] += float(mttr_s)
                self._fault["recovery_recompute_tokens"] += float(
                    recompute_tokens)

    def availability_split(self) -> dict[str, float]:
        """Fault/retry/recovery counters + timings (the table10 view).

        ``fault_rate`` is faults per attempt, where attempts = successful
        execs + faulted attempts (so a fault-free ledger reads 0.0 and a
        ledger that never executed reads 0.0 with ``attempts`` = 0 —
        distinguishable).  ``mttr_s`` is the mean engine-clock time from a
        request's fault-park to its resume."""
        with self._lock:
            out = dict(self._fault)
            out["fault_s"] = self._stats[FAULT].total_s
            out["retry_backoff_s"] = self._stats[RETRY].total_s
            out["recover_s"] = self._stats[RECOVER].total_s
            execs = self._stats[EXEC].count
        out["attempts"] = float(execs) + out["faults"]
        out["fault_rate"] = (
            out["faults"] / out["attempts"] if out["attempts"] else 0.0
        )
        out["mttr_s"] = (
            out["mttr_total_s"] / out["recoveries"] if out["recoveries"]
            else 0.0
        )
        return out

    # -- integrity accounting (silent-corruption detection) ------------------

    def record_corruption(self, *, kind: str) -> None:
        """One silent corruption injected (or observed).  ``kind`` is
        ``"flip_page"`` | ``"flip_block"`` | ``"corrupt_transfer"`` |
        ``"stale_region"`` — the four state tiers."""
        key = self._CORRUPTION_KEY.get(kind)
        if key is None:
            raise ValueError(f"unknown corruption kind {kind!r}")
        with self._lock:
            self._integrity["corruptions"] += 1.0
            self._integrity[key] += 1.0

    def record_integrity_detection(self, *, via: str,
                                   recovered: bool = False) -> None:
        """One corruption caught by verification.  ``via`` names the
        detection site: ``"scrub"`` (background audit), ``"read"``
        (pre-commit page verification after a decode launch),
        ``"transfer"`` (DMA payload digest), ``"region"`` (region-image
        digest).  ``recovered=True`` additionally counts the park/demote
        that healed it."""
        if via not in ("scrub", "read", "transfer", "region"):
            raise ValueError(f"unknown detection site {via!r}")
        with self._lock:
            self._integrity["detected"] += 1.0
            self._integrity[f"detected_{via}"] += 1.0
            if recovered:
                self._integrity["integrity_recoveries"] += 1.0

    def record_scrub(self, *, pages: int = 0, blocks: int = 0,
                     targets: int = 0) -> None:
        """One scrub pass: ``pages`` device pages and ``blocks`` arena
        blocks re-hashed out of ``targets`` total auditable targets (the
        coverage denominator; audit seconds ride the SCRUB category)."""
        with self._lock:
            self._integrity["scrubbed_pages"] += float(pages)
            self._integrity["scrubbed_blocks"] += float(blocks)
            self._integrity["scrub_targets"] += float(targets)

    def record_page_quarantine(self) -> None:
        """One device page retired from circulation after a digest
        mismatch (the pool shrinks by one page)."""
        with self._lock:
            self._integrity["quarantined_pages"] += 1.0

    def record_verified_transfer(self) -> None:
        """One DMA payload digest-checked (clean or not)."""
        with self._lock:
            self._integrity["verified_transfers"] += 1.0

    def record_verified_region(self) -> None:
        """One region image digest-checked after a load (clean or not)."""
        with self._lock:
            self._integrity["verified_regions"] += 1.0

    def record_escape(self) -> None:
        """One corruption whose bytes influenced a sampled token before
        any verification caught it — the number every integrity
        configuration worth shipping holds at zero."""
        with self._lock:
            self._integrity["escaped"] += 1.0

    def integrity_split(self) -> dict[str, float]:
        """Silent-corruption counters + audit timing (the table12 view).

        ``detection_rate`` is detected / injected (0.0 on a corruption-free
        ledger, not a ZeroDivisionError — latent corruption whose page was
        freed before any read keeps it below 1.0 without an escape).
        ``scrub_coverage`` is targets re-hashed per pass averaged over
        passes, 0.0 when nothing was auditable.  ``audit_s`` is SCRUB time;
        callers grade it against their own step-time denominator."""
        with self._lock:
            out = dict(self._integrity)
            out["audit_s"] = self._stats[SCRUB].total_s
            out["scrub_passes"] = float(self._stats[SCRUB].count)
        scanned = out["scrubbed_pages"] + out["scrubbed_blocks"]
        out["scrub_coverage"] = (
            scanned / out["scrub_targets"] if out["scrub_targets"] else 0.0
        )
        out["detection_rate"] = (
            out["detected"] / out["corruptions"] if out["corruptions"]
            else 0.0
        )
        return out

    # -- prefix-sharing accounting (the KV hit-rate view) --------------------

    def record_prefix_lookup(self, *, hit: bool, pages_saved: int = 0) -> None:
        """One admission-time prefix probe.  ``hit=True`` means the request
        attached to at least ``PrefixPolicy.min_prefix_pages`` resident
        pages; ``pages_saved`` is the private prompt-page allocations (and
        their prefill rows) the attach avoided."""
        with self._lock:
            self._prefix["prefix_lookups"] += 1.0
            if hit:
                self._prefix["prefix_hits"] += 1.0
                self._prefix["pages_saved"] += float(pages_saved)

    def record_prefix_sharing(self, *, shared_pages: int) -> None:
        """Gauge update: pages currently held by more than one reader."""
        with self._lock:
            self._prefix["shared_pages"] = float(shared_pages)
            self._prefix["peak_shared_pages"] = max(
                self._prefix["peak_shared_pages"], float(shared_pages)
            )

    def record_prefix_cow(self, n: int = 1) -> None:
        """``n`` copy-on-write re-prefills: readers that lost their shared
        pages (quarantine of the page, or a parked snapshot whose prefix
        evaporated before resume) and rebuilt them privately."""
        with self._lock:
            self._prefix["cow_copies"] += float(n)

    def prefix_split(self) -> dict[str, float]:
        """Prefix-sharing counters (the table13 view).  ``hit_rate`` is
        hits / lookups — the KV analogue of Table II's
        ``if_not_configured`` fraction — 0.0 on an empty ledger."""
        with self._lock:
            out = dict(self._prefix)
        out["hit_rate"] = (
            out["prefix_hits"] / out["prefix_lookups"]
            if out["prefix_lookups"] else 0.0
        )
        return out

    def reconfig_split(self) -> dict[str, float]:
        """Exposed vs hidden reconfiguration time (scheduler-clock seconds).

        ``measured_s`` is the RegionManager's real load total; ``exposed_s``
        is schedule time during which a queue sat stalled on the load;
        ``hidden_s`` ran on the reconfiguration engine behind compute."""
        with self._lock:
            exposed = self._stats[RECONFIG_EXPOSED]
            hidden = self._stats[RECONFIG_HIDDEN]
            measured = self._stats[RECONFIG]
            return {
                "measured_s": measured.total_s,
                "exposed_s": exposed.total_s,
                "hidden_s": hidden.total_s,
                "exposed_n": float(exposed.count),
                "hidden_n": float(hidden.count),
            }

    def traffic_split(self) -> dict[str, float]:
        """Serving-latency quantiles under live traffic (table9's SLO view).

        For each of TTFT and TPOT: sample count, mean, and the p50/p99 of
        the recent quantile window.  Quantiles are 0.0 when no samples
        exist — callers grading SLOs should check ``*_n`` first so an
        unwired ledger is distinguishable from a perfectly fast one.
        """
        out: dict[str, float] = {}
        for cat in (TTFT, TPOT):
            s = self.stat(cat)
            out[f"{cat}_n"] = float(s.count)
            out[f"{cat}_mean_s"] = s.total_s / s.count if s.count else 0.0
            for q, name in ((0.5, "p50"), (0.99, "p99")):
                v = self.quantile(cat, q)
                out[f"{cat}_{name}_s"] = v if v is not None else 0.0
        return out

    def dispatch_split(self) -> dict[str, float]:
        """Invocation-overhead round trip, split per leg (Table II row 3).

        Totals and counts for dispatch_submit / dispatch_grant /
        dispatch_wait, plus ``per_packet_us`` (sum of the three legs divided
        by the submit count — the per-packet invocation cost fused decode and
        burst submission amortize)."""
        with self._lock:
            sub = self._stats[DISPATCH_SUBMIT]
            grant = self._stats[DISPATCH_GRANT]
            wait = self._stats[DISPATCH_WAIT]
            total = sub.total_s + grant.total_s + wait.total_s
            n = max(sub.count, grant.count, wait.count)
            return {
                "submit_s": sub.total_s,
                "grant_s": grant.total_s,
                "wait_s": wait.total_s,
                "submit_n": float(sub.count),
                "grant_n": float(grant.count),
                "wait_n": float(wait.count),
                "total_s": total,
                "per_packet_us": (total / n) * 1e6 if n else 0.0,
            }

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {
                c: {
                    "count": float(s.count),
                    "mean_us": s.mean_us,
                    "total_us": s.total_s * 1e6,
                }
                for c, s in self._stats.items()
            }

    def table(self) -> str:
        """Paper Table II layout: operation | occurrence | mean microseconds."""
        rows = [("Operation", "Occurrence", "Mean [us]", "n")]
        split_rows = (RECONFIG_EXPOSED, RECONFIG_HIDDEN,
                      DISPATCH_SUBMIT, DISPATCH_GRANT, DISPATCH_WAIT)
        for cat in (SETUP, RECONFIG, RECONFIG_EXPOSED, RECONFIG_HIDDEN,
                    DISPATCH, DISPATCH_SUBMIT, DISPATCH_GRANT, DISPATCH_WAIT):
            s = self.stat(cat)
            label = {
                SETUP: "device/kernel setup",
                RECONFIG: "reconfiguration",
                RECONFIG_EXPOSED: "  - exposed (queue stalled)",
                RECONFIG_HIDDEN: "  - hidden (prefetched)",
                DISPATCH: "dispatch latency",
                DISPATCH_SUBMIT: "  - submit (packet + doorbell)",
                DISPATCH_GRANT: "  - grant (scheduler launch)",
                DISPATCH_WAIT: "  - wait (completion signal)",
            }[cat]
            if cat in split_rows and s.count == 0:
                continue                   # keep the paper's 3-row layout unless split
            rows.append((label, OCCURRENCE[cat], f"{s.mean_us:.1f}", str(s.count)))
        widths = [max(len(r[i]) for r in rows) for i in range(4)]
        lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
        lines.insert(1, "-" * len(lines[0]))
        return "\n".join(lines)


GLOBAL_LEDGER = OverheadLedger(keep_entries=False)
