"""Overhead ledger — reproduces the accounting structure of paper Table II.

The paper decomposes the cost of transparent acceleration into exactly three
categories:

  ===================  =====================  =============================
  category             occurrence             FPGA meaning -> TPU meaning
  ===================  =====================  =============================
  device/kernel setup  once                   runtime+driver init, kernel
                                              registration -> hsa_init(),
                                              registry build, AOT synthesis
  reconfiguration      if not configured      partial bitstream load ->
                                              program/weights residency miss
  dispatch latency     every dispatch         AQL packet -> kernel launch
  ===================  =====================  =============================

All entries are *measured* wall times (perf_counter_ns), never simulated
constants.  ``table()`` renders the Table II layout; benchmarks/table2 uses it.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Iterator

import contextlib

SETUP = "setup"
RECONFIG = "reconfig"
DISPATCH = "dispatch"
EXEC = "exec"                 # kernel execution proper (not in Table II, kept for Table III)
WAIT = "wait"                 # queue residency: submit -> launch grant (scheduler)

# Table II row 2, split by whether the load stalled a queue.  RECONFIG keeps
# the *measured* load time (recorded by RegionManager at the choke point);
# the scheduler additionally attributes each load's schedule time as
# *exposed* (the issuing queue sat stalled) or *hidden* (overlapped with
# compute by the lookahead prefetcher).  exposed + hidden reconstructs the
# scheduler-clock reconfiguration total; driving exposed toward zero is the
# prefetch pipeline's whole point.
RECONFIG_EXPOSED = "reconfig_exposed"
RECONFIG_HIDDEN = "reconfig_hidden"

CATEGORIES = (SETUP, RECONFIG, RECONFIG_EXPOSED, RECONFIG_HIDDEN, DISPATCH,
              EXEC, WAIT)

OCCURRENCE = {
    SETUP: "once",
    RECONFIG: "if not configured",
    RECONFIG_EXPOSED: "if not configured",
    RECONFIG_HIDDEN: "if not configured",
    DISPATCH: "every dispatch",
    EXEC: "every dispatch",
    WAIT: "every dispatch",
}


@dataclasses.dataclass
class Entry:
    category: str
    seconds: float
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Stat:
    count: int = 0
    total_s: float = 0.0
    min_s: float = math.inf
    max_s: float = 0.0

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total_s += seconds
        self.min_s = min(self.min_s, seconds)
        self.max_s = max(self.max_s, seconds)

    @property
    def mean_us(self) -> float:
        return (self.total_s / self.count) * 1e6 if self.count else 0.0


class OverheadLedger:
    """Thread-safe accumulator of measured runtime overheads."""

    def __init__(self, keep_entries: bool = False) -> None:
        self._lock = threading.Lock()
        self._stats: dict[str, Stat] = {c: Stat() for c in CATEGORIES}
        self._entries: list[Entry] | None = [] if keep_entries else None
        self._by_queue: dict[str, dict[str, Stat]] = {}

    def record(self, category: str, seconds: float, **meta: Any) -> None:
        if category not in self._stats:
            raise ValueError(f"unknown ledger category {category!r}")
        with self._lock:
            self._stats[category].add(seconds)
            if "queue" in meta and meta["queue"] is not None:
                per_q = self._by_queue.setdefault(str(meta["queue"]), {})
                per_q.setdefault(category, Stat()).add(seconds)
            if self._entries is not None:
                self._entries.append(Entry(category, seconds, meta))

    @contextlib.contextmanager
    def timed(self, category: str, **meta: Any) -> Iterator[None]:
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            self.record(category, (time.perf_counter_ns() - t0) * 1e-9, **meta)

    def stat(self, category: str) -> Stat:
        with self._lock:
            return dataclasses.replace(self._stats[category])

    def entries(self) -> list[Entry]:
        with self._lock:
            return list(self._entries or ())

    def queue_breakdown(self) -> dict[str, dict[str, Stat]]:
        """Per-queue stats for entries recorded with ``queue=`` meta
        (the scheduler's wait/exec/reconfig attribution)."""
        with self._lock:
            return {
                q: {c: dataclasses.replace(s) for c, s in per_q.items()}
                for q, per_q in self._by_queue.items()
            }

    def reset(self) -> None:
        with self._lock:
            self._stats = {c: Stat() for c in CATEGORIES}
            self._by_queue = {}
            if self._entries is not None:
                self._entries = []

    def reconfig_split(self) -> dict[str, float]:
        """Exposed vs hidden reconfiguration time (scheduler-clock seconds).

        ``measured_s`` is the RegionManager's real load total; ``exposed_s``
        is schedule time during which a queue sat stalled on the load;
        ``hidden_s`` ran on the reconfiguration engine behind compute."""
        with self._lock:
            exposed = self._stats[RECONFIG_EXPOSED]
            hidden = self._stats[RECONFIG_HIDDEN]
            measured = self._stats[RECONFIG]
            return {
                "measured_s": measured.total_s,
                "exposed_s": exposed.total_s,
                "hidden_s": hidden.total_s,
                "exposed_n": float(exposed.count),
                "hidden_n": float(hidden.count),
            }

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {
                c: {
                    "count": float(s.count),
                    "mean_us": s.mean_us,
                    "total_us": s.total_s * 1e6,
                }
                for c, s in self._stats.items()
            }

    def table(self) -> str:
        """Paper Table II layout: operation | occurrence | mean microseconds."""
        rows = [("Operation", "Occurrence", "Mean [us]", "n")]
        for cat in (SETUP, RECONFIG, RECONFIG_EXPOSED, RECONFIG_HIDDEN, DISPATCH):
            s = self.stat(cat)
            label = {
                SETUP: "device/kernel setup",
                RECONFIG: "reconfiguration",
                RECONFIG_EXPOSED: "  - exposed (queue stalled)",
                RECONFIG_HIDDEN: "  - hidden (prefetched)",
                DISPATCH: "dispatch latency",
            }[cat]
            if cat in (RECONFIG_EXPOSED, RECONFIG_HIDDEN) and s.count == 0:
                continue                   # keep the paper's 3-row layout unless split
            rows.append((label, OCCURRENCE[cat], f"{s.mean_us:.1f}", str(s.count)))
        widths = [max(len(r[i]) for r in rows) for i in range(4)]
        lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
        lines.insert(1, "-" * len(lines[0]))
        return "\n".join(lines)


GLOBAL_LEDGER = OverheadLedger(keep_entries=False)
