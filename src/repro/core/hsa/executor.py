"""Packet processor: the runtime half of transparent dispatch.

Consumes AQL packets from a queue and, for kernel dispatches:

  1. resolves the role in the library,
  2. ``RegionManager.ensure_resident`` — reconfigures (load + LRU evict) when
     the role is not currently on the device, recording ledger RECONFIG,
  3. launches the loaded executable (ledger DISPATCH = submit-to-launch time,
     paper Table II row 3),
  4. blocks for completion (ledger EXEC) and stores the result, then sets the
     completion signal to 0.

Supports synchronous draining (deterministic, used by tests/benchmarks) and a
background worker thread (used by the serving engine so multiple producers can
share the agent, per the paper's multi-tenancy claim).
"""

from __future__ import annotations

import threading
import time
from typing import Any

import jax

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.queue import BarrierAndPacket, KernelDispatchPacket, Packet, Queue
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary


class Executor:
    def __init__(
        self,
        regions: RegionManager,
        library: RoleLibrary,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
    ) -> None:
        self.regions = regions
        self.library = library
        self.ledger = ledger
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()

    # -- packet processing -------------------------------------------------------

    def _process(self, pkt: Packet) -> None:
        if isinstance(pkt, BarrierAndPacket):
            for dep in pkt.deps:
                dep.wait_eq(0)
            if pkt.completion is not None:
                pkt.completion.store(0)
            return

        assert isinstance(pkt, KernelDispatchPacket)
        try:
            role = self.library.get(pkt.role_key)
            self.regions.ensure_resident(role)

            t0 = time.perf_counter_ns()
            out = role(*pkt.args)                      # async dispatch
            t1 = time.perf_counter_ns()
            self.ledger.record(
                ledger_mod.DISPATCH, (t1 - t0) * 1e-9,
                role=role.name, producer=pkt.producer,
            )
            out = jax.block_until_ready(out)
            self.ledger.record(ledger_mod.EXEC, (time.perf_counter_ns() - t1) * 1e-9,
                               role=role.name)
            pkt.out.value = out
        except BaseException as e:                      # surface to waiter, don't kill worker
            pkt.out.error = e
        finally:
            if pkt.completion is not None:
                pkt.completion.store(0)

    def drain(self, queue: Queue) -> int:
        """Synchronously process everything currently in the queue."""
        n = 0
        while (pkt := queue.pop()) is not None:
            self._process(pkt)
            n += 1
        return n

    # -- background mode ------------------------------------------------------------

    def start(self, queue: Queue, poll_s: float = 0.0005) -> None:
        if self._worker is not None:
            raise RuntimeError("executor already running")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                if queue.doorbell.wait_ge(1, timeout=poll_s):
                    if self.drain(queue) == 0:
                        queue.doorbell.store(0)

        self._worker = threading.Thread(target=loop, name="hsa-executor", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        if self._worker is not None:
            self._stop.set()
            self._worker.join(timeout=5.0)
            self._worker = None


def run_packet_sync(executor: Executor, queue: Queue, pkt: KernelDispatchPacket) -> Any:
    """Helper: drain until this packet completes and return (or raise) its result."""
    executor.drain(queue)
    assert pkt.completion is not None
    pkt.completion.wait_eq(0)
    if pkt.out.error is not None:
        raise pkt.out.error
    return pkt.out.value
