"""Legacy single-queue executor, now a façade over the async scheduler.

The synchronous ``Executor`` API (drain / start / stop) is kept for existing
callers and benchmarks, but all packet processing lives in one place:
:class:`repro.core.hsa.scheduler.Scheduler`.  ``drain`` is the cooperative
single-consumer mode; ``start`` runs the scheduler's doorbell-driven worker
thread so multiple producers can share the agent, per the paper's
multi-tenancy claim.
"""

from __future__ import annotations

from typing import Any

from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.queue import KernelDispatchPacket, Queue
from repro.core.hsa.scheduler import Scheduler
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary


class Executor:
    def __init__(
        self,
        regions: RegionManager,
        library: RoleLibrary,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        scheduler: Scheduler | None = None,
    ) -> None:
        self.regions = regions
        self.library = library
        self.ledger = ledger
        self.scheduler = scheduler or Scheduler(regions, library, ledger=ledger)
        self._running = False

    def drain(self, queue: Queue) -> int:
        """Synchronously process everything currently submitted."""
        return self.scheduler.drain(queue)

    # -- background mode ------------------------------------------------------------

    def start(self, queue: Queue, poll_s: float = 0.0005) -> None:
        if self._running:
            raise RuntimeError("executor already running")
        if all(q is not queue for q in self.scheduler.queues):
            self.scheduler.add_queue(queue)
        self.scheduler.start(poll_s=poll_s)
        self._running = True

    def stop(self) -> None:
        if self._running:
            self.scheduler.stop()
            self._running = False


def run_packet_sync(executor: Executor, queue: Queue, pkt: KernelDispatchPacket) -> Any:
    """Helper: drain until this packet completes and return (or raise) its result."""
    executor.drain(queue)
    assert pkt.completion is not None
    pkt.completion.wait_eq(0)
    if pkt.out.error is not None:
        raise pkt.out.error
    return pkt.out.value
