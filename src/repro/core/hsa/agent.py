"""HSA agents: devices as the runtime sees them.

An agent wraps one ``jax.Device`` plus the memory-region descriptors the HSA
standard exposes (here: HBM + VMEM of the target chip, or host RAM for CPU
agents).  Discovery enumerates every visible device — the paper's "detects and
manages all the accessible HSA devices visible to the framework".
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.hw import DEFAULT_CHIP


@dataclasses.dataclass(frozen=True)
class MemoryRegion:
    name: str
    size_bytes: int
    kind: str                     # "global" (HBM/RAM) | "group" (VMEM/scratch)
    bandwidth_bps: float = 0.0


class Agent:
    """One kernel-dispatch-capable device."""

    def __init__(self, device: jax.Device, *, num_reconfig_regions: int = 4) -> None:
        self.device = device
        self.kind = device.platform            # "cpu" | "tpu" | "gpu"
        self.name = f"{self.kind}:{device.id}"
        self.num_reconfig_regions = num_reconfig_regions
        if self.kind == "tpu":
            chip = DEFAULT_CHIP
            self.regions = (
                MemoryRegion("HBM", chip.hbm_bytes, "global", chip.hbm_bw),
                MemoryRegion("VMEM", chip.vmem_bytes, "group"),
            )
        else:
            self.regions = (MemoryRegion("RAM", 16 * 1024**3, "global"),)
        self._queues: list[Any] = []

    # -- queues --------------------------------------------------------------

    def create_queue(
        self, size: int = 256, *, name: str | None = None, weight: int = 1
    ) -> "Any":
        from repro.core.hsa.queue import Queue

        q = Queue(agent=self, size=size, name=name, weight=weight)
        self._queues.append(q)
        return q

    @property
    def queues(self) -> list[Any]:
        return list(self._queues)

    # -- discovery -------------------------------------------------------------

    @staticmethod
    def discover(*, num_reconfig_regions: int = 4) -> list["Agent"]:
        return [
            Agent(d, num_reconfig_regions=num_reconfig_regions) for d in jax.devices()
        ]

    def __repr__(self) -> str:
        return f"Agent({self.name}, regions={len(self.regions)}, queues={len(self._queues)})"
