"""User-mode queues with AQL-style packets.

HSA dispatch works by writing an Architected Queuing Language packet into a
user-mode ring buffer and ringing a doorbell signal.  The packet types the
paper's runtime needs are kernel-dispatch and barrier-AND (dependency
fences) — both modeled here.  A kernel-dispatch packet may additionally
carry its own dependency signals (AQL header barrier bit + implicit fence):
the scheduler will not launch it until every dep reads 0.

Multiple producers (the training engine, the serving engine, ad-hoc
OpenCL/OpenMP-style user code) may submit to the same queue, and one agent
may own many *soft queues* — the multi-tenancy substrate the async scheduler
(:mod:`repro.core.hsa.scheduler`) round-robins across.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Sequence

from repro.core import ledger as ledger_mod
from repro.core.hsa.signal import Signal
from repro.core.roles import RoleKey

_QUEUE_IDS = itertools.count()
_BURST_IDS = itertools.count(1)


class Box:
    """Mutable result slot for a dispatch packet."""

    __slots__ = ("value", "error")

    def __init__(self) -> None:
        self.value: Any = None
        self.error: BaseException | None = None


@dataclasses.dataclass
class KernelDispatchPacket:
    """AQL kernel dispatch.

    Either ``role_key`` (region-managed role, participates in reconfiguration)
    or ``fn`` (pinned-shell service: executed directly, e.g. the serving
    engine's decode step) must be set.
    """

    role_key: RoleKey | None = None
    args: tuple[Any, ...] = ()
    fn: Callable[..., Any] | None = None
    deps: tuple[Signal, ...] = ()       # AQL barrier-bit dependencies
    completion: Signal | None = None
    out: Box = dataclasses.field(default_factory=Box)
    producer: str = "tf"                # who enqueued: "tf" | "opencl" | "openmp" | ...
    enqueue_t: float | None = None      # stamped by Queue.submit when a clock is attached
    burst_id: int | None = None         # set by submit_burst: shared by the whole burst
    burst_n: int = 1                    # packets in that burst (1 = plain submit)

    def __post_init__(self) -> None:
        if (self.role_key is None) == (self.fn is None):
            raise ValueError("exactly one of role_key / fn required")

    @property
    def what(self) -> str:
        return str(self.role_key) if self.role_key is not None else getattr(
            self.fn, "__name__", "fn"
        )


@dataclasses.dataclass
class BarrierAndPacket:
    deps: tuple[Signal, ...]
    completion: Signal | None = None
    enqueue_t: float | None = None
    burst_id: int | None = None
    burst_n: int = 1


Packet = KernelDispatchPacket | BarrierAndPacket


class QueueFullError(RuntimeError):
    pass


def dispatch_packet(
    role_key: RoleKey, *args: Any, producer: str = "tf",
    deps: Sequence[Signal] = (),
) -> KernelDispatchPacket:
    """Build (don't submit) a region-managed dispatch packet — the unit a
    burst is assembled from before one :meth:`Queue.submit_burst`."""
    return KernelDispatchPacket(
        role_key=role_key, args=args, deps=tuple(deps),
        completion=Signal(1, name=f"done:{role_key}"), producer=producer,
    )


def call_packet(
    fn: Callable[..., Any], *args: Any, producer: str = "tf",
    deps: Sequence[Signal] = (),
) -> KernelDispatchPacket:
    """Build (don't submit) a pinned-shell dispatch packet."""
    return KernelDispatchPacket(
        fn=fn, args=args, deps=tuple(deps),
        completion=Signal(1, name=f"done:{getattr(fn, '__name__', 'fn')}"),
        producer=producer,
    )


class Queue:
    """Bounded ring buffer with a doorbell signal (single consumer).

    ``name`` identifies the queue in scheduler event logs and the per-queue
    ledger breakdown; ``weight`` is consumed by weighted scheduling policies
    (a weight-2 queue gets two grants per round).
    """

    def __init__(
        self,
        agent: Any,
        size: int = 256,
        *,
        name: str | None = None,
        weight: int = 1,
        clock: Any = None,
    ) -> None:
        if size < 1:
            raise ValueError("queue size must be >= 1")
        if weight < 1:
            raise ValueError("queue weight must be >= 1")
        self.agent = agent
        self.size = size
        self.name = name if name is not None else f"q{next(_QUEUE_IDS)}"
        self.weight = weight
        self.clock = clock                 # optional: stamps packet enqueue times
        self.ledger = None                 # optional: records dispatch_submit (set on add_queue)
        self._ring: list[Packet | None] = [None] * size
        self._write = 0
        self._read = 0
        self._lock = threading.Lock()
        self.doorbell = Signal(0, name=f"doorbell:{self.name}")
        self._notify: Any = None           # scheduler doorbell fan-in (set on add_queue)

    # -- producer side -----------------------------------------------------------

    def _write_packets(self, packets: Sequence[Packet]) -> int:
        """Ring-write + one doorbell store + one scheduler notify; returns the
        first packet's index.  The shared tail of submit/submit_burst."""
        now = self.clock.now() if self.clock is not None else None
        for packet in packets:
            if now is not None and packet.enqueue_t is None:
                packet.enqueue_t = now
            # Completion waits inherit the queue's time source so timed waits
            # (engine launch waits, watchdog probes) are deterministic under a
            # VirtualClock without the producer having to plumb it per packet.
            completion = packet.completion
            if (
                self.clock is not None
                and completion is not None
                and getattr(completion, "clock", None) is None
            ):
                completion.clock = self.clock
        with self._lock:
            if self._write - self._read + len(packets) > self.size:
                raise QueueFullError(f"queue {self.name} full ({self.size} packets)")
            idx = self._write
            for packet in packets:
                self._ring[self._write % self.size] = packet
                self._write += 1
        self.doorbell.store(self._write)      # ring the doorbell (once per burst)
        if self._notify is not None:
            self._notify()
        return idx

    def _record_submit(self, packets: Sequence[Packet], seconds: float) -> None:
        if self.ledger is None:
            return
        per_pkt = seconds / len(packets)
        for packet in packets:
            self.ledger.record(
                ledger_mod.DISPATCH_SUBMIT, per_pkt, queue=self.name,
                producer=getattr(packet, "producer", None),
                burst=len(packets),
            )

    def submit(self, packet: Packet) -> int:
        t0 = time.perf_counter_ns()
        idx = self._write_packets((packet,))
        self._record_submit((packet,), (time.perf_counter_ns() - t0) * 1e-9)
        return idx

    def submit_burst(self, packets: Sequence[Packet]) -> int:
        """Write N packets and ring the doorbell **once** (burst AQL submission).

        The whole burst shares one ``burst_id`` (the scheduler's grant loop
        uses it to drain the burst in a single wakeup) and the measured
        submit cost is divided over the N packets in the ledger — the
        amortization Table II's invocation row is split to expose.  Packets
        may carry dependency signals on each other (a chained decode burst);
        in-order consumption guarantees a packet's intra-burst deps precede
        it.  Returns the first packet's ring index.
        """
        packets = list(packets)
        if not packets:
            raise ValueError("submit_burst needs at least one packet")
        t0 = time.perf_counter_ns()
        bid = next(_BURST_IDS)
        unstamped = [p for p in packets if p.enqueue_t is None]
        for packet in packets:
            packet.burst_id = bid
            packet.burst_n = len(packets)
        try:
            idx = self._write_packets(packets)
        except QueueFullError:
            # nothing was written: revert the burst stamps so a caller that
            # falls back to individual submits doesn't carry a dead burst_id
            # (which would fuse its retries into one grant pass) or a stale
            # enqueue_t (which would inflate WAIT on retry)
            for packet in packets:
                packet.burst_id = None
                packet.burst_n = 1
            for packet in unstamped:
                packet.enqueue_t = None
            raise
        self._record_submit(packets, (time.perf_counter_ns() - t0) * 1e-9)
        return idx

    def dispatch(
        self,
        role_key: RoleKey,
        *args: Any,
        producer: str = "tf",
        deps: Sequence[Signal] = (),
    ) -> KernelDispatchPacket:
        pkt = dispatch_packet(role_key, *args, producer=producer, deps=deps)
        self.submit(pkt)
        return pkt

    def call(
        self,
        fn: Callable[..., Any],
        *args: Any,
        producer: str = "tf",
        deps: Sequence[Signal] = (),
    ) -> KernelDispatchPacket:
        """Dispatch a pinned-shell callable (no region management)."""
        pkt = call_packet(fn, *args, producer=producer, deps=deps)
        self.submit(pkt)
        return pkt

    def barrier(self, deps: Sequence[Signal]) -> BarrierAndPacket:
        pkt = BarrierAndPacket(deps=tuple(deps), completion=Signal(1, name="barrier"))
        self.submit(pkt)
        return pkt

    # -- consumer side -----------------------------------------------------------

    def peek(self) -> Packet | None:
        """Head packet without consuming it (in-order queues never skip)."""
        with self._lock:
            if self._read >= self._write:
                return None
            return self._ring[self._read % self.size]

    def peek_window(self, n: int) -> list[Packet]:
        """First ``n`` packets without consuming them — the scheduler's
        lookahead window for reconfiguration prefetch.  Like ``peek`` this
        never reorders: in-order queues expose, not skip, their future."""
        with self._lock:
            depth = min(n, self._write - self._read)
            return [
                self._ring[(self._read + i) % self.size]  # type: ignore[misc]
                for i in range(max(0, depth))
            ]

    def pop(self) -> Packet | None:
        with self._lock:
            if self._read >= self._write:
                return None
            pkt = self._ring[self._read % self.size]
            self._ring[self._read % self.size] = None
            self._read += 1
            return pkt

    def requeue_head(self, packet: Packet) -> None:
        """Consumer-side undo: push a just-popped packet back into the head
        slot so the grant loop re-presents it without reordering it behind
        later submissions.  Used by the scheduler's fault-retry path; the
        packet keeps its original ``enqueue_t`` so WAIT accounting spans the
        whole retried lifetime."""
        with self._lock:
            if self._write - self._read + 1 > self.size:
                raise QueueFullError(f"queue {self.name} full ({self.size} packets)")
            self._read -= 1
            self._ring[self._read % self.size] = packet

    def pending(self) -> int:
        with self._lock:
            return self._write - self._read

    def __len__(self) -> int:
        return self.pending()

    def __repr__(self) -> str:
        return f"Queue({self.name}, pending={self.pending()}, weight={self.weight})"
