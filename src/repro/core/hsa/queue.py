"""User-mode queues with AQL-style packets.

HSA dispatch works by writing an Architected Queuing Language packet into a
user-mode ring buffer and ringing a doorbell signal.  The two packet types the
paper's runtime needs are kernel-dispatch and barrier-AND (dependency fences) —
both modeled here.  Multiple producers (the training engine, the serving
engine, ad-hoc user code) may submit to the same queue: the paper's
"simultaneously from other sources e.g. OpenCL/OpenMP" property.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Sequence

from repro.core.hsa.signal import Signal
from repro.core.roles import RoleKey


class Box:
    """Mutable result slot for a dispatch packet."""

    __slots__ = ("value", "error")

    def __init__(self) -> None:
        self.value: Any = None
        self.error: BaseException | None = None


@dataclasses.dataclass
class KernelDispatchPacket:
    role_key: RoleKey
    args: tuple[Any, ...]
    completion: Signal | None = None
    out: Box = dataclasses.field(default_factory=Box)
    producer: str = "tf"            # who enqueued: "tf" | "opencl" | "openmp" | ...


@dataclasses.dataclass
class BarrierAndPacket:
    deps: tuple[Signal, ...]
    completion: Signal | None = None


Packet = KernelDispatchPacket | BarrierAndPacket


class QueueFullError(RuntimeError):
    pass


class Queue:
    """Bounded ring buffer with a doorbell signal (single consumer)."""

    def __init__(self, agent: Any, size: int = 256) -> None:
        if size < 1:
            raise ValueError("queue size must be >= 1")
        self.agent = agent
        self.size = size
        self._ring: list[Packet | None] = [None] * size
        self._write = 0
        self._read = 0
        self._lock = threading.Lock()
        self.doorbell = Signal(0, name="doorbell")

    # -- producer side -----------------------------------------------------------

    def submit(self, packet: Packet) -> int:
        with self._lock:
            if self._write - self._read >= self.size:
                raise QueueFullError(f"queue full ({self.size} packets)")
            idx = self._write
            self._ring[idx % self.size] = packet
            self._write += 1
        self.doorbell.store(self._write)      # ring the doorbell
        return idx

    def dispatch(
        self,
        role_key: RoleKey,
        *args: Any,
        producer: str = "tf",
    ) -> KernelDispatchPacket:
        pkt = KernelDispatchPacket(
            role_key=role_key,
            args=args,
            completion=Signal(1, name=f"done:{role_key}"),
            producer=producer,
        )
        self.submit(pkt)
        return pkt

    def barrier(self, deps: Sequence[Signal]) -> BarrierAndPacket:
        pkt = BarrierAndPacket(deps=tuple(deps), completion=Signal(1, name="barrier"))
        self.submit(pkt)
        return pkt

    # -- consumer side -----------------------------------------------------------

    def pop(self) -> Packet | None:
        with self._lock:
            if self._read >= self._write:
                return None
            pkt = self._ring[self._read % self.size]
            self._ring[self._read % self.size] = None
            self._read += 1
            return pkt

    def pending(self) -> int:
        with self._lock:
            return self._write - self._read

    def __len__(self) -> int:
        return self.pending()
