"""Injectable time sources for the HSA scheduler.

The scheduler never calls ``time.*`` directly: it asks its clock.  Two
implementations:

  - :class:`WallClock` — monotonic wall time (production / threaded mode).
  - :class:`VirtualClock` — a discrete-event clock that only moves when the
    scheduler advances it.  Deterministic: tests assert exact event
    timestamps and interleavings with zero wall-clock sleeps and zero flakes.

This is the paper's runtime made testable under load: the same scheduler
code path runs against either clock, so every interleaving exercised in CI
is an interleaving the production path can produce.
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    def now(self) -> float: ...
    def sleep(self, seconds: float) -> None: ...


class WallClock:
    """Monotonic wall time."""

    virtual = False

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def __repr__(self) -> str:
        return "WallClock()"


class VirtualClock:
    """Deterministic simulated time.

    ``advance``/``advance_to`` are the only ways time moves; ``sleep`` is an
    advance (never a wall-clock wait).  Monotonicity is enforced so event
    logs are always well ordered.
    """

    virtual = True

    def __init__(self, start: float = 0.0) -> None:
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance virtual time by {dt}")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        if t > self._t:
            self._t = float(t)
        return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(max(0.0, seconds))

    def __repr__(self) -> str:
        return f"VirtualClock(t={self._t:.9g})"
