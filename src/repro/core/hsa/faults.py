"""Deterministic fault injection for the HSA runtime.

Real accelerator runtimes fail in three characteristic ways, and the paper's
"hide the complexity of controlling new hardware" promise only holds if the
runtime absorbs all three without user-visible effect:

  - **exec faults** — a kernel launch raises (transient: a retry succeeds;
    permanent: the packet is unrunnable no matter how often it is retried);
  - **load faults** — a partial-bitstream / region load aborts mid-flight
    (the FPGA story's reconfiguration failure);
  - **wedged launches** — the launch neither completes nor errors: its
    completion signal never fires, and only a watchdog deadline kills it;
  - **transfer faults** — a D2H/H2D DMA between the page-pool tiers aborts
    (the spill/refill analogue of a load fault).

A :class:`FaultPlan` injects all of them *deterministically*: one seeded RNG,
one draw per attempt, scheduled on the injectable clock — so every fault
trace is a reproducible virtual-clock event log and a recovery bug replays
exactly.  Tests wanting surgical faults script them with :meth:`force`
(consumed before any random draw).

The injected exceptions all derive from :class:`FaultError`, which is the
type the recovery stack gates on: a ``FaultError`` is the hardware's problem
and is absorbed by retry/quarantine/park-resume; any other exception is a
programming error and still surfaces to the caller unchanged.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Any


class FaultError(RuntimeError):
    """Base class for hardware-attributable launch failures.

    Recovery layers (scheduler retry, reconfig reload, engine park/resume)
    absorb ``FaultError`` subclasses only — user code bugs propagate."""


class InjectedFault(FaultError):
    """Transient kernel-exec failure: a retry may succeed."""


class PermanentFault(InjectedFault):
    """Kernel-exec failure no retry can absorb (broken region, bad SKU)."""


class InjectedLoadFault(FaultError):
    """Region (partial-bitstream) load aborted mid-flight."""


class InjectedTransferFault(FaultError):
    """D2H spill or H2D refill DMA aborted mid-flight.

    The tiered KV pool's failure mode: a faulted spill parks its victim by
    re-prefill replay instead of snapshot; a faulted refill demotes the
    parked snapshot to replay — either way the committed token prefix is
    regenerated bitwise-identically, so the fault never reaches the user."""


class WedgedLaunch(FaultError):
    """Launch that never completes: no error, no completion signal.

    Only the scheduler's watchdog deadline converts a wedge into this
    exception; the time charged for the attempt is the full watchdog
    window, not the expected exec time."""


class SilentCorruption(FaultError):
    """Verification caught wrong bytes in trusted state.

    Raised when a content digest mismatches on a sealed device KV page or a
    host-arena block — the state the serving path would otherwise feed to
    attention unchecked.  Recovery is the PR 7 park path: the owning slot's
    device KV is untrusted and it resumes by re-prefill replay."""


class CorruptPayload(InjectedTransferFault):
    """A DMA completed but delivered wrong bytes (digest mismatch).

    Unlike :class:`InjectedTransferFault` the DMA *succeeded* — the
    corruption is only visible because the payload carries its source
    digest.  Handled like a transfer fault: the refill/spill is discarded
    and the request demotes to re-prefill replay."""


class StaleRegionImage(InjectedLoadFault):
    """A region load completed with the wrong (stale) bitstream image.

    The dynamic-reconfiguration failure mode the fail-stop load fault
    misses: ``role.load()`` returns cleanly but the region holds a previous
    role's image.  Subclasses :class:`InjectedLoadFault` so the scheduler's
    existing load retry (``abort_prefetch`` + reload) absorbs it before any
    packet executes against the stale image."""


#: silent-corruption kinds (drawn from the independent corruption stream)
CORRUPTION_KINDS = ("flip_page", "flip_block", "corrupt_transfer",
                    "stale_region")

_FAILSTOP_KINDS = ("exec", "load", "wedge", "d2h", "h2d")


@dataclasses.dataclass
class FaultEvent:
    """One injected fault, stamped on the plan's clock."""

    t: float
    kind: str                  # _FAILSTOP_KINDS | CORRUPTION_KINDS
    what: str                  # packet .what / role name / transfer tag
    queue: str | None = None
    permanent: bool = False
    forced: bool = False


@dataclasses.dataclass
class FaultPlan:
    """Seeded fault schedule over launch/load/DMA attempts.

    **Draw order** (the contract scripted tests rely on):

    - *Forced first.*  Every draw site consumes matching :meth:`force`
      entries before any random draw, scanning the forced list in
      :meth:`force` insertion order and taking the first entry whose kind
      matches the site and whose ``what`` is ``None`` or a substring of the
      attempt's tag.  An entry with ``count=N`` is consumed once per
      matching attempt and removed after its N-th hit, so interleaved
      forced kinds fire independently: ``force("exec", count=2)`` +
      ``force("h2d")`` injects the next two exec attempts and the next
      H2D refill, whichever order the runtime reaches them.
    - *Fail-stop stream.*  One ``random.Random(seed)`` draw per exec
      attempt, compared against cumulative ``wedge_rate`` /
      ``permanent_rate`` / ``exec_rate`` bands (first band wins); one draw
      per load attempt against ``load_rate``; one draw per DMA attempt
      against ``transfer_rate``.  A given seed therefore produces the same
      fail-stop trace regardless of which faults a test cares about.
    - *Corruption stream.*  Silent-corruption draws
      (:data:`CORRUPTION_KINDS`) come from an **independent** seeded RNG:
      one draw per opportunity against ``corrupt_rate``, plus one target
      draw per hit.  Enabling corruption never perturbs the fail-stop
      schedule (and vice versa), so PR 7/8 benchmark floors survive a
      corruption sweep with the same seed.

    ``trace`` accumulates every injected fault as a clock-stamped
    :class:`FaultEvent`.
    """

    seed: int = 0
    exec_rate: float = 0.0        # transient exec exception
    load_rate: float = 0.0        # region load abort
    wedge_rate: float = 0.0       # completion never fires
    permanent_rate: float = 0.0   # unretryable exec failure
    transfer_rate: float = 0.0    # D2H/H2D DMA abort (spill/refill tier)
    corrupt_rate: float = 0.0     # silent corruption (per opportunity)
    clock: Any = None             # bound by the scheduler (bind_clock)

    def __post_init__(self) -> None:
        for name in ("exec_rate", "load_rate", "wedge_rate", "permanent_rate",
                     "transfer_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.exec_rate + self.wedge_rate + self.permanent_rate > 1.0:
            raise ValueError("exec_rate + wedge_rate + permanent_rate > 1")
        self._rng = random.Random(self.seed)
        # str seeding hashes via sha512 (process-independent), and a
        # distinct stream keeps corruption draws from perturbing the
        # fail-stop schedule above.
        self._crng = random.Random(f"corruption-{self.seed}")
        self.trace: list[FaultEvent] = []
        self._forced: list[dict[str, Any]] = []

    # -- wiring ------------------------------------------------------------

    def bind_clock(self, clock: Any) -> None:
        """Attach the runtime's clock so trace events are stamped in the
        same timeline as the scheduler's event log.  First binding wins
        (a plan shared by scheduler + region manager keeps one timeline)."""
        if self.clock is None:
            self.clock = clock

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else 0.0

    # -- scripted faults ---------------------------------------------------

    def force(self, kind: str, what: str | None = None, *,
              permanent: bool = False, count: int = 1) -> None:
        """Script ``count`` faults of ``kind`` ("exec" | "load" | "wedge" |
        "d2h" | "h2d") against the next matching attempts (``what`` is a
        substring match on the packet's ``.what`` / role name / transfer
        tag; None matches any).  Corruption kinds ("flip_page" |
        "flip_block" | "corrupt_transfer" | "stale_region") are scripted
        the same way.  Forced faults are consumed before any random draw,
        so a test can hit one specific launch without touching the seeded
        schedule."""
        if kind not in _FAILSTOP_KINDS + CORRUPTION_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        if count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        self._forced.append(
            {"kind": kind, "what": what, "permanent": permanent,
             "count": count}
        )

    def _take_forced(self, kinds: tuple[str, ...], what: str) -> dict | None:
        for entry in self._forced:
            if entry["kind"] in kinds and (
                entry["what"] is None or entry["what"] in what
            ):
                entry["count"] -= 1
                if entry["count"] == 0:
                    self._forced.remove(entry)
                return entry
        return None

    # -- draws -------------------------------------------------------------

    def _log(self, kind: str, what: str, queue: str | None,
             permanent: bool, forced: bool) -> None:
        self.trace.append(FaultEvent(
            t=self._now(), kind=kind, what=what, queue=queue,
            permanent=permanent, forced=forced,
        ))

    def draw_exec(self, what: str, *,
                  queue: str | None = None) -> FaultError | None:
        """Fault (or None) for one kernel-exec attempt of ``what``."""
        forced = self._take_forced(("exec", "wedge"), what)
        if forced is not None:
            kind = forced["kind"]
            permanent = bool(forced["permanent"])
            self._log(kind, what, queue, permanent, forced=True)
            if kind == "wedge":
                return WedgedLaunch(f"wedged launch (forced): {what}")
            if permanent:
                return PermanentFault(f"permanent exec fault (forced): {what}")
            return InjectedFault(f"exec fault (forced): {what}")
        r = self._rng.random()
        if r < self.wedge_rate:
            self._log("wedge", what, queue, False, forced=False)
            return WedgedLaunch(f"wedged launch: {what}")
        r -= self.wedge_rate
        if r < self.permanent_rate:
            self._log("exec", what, queue, True, forced=False)
            return PermanentFault(f"permanent exec fault: {what}")
        r -= self.permanent_rate
        if r < self.exec_rate:
            self._log("exec", what, queue, False, forced=False)
            return InjectedFault(f"exec fault: {what}")
        return None

    def draw_load(self, role: str, *,
                  queue: str | None = None) -> FaultError | None:
        """Fault (or None) for one region-load attempt of ``role``."""
        forced = self._take_forced(("load",), role)
        if forced is not None:
            self._log("load", role, queue, bool(forced["permanent"]),
                      forced=True)
            return InjectedLoadFault(f"load fault (forced): {role}")
        if self._rng.random() < self.load_rate:
            self._log("load", role, queue, False, forced=False)
            return InjectedLoadFault(f"load fault: {role}")
        return None

    def draw_transfer(self, kind: str, what: str, *,
                      queue: str | None = None) -> FaultError | None:
        """Fault (or None) for one DMA attempt of ``kind`` ("d2h" | "h2d")
        moving ``what`` between the pool tiers."""
        if kind not in ("d2h", "h2d"):
            raise ValueError(f"transfer kind must be d2h|h2d, got {kind!r}")
        forced = self._take_forced((kind,), what)
        if forced is not None:
            self._log(kind, what, queue, False, forced=True)
            return InjectedTransferFault(
                f"{kind} transfer fault (forced): {what}"
            )
        if self._rng.random() < self.transfer_rate:
            self._log(kind, what, queue, False, forced=False)
            return InjectedTransferFault(f"{kind} transfer fault: {what}")
        return None

    def draw_corruption(self, kind: str, targets: list[str], *,
                        queue: str | None = None) -> int | None:
        """Index of the corrupted target (or None) for one silent-corruption
        opportunity of ``kind`` over ``targets`` (display tags).

        Forced entries are consumed first (matched against each target tag
        in order); otherwise one draw from the corruption stream against
        ``corrupt_rate`` decides whether to corrupt, and a second draw
        picks the target uniformly.  Returns the index into ``targets``."""
        if kind not in CORRUPTION_KINDS:
            raise ValueError(f"corruption kind must be one of "
                             f"{CORRUPTION_KINDS}, got {kind!r}")
        if not targets:
            return None
        for i, what in enumerate(targets):
            if self._take_forced((kind,), what) is not None:
                self._log(kind, what, queue, False, forced=True)
                return i
        if self._crng.random() < self.corrupt_rate:
            i = self._crng.randrange(len(targets))
            self._log(kind, targets[i], queue, False, forced=False)
            return i
        return None

    def stale_region_hook(self, role: str) -> bool:
        """RegionManager ``corrupt_hook`` adapter: True when this load
        should deliver a stale (wrong) region image."""
        return self.draw_corruption("stale_region", [role]) is not None

    def load_hook(self, role: str) -> None:
        """RegionManager ``fault_hook`` adapter: raise instead of return,
        matching the real failure mode (``role.load()`` raising)."""
        err = self.draw_load(role)
        if err is not None:
            raise err

    def __repr__(self) -> str:
        return (
            f"FaultPlan(seed={self.seed}, exec={self.exec_rate}, "
            f"load={self.load_rate}, wedge={self.wedge_rate}, "
            f"permanent={self.permanent_rate}, "
            f"transfer={self.transfer_rate}, corrupt={self.corrupt_rate}, "
            f"injected={len(self.trace)})"
        )
