"""hsa_init / hsa_shut_down: system bring-up.

One-time device/kernel setup (paper Table II row 1): enumerate agents, build
the role library, create the default queue + executor + region manager per
kernel-dispatch agent.  The measured setup time lands in the ledger's SETUP
category.
"""

from __future__ import annotations

import threading
from typing import Any

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.agent import Agent
from repro.core.hsa.executor import Executor
from repro.core.hsa.queue import Queue
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary


class HsaSystem:
    def __init__(
        self,
        *,
        num_regions: int = 4,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        queue_size: int = 1024,
    ) -> None:
        self.ledger = ledger
        with ledger.timed(ledger_mod.SETUP, what="hsa_init"):
            self.agents = Agent.discover(num_reconfig_regions=num_regions)
            self.library = RoleLibrary(ledger=ledger)
            self.queues: dict[str, Queue] = {}
            self.executors: dict[str, Executor] = {}
            self.regions: dict[str, RegionManager] = {}
            for agent in self.agents:
                q = agent.create_queue(queue_size)
                rm = RegionManager(agent.num_reconfig_regions, ledger=ledger)
                self.queues[agent.name] = q
                self.regions[agent.name] = rm
                self.executors[agent.name] = Executor(rm, self.library, ledger=ledger)

    @property
    def default_agent(self) -> Agent:
        # Prefer a real accelerator when present; else the first agent.
        for a in self.agents:
            if a.kind != "cpu":
                return a
        return self.agents[0]

    def queue_of(self, agent: Agent) -> Queue:
        return self.queues[agent.name]

    def executor_of(self, agent: Agent) -> Executor:
        return self.executors[agent.name]

    def regions_of(self, agent: Agent) -> RegionManager:
        return self.regions[agent.name]

    def shutdown(self) -> None:
        for ex in self.executors.values():
            ex.stop()
        for rm in self.regions.values():
            rm.flush()


_SYSTEM: HsaSystem | None = None
_LOCK = threading.Lock()


def hsa_init(**kw: Any) -> HsaSystem:
    global _SYSTEM
    with _LOCK:
        if _SYSTEM is None:
            _SYSTEM = HsaSystem(**kw)
        return _SYSTEM


def hsa_system() -> HsaSystem:
    if _SYSTEM is None:
        raise RuntimeError("hsa_init() has not been called")
    return _SYSTEM


def hsa_shut_down() -> None:
    global _SYSTEM
    with _LOCK:
        if _SYSTEM is not None:
            _SYSTEM.shutdown()
            _SYSTEM = None
