"""hsa_init / hsa_shut_down: system bring-up.

One-time device/kernel setup (paper Table II row 1): enumerate agents, build
the role library, and create per kernel-dispatch agent:

  - ``num_queues`` user-level soft queues (the paper's multi-producer story:
    TensorFlow, OpenCL, OpenMP clients each get their own queue),
  - one async multi-queue :class:`Scheduler` plus a legacy ``Executor``
    façade over it,
  - one :class:`RegionManager` (bounded residency, LRU).

The measured setup time lands in the ledger's SETUP category.
"""

from __future__ import annotations

import threading
from typing import Any

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.agent import Agent
from repro.core.hsa.executor import Executor
from repro.core.hsa.queue import Queue
from repro.core.hsa.scheduler import Scheduler
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary


class HsaSystem:
    def __init__(
        self,
        *,
        num_regions: int = 4,
        num_queues: int = 1,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        queue_size: int = 1024,
        scheduler_policy: str = "round_robin",
    ) -> None:
        self.ledger = ledger
        with ledger.timed(ledger_mod.SETUP, what="hsa_init"):
            self.agents = Agent.discover(num_reconfig_regions=num_regions)
            self.library = RoleLibrary(ledger=ledger)
            self.queues: dict[str, Queue] = {}             # default queue per agent
            self.soft_queues: dict[str, list[Queue]] = {}  # all soft queues per agent
            self.executors: dict[str, Executor] = {}
            self.schedulers: dict[str, Scheduler] = {}
            self.regions: dict[str, RegionManager] = {}
            for agent in self.agents:
                rm = RegionManager(agent.num_reconfig_regions, ledger=ledger)
                sched = Scheduler(
                    rm, self.library, ledger=ledger, policy=scheduler_policy
                )
                qs = [
                    sched.add_queue(
                        agent.create_queue(queue_size, name=f"{agent.name}/q{i}")
                    )
                    for i in range(max(1, num_queues))
                ]
                self.queues[agent.name] = qs[0]
                self.soft_queues[agent.name] = qs
                self.regions[agent.name] = rm
                self.schedulers[agent.name] = sched
                self.executors[agent.name] = Executor(
                    rm, self.library, ledger=ledger, scheduler=sched
                )

    @property
    def default_agent(self) -> Agent:
        # Prefer a real accelerator when present; else the first agent.
        for a in self.agents:
            if a.kind != "cpu":
                return a
        return self.agents[0]

    def queue_of(self, agent: Agent) -> Queue:
        return self.queues[agent.name]

    def queues_of(self, agent: Agent) -> list[Queue]:
        return list(self.soft_queues[agent.name])

    def executor_of(self, agent: Agent) -> Executor:
        return self.executors[agent.name]

    def scheduler_of(self, agent: Agent) -> Scheduler:
        return self.schedulers[agent.name]

    def regions_of(self, agent: Agent) -> RegionManager:
        return self.regions[agent.name]

    def create_queue(
        self, agent: Agent, *, name: str | None = None, size: int = 256,
        weight: int = 1,
    ) -> Queue:
        """Open an extra soft queue on ``agent`` (a new tenant)."""
        q = agent.create_queue(size, name=name, weight=weight)
        self.schedulers[agent.name].add_queue(q)
        self.soft_queues[agent.name].append(q)
        return q

    def shutdown(self) -> None:
        for ex in self.executors.values():
            ex.stop()
        for sched in self.schedulers.values():
            sched.stop()                 # idempotent; covers direct .start() users
        for rm in self.regions.values():
            rm.flush()


_SYSTEM: HsaSystem | None = None
_LOCK = threading.Lock()


def hsa_init(**kw: Any) -> HsaSystem:
    global _SYSTEM
    with _LOCK:
        if _SYSTEM is None:
            _SYSTEM = HsaSystem(**kw)
        return _SYSTEM


def hsa_system() -> HsaSystem:
    if _SYSTEM is None:
        raise RuntimeError("hsa_init() has not been called")
    return _SYSTEM


def hsa_shut_down() -> None:
    global _SYSTEM
    with _LOCK:
        if _SYSTEM is not None:
            _SYSTEM.shutdown()
            _SYSTEM = None
