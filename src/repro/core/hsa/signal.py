"""HSA signals: the synchronization primitive of the runtime.

HSA 1.2 signals are 64-bit values with atomic ops and blocking waits; producers
decrement/store, consumers wait on a condition.  Used here for queue doorbells,
packet completion, and barrier-AND dependencies — same roles as in the paper's
runtime.
"""

from __future__ import annotations

import threading
import time
from typing import Callable


class Signal:
    def __init__(self, initial: int = 1, name: str = "") -> None:
        self._value = int(initial)
        self._cond = threading.Condition()
        self.name = name

    # -- atomics ---------------------------------------------------------------

    def load(self) -> int:
        with self._cond:
            return self._value

    def store(self, value: int) -> None:
        with self._cond:
            self._value = int(value)
            self._cond.notify_all()

    def add(self, delta: int) -> int:
        with self._cond:
            self._value += int(delta)
            self._cond.notify_all()
            return self._value

    def subtract(self, delta: int) -> int:
        return self.add(-delta)

    def decrement(self) -> int:
        return self.add(-1)

    def exchange(self, value: int) -> int:
        with self._cond:
            old = self._value
            self._value = int(value)
            self._cond.notify_all()
            return old

    # -- waits -------------------------------------------------------------------

    def _wait(self, pred: Callable[[int], bool], timeout: float | None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not pred(self._value):
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(remaining)
            return True

    def wait_eq(self, target: int = 0, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v == target, timeout)

    def wait_ne(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v != target, timeout)

    def wait_lt(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v < target, timeout)

    def wait_ge(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v >= target, timeout)

    def __repr__(self) -> str:
        return f"Signal({self.load()}, name={self.name!r})"
