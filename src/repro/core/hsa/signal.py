"""HSA signals: the synchronization primitive of the runtime.

HSA 1.2 signals are 64-bit values with atomic ops and blocking waits; producers
decrement/store, consumers wait on a condition.  Used here for queue doorbells,
packet completion, and barrier-AND dependencies — same roles as in the paper's
runtime.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable


class Signal:
    def __init__(self, initial: int = 1, name: str = "", clock: Any = None) -> None:
        self._value = int(initial)
        self._cond = threading.Condition()
        self.name = name
        self.clock = clock  # optional injectable time source for timed waits

    # -- atomics ---------------------------------------------------------------

    def load(self) -> int:
        with self._cond:
            return self._value

    def store(self, value: int) -> None:
        with self._cond:
            self._value = int(value)
            self._cond.notify_all()

    def add(self, delta: int) -> int:
        with self._cond:
            self._value += int(delta)
            self._cond.notify_all()
            return self._value

    def subtract(self, delta: int) -> int:
        return self.add(-delta)

    def decrement(self) -> int:
        return self.add(-1)

    def exchange(self, value: int) -> int:
        with self._cond:
            old = self._value
            self._value = int(value)
            self._cond.notify_all()
            return old

    # -- waits -------------------------------------------------------------------

    def _now(self) -> float:
        return time.monotonic() if self.clock is None else self.clock.now()

    def _wait(self, pred: Callable[[int], bool], timeout: float | None) -> bool:
        clk = self.clock
        if timeout is not None and clk is not None and getattr(clk, "virtual", False):
            # Virtual time never moves inside a blocking wait, so a timed wait
            # is modeled as a deterministic advance-and-recheck: either the
            # value is already there, or the timeout window elapses on the
            # virtual clock and the wait reports whatever the value then is.
            with self._cond:
                if pred(self._value):
                    return True
            clk.sleep(max(0.0, timeout))
            with self._cond:
                return pred(self._value)
        deadline = None if timeout is None else self._now() + timeout
        with self._cond:
            while not pred(self._value):
                remaining = None if deadline is None else deadline - self._now()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(remaining)
            return True

    def wait_eq(self, target: int = 0, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v == target, timeout)

    def wait_ne(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v != target, timeout)

    def wait_lt(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v < target, timeout)

    def wait_ge(self, target: int, timeout: float | None = None) -> bool:
        return self._wait(lambda v: v >= target, timeout)

    def __repr__(self) -> str:
        return f"Signal({self.load()}, name={self.name!r})"


def wait_all(
    signals: Iterable["Signal"],
    target: int = 0,
    timeout: float | None = None,
    clock: Any = None,
) -> bool:
    """Block until every signal reads ``target``; one wait covers a burst.

    The sequential component waits share a single deadline, so the total
    blocking time is bounded by ``timeout`` regardless of completion order
    (waiting on an already-satisfied signal returns immediately, so order
    only affects which signal eats the remaining budget on timeout).
    Returns False as soon as the deadline expires with any signal unmet.

    The deadline is tracked on ``clock`` when given, else on the first
    component signal that carries one, else on ``time.monotonic`` — so a
    burst wait under :class:`VirtualClock` stays deterministic end to end.
    """
    signals = tuple(signals)
    clk = clock
    if clk is None:
        for sig in signals:
            if getattr(sig, "clock", None) is not None:
                clk = sig.clock
                break
    now = time.monotonic if clk is None else clk.now
    deadline = None if timeout is None else now() + timeout
    for sig in signals:
        remaining = None if deadline is None else deadline - now()
        if not sig.wait_eq(target, remaining):
            return False
    return True


class CompositeSignal:
    """Aggregate read/wait view over a burst's completion signals.

    HSA has no N-way completion object; the idiom is one barrier-AND packet
    or a host-side wait over all signals.  This is the host-side form: it
    quacks like a :class:`Signal` for the read/wait subset (``load`` returns
    the number of components not yet at 0; ``wait_eq(0)`` blocks until every
    component reads 0), so producer code that waits one packet's completion
    can wait a whole burst through the same call site.
    """

    def __init__(self, signals: Iterable[Signal], name: str = "") -> None:
        self.signals = tuple(signals)
        self.name = name or f"composite[{len(self.signals)}]"

    def load(self) -> int:
        return sum(1 for s in self.signals if s.load() != 0)

    def wait_eq(self, target: int = 0, timeout: float | None = None) -> bool:
        if target != 0:
            raise ValueError("CompositeSignal only supports waiting to 0")
        return wait_all(self.signals, 0, timeout)

    def __len__(self) -> int:
        return len(self.signals)

    def __repr__(self) -> str:
        return f"CompositeSignal(pending={self.load()}/{len(self.signals)}, name={self.name!r})"
