"""HSA-style runtime layer (agents, queues, signals, scheduler, executor)."""

from repro.core.hsa.agent import Agent, MemoryRegion
from repro.core.hsa.clock import Clock, VirtualClock, WallClock
from repro.core.hsa.executor import Executor, run_packet_sync
from repro.core.hsa.faults import (
    FaultError,
    FaultEvent,
    FaultPlan,
    InjectedFault,
    InjectedLoadFault,
    PermanentFault,
    WedgedLaunch,
)
from repro.core.hsa.queue import (
    BarrierAndPacket,
    Box,
    KernelDispatchPacket,
    Queue,
    QueueFullError,
    call_packet,
    dispatch_packet,
)
from repro.core.hsa.runtime import HsaSystem, hsa_init, hsa_shut_down, hsa_system
from repro.core.hsa.scheduler import (
    SchedEvent,
    Scheduler,
    SchedulerDeadlock,
    QueueStats,
)
from repro.core.hsa.signal import CompositeSignal, Signal, wait_all

__all__ = [
    "Agent",
    "MemoryRegion",
    "Clock",
    "VirtualClock",
    "WallClock",
    "Executor",
    "run_packet_sync",
    "FaultError",
    "FaultEvent",
    "FaultPlan",
    "InjectedFault",
    "InjectedLoadFault",
    "PermanentFault",
    "WedgedLaunch",
    "BarrierAndPacket",
    "Box",
    "KernelDispatchPacket",
    "Queue",
    "QueueFullError",
    "call_packet",
    "dispatch_packet",
    "HsaSystem",
    "hsa_init",
    "hsa_shut_down",
    "hsa_system",
    "SchedEvent",
    "Scheduler",
    "SchedulerDeadlock",
    "QueueStats",
    "CompositeSignal",
    "Signal",
    "wait_all",
]
