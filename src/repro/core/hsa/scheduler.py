"""Async multi-queue packet scheduler — the runtime half of transparent dispatch.

The paper's FPGA is shared dynamically at runtime: kernels arrive on HSA
user-level queues from several producers at once (the TensorFlow engine,
OpenCL/OpenMP clients), and the device reconfigures regions on demand.  This
scheduler is that sharing layer:

  - N *soft queues* per agent; AQL packets carry completion signals, and
    kernel packets / barrier-AND packets carry dependency signals.
  - A doorbell-driven loop round-robins (or weight-round-robins) *ready*
    packets across queues: a packet is ready when its queue is not stalled
    and every dependency signal reads 0.
  - Reconfiguration stalls only the queue that missed residency.  The
    reconfiguration engine (the FPGA's ICAP; here the XLA load path) is
    modeled separately from the compute engine, so an independent queue keeps
    executing while another queue's region loads.  ``overlap_reconfig=False``
    recovers the synchronous baseline where reconfiguration occupies the
    device — the comparison benchmarks/table4 measures.
  - **Lookahead reconfiguration prefetch** (``lookahead=N``): whenever a
    queue is blocked (stalled on a load, or its head waits on dependency
    signals), the scheduler scans that queue's next N packets and issues
    speculative loads on the reconfiguration engine for roles that would
    miss — by the time the packet is granted its region is hot (ICAP
    pipelining).  A demand miss that finds its role already in flight *joins*
    the prefetch instead of double-loading; the victim search skips roles
    referenced inside any lookahead window (an approximate Bélády oracle read
    straight off the queues).  ``lookahead=0`` recovers the purely reactive
    PR-1 scheduler; benchmarks/table5 sweeps the depth.
  - Per-queue wait / exec / reconfig time lands in the overhead ledger
    (``queue=`` meta → ``OverheadLedger.queue_breakdown()``), with
    reconfiguration split into *exposed* (queue sat stalled) and *hidden*
    (overlapped by prefetch) — paper Table II row 2, prefetch-refined.

Determinism: the scheduler takes an injectable clock.  With a
:class:`~repro.core.hsa.clock.VirtualClock` the whole schedule is a
discrete-event simulation — no threads, no sleeps — and the event log is
bit-for-bit reproducible, which is what the interleaving tests assert.
Durations on the virtual timeline come from ``cost_model(kind, what,
measured_s)``; by default the actually-measured execution time is used.
With a :class:`WallClock` the same code path runs threaded (``start()``)
with reconfigurations offloaded to a background worker.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Iterable

import jax

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.clock import Clock, VirtualClock, WallClock
from repro.core.hsa.faults import (
    FaultError, FaultPlan, InjectedLoadFault, PermanentFault, WedgedLaunch,
)
from repro.core.hsa.queue import BarrierAndPacket, KernelDispatchPacket, Packet, Queue
from repro.core.policy import PrefetchPolicy, RetryPolicy
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary

ROUND_ROBIN = "round_robin"
WEIGHTED = "weighted"
RANDOM = "random"
POLICIES = (ROUND_ROBIN, WEIGHTED, RANDOM)


class SchedulerDeadlock(RuntimeError):
    """No packet can ever become ready (unsatisfiable dependency)."""


@dataclasses.dataclass(frozen=True)
class SchedEvent:
    """One entry of the deterministic event log."""

    t: float
    kind: str  # exec_start | exec_end | reconfig_start | reconfig_end |
    #            prefetch_start | prefetch_end | prefetch_hit | barrier | error
    queue: str
    what: str
    seq: int = 0

    def brief(self) -> tuple[str, str, str]:
        return (self.kind, self.queue, self.what)


@dataclasses.dataclass
class QueueStats:
    wait_s: float = 0.0
    exec_s: float = 0.0
    reconfig_s: float = 0.0           # exposed: time this queue sat stalled
    reconfig_hidden_s: float = 0.0    # prefetched load time hidden behind compute
    dispatched: int = 0
    barriers: int = 0
    reconfigs: int = 0
    prefetches: int = 0               # speculative loads issued for this queue
    prefetch_hits: int = 0            # packets that found their role prefetched


@dataclasses.dataclass
class _Stall:
    """An in-progress reconfiguration attributed to one queue."""

    role_name: str
    start_t: float
    end_t: float                      # virtual end (cooperative) / inf (threaded)
    future: Future | None = None      # threaded mode only
    error: BaseException | None = None  # load failed: fail the head packet at retire
    role_key: Any = None
    joined: bool = False              # riding an in-flight prefetch, not a load
    exposed_s: float = 0.0            # joined stalls: residual wait past compute


@dataclasses.dataclass
class _Prefetch:
    """A speculative region load in flight on the reconfiguration engine."""

    role: Any
    role_key: Any
    queue: str                        # beneficiary queue (whose window demanded it)
    start_t: float
    end_t: float                      # virtual end (cooperative) / inf (threaded)
    future: Future | None = None
    error: BaseException | None = None
    started: bool = True              # begin_prefetch actually took a region
    joined: bool = False              # a demand miss is riding this load
    exposed_s: float = 0.0            # residual stall time claimed by joiners


def _default_cost(kind: str, what: str, measured_s: float) -> float:
    del kind, what
    return measured_s


class Scheduler:
    """Doorbell-driven multi-queue packet scheduler over one agent's engines."""

    def __init__(
        self,
        regions: RegionManager,
        library: RoleLibrary,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        clock: Clock | None = None,
        policy: str = ROUND_ROBIN,
        seed: int = 0,
        cost_model: Callable[[str, str, float], float] | None = None,
        overlap_reconfig: bool = True,
        lookahead: "PrefetchPolicy | int" = 0,
        burst_grants: bool = True,
        keep_events: int = 100_000,
        retry: "RetryPolicy | int | None" = None,
        faults: "FaultPlan | None" = None,
        expected_exec_s: float | Callable[[str], float] | None = None,
    ) -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")
        self.regions = regions
        self.library = library
        self.ledger = ledger
        self.clock: Clock = clock if clock is not None else WallClock()
        # honor the Clock protocol's `virtual` flag so user-supplied
        # deterministic clocks get virtual-time semantics too
        self._virtual = bool(getattr(self.clock, "virtual", False))
        self.policy = policy
        self.cost_model = cost_model or _default_cost
        self.overlap_reconfig = overlap_reconfig
        self.lookahead = PrefetchPolicy.of(lookahead).lookahead
        self.burst_grants = burst_grants
        self.keep_events = keep_events
        # fault tolerance: retry=None keeps the legacy fail-fast semantics
        # (one error kills the packet); a RetryPolicy turns on per-packet
        # retry/backoff, the wedge watchdog, and queue quarantine.  A
        # FaultPlan deterministically injects the faults the policy absorbs.
        self.retry = RetryPolicy.of(retry)
        self.faults = faults
        # expected exec duration (seconds, or a fn of packet .what) the
        # watchdog deadline is derived from — callers with a step_time_model
        # thread it here so wedge kills track the workload's real tempo
        self.expected_exec_s = expected_exec_s
        if faults is not None:
            faults.bind_clock(self.clock)
            if regions.fault_hook is None:
                regions.fault_hook = faults.load_hook
            if regions.corrupt_hook is None:
                regions.corrupt_hook = faults.stale_region_hook

        self.queues: list[Queue] = []
        self.stats: dict[str, QueueStats] = {}
        self.events: list[SchedEvent] = []
        self.dropped_events = 0

        self._rng = random.Random(seed)
        self._grant_order: list[int] = []
        self._grant_ptr = 0
        self._stalls: dict[str, _Stall] = {}       # queue name -> reconfig in flight
        self._prefetches: dict[Any, _Prefetch] = {}  # role key -> speculative load
        self._backoff_until: dict[str, float] = {}   # queue -> no grants before t
        self._consecutive_faults: dict[str, int] = {}
        self._quarantined: set[str] = set()
        self._migrated_counts: dict[str, int] = {}   # origin queue -> in flight
        self._seq = 0
        self._t0 = self.clock.now()
        self._compute_free_t = self._t0
        self._reconfig_free_t = self._t0
        self._busy_s = 0.0
        self._completed = 0

        self._refill_sources: list[Callable[[], Any]] = []

        self._doorbell_counter = 0
        self._work = threading.Condition()
        # serializes consumers: the worker thread and a legacy synchronous
        # drain() may step concurrently; peek-then-pop must stay atomic
        self._step_lock = threading.RLock()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        self._reconfig_pool: ThreadPoolExecutor | None = None

    # -- refill sources (tiered-pool ahead-of-need pump) -----------------------

    def register_refill_source(self, pump: Callable[[], Any]) -> None:
        """Register a tiered-pool refill pump, called once per scheduling
        step right after speculative region prefetches are issued.

        The pump (e.g. ``ServeEngine._pump_refills_external``) issues H2D
        arena refills for parked requests nearing resume — the memory-tier
        twin of ``_issue_prefetches``.  Pumps must never block on the
        caller: a pump that cannot take its own lock should return and try
        again next step.
        """
        self._refill_sources.append(pump)

    # -- queue management -----------------------------------------------------

    def add_queue(self, queue: Queue) -> Queue:
        if any(q.name == queue.name for q in self.queues):
            raise ValueError(f"duplicate queue name {queue.name!r}")
        queue.clock = self.clock
        queue.ledger = self.ledger                 # dispatch_submit attribution
        queue._notify = self._ring                 # doorbell fan-in
        self.queues.append(queue)
        self.stats[queue.name] = QueueStats()
        self._rebuild_grants()
        return queue

    def create_queue(
        self, agent: Any = None, *, name: str | None = None, size: int = 256,
        weight: int = 1,
    ) -> Queue:
        return self.add_queue(Queue(agent, size, name=name, weight=weight))

    def _rebuild_grants(self) -> None:
        order: list[int] = []
        for i, q in enumerate(self.queues):
            order.extend([i] * (q.weight if self.policy == WEIGHTED else 1))
        self._grant_order = order
        self._grant_ptr = self._grant_ptr % max(1, len(order))

    def _ring(self) -> None:
        with self._work:
            self._doorbell_counter += 1
            self._work.notify_all()

    # -- readiness ------------------------------------------------------------

    def _deps_zero(self, deps: Iterable[Any]) -> bool:
        return all(d.load() == 0 for d in deps)

    def _deps_time(self, deps: Iterable[Any], now: float) -> float:
        # completion times ride on the signal objects themselves: lifetime is
        # exactly the signal's, so no unbounded id-keyed map / stale-id reuse
        return max([now] + [getattr(d, "_complete_t", now) for d in deps])

    def _deps_error(self, deps: Iterable[Any]) -> BaseException | None:
        # like _complete_t, upstream errors ride on the signal objects: a
        # failed packet's completion still reaches 0 (waiters wake) but
        # carries the error, so barrier-AND chains propagate failure instead
        # of reporting success over a dead dependency
        for d in deps:
            err = getattr(d, "_error", None)
            if err is not None:
                return err
        return None

    def _complete(self, sig: Any, t: float,
                  error: BaseException | None = None) -> None:
        if sig is not None:
            sig._complete_t = t
            if error is not None:
                sig._error = error
            sig.store(0)

    def _note_done(self, pkt: Packet) -> None:
        self._completed += 1
        src = getattr(pkt, "_migrated_from", None)
        if src is not None:
            pkt._migrated_from = None
            c = self._migrated_counts.get(src, 0) - 1
            if c > 0:
                self._migrated_counts[src] = c
            else:
                self._migrated_counts.pop(src, None)

    def _log(self, t: float, kind: str, queue: str, what: str) -> SchedEvent:
        ev = SchedEvent(t=t, kind=kind, queue=queue, what=what, seq=self._seq)
        self._seq += 1
        if len(self.events) < self.keep_events:
            self.events.append(ev)
        else:
            self.dropped_events += 1
        return ev

    # -- the scheduling step ----------------------------------------------------

    def step(self) -> SchedEvent | None:
        """Process at most one packet (or retire one stall); None when idle.

        Cooperative core shared by ``run_until_idle`` (virtual clock,
        deterministic) and the background worker (wall clock).
        """
        with self._step_lock:
            return self._step_locked()

    def _step_locked(self) -> SchedEvent | None:
        now = self.clock.now()
        n = len(self.queues)
        if n == 0:
            return None

        # expire elapsed retry backoffs; move late submissions off
        # quarantined queues before anything can grant from them
        for qname, until in list(self._backoff_until.items()):
            if until <= now:
                del self._backoff_until[qname]
        if self._quarantined:
            for q in self.queues:
                if q.name in self._quarantined and q.pending():
                    self._migrate_pending(q)

        # retire finished prefetches before stalls: a joined stall's packet
        # must find its role resident when the grant loop re-reaches it
        self._retire_prefetches(now)

        # retire finished stalls so their queues become eligible
        for qname, stall in list(self._stalls.items()):
            if stall.future is not None:
                if not stall.future.done():
                    continue
                end = self.clock.now()
                stall.error = stall.future.result()[1]
            elif stall.end_t <= now:
                end = stall.end_t
            else:
                continue
            del self._stalls[qname]
            st = self.stats[qname]
            if stall.joined:
                # riding a prefetch: only the residual wait past compute
                # availability is exposed; the load itself retires with the
                # prefetch (reconfig_hidden).  No reconfig_end — the paired
                # prefetch_end marks the load's completion on the timeline.
                exposed = (
                    stall.exposed_s if stall.future is None
                    else max(0.0, end - stall.start_t)
                )
                st.reconfig_s += exposed
                if exposed > 0.0:
                    self.ledger.record(
                        ledger_mod.RECONFIG_EXPOSED, exposed, queue=qname,
                        role=stall.role_name, joined=True,
                    )
            else:
                st.reconfigs += 1
                st.reconfig_s += end - stall.start_t
                self.ledger.record(
                    ledger_mod.RECONFIG_EXPOSED, end - stall.start_t,
                    queue=qname, role=stall.role_name,
                )
                self._log(end, "reconfig_end", qname, stall.role_name)
            if stall.error is not None:
                q = next(qq for qq in self.queues if qq.name == qname)
                pkt = q.peek()
                if isinstance(pkt, KernelDispatchPacket):
                    if isinstance(stall.error, FaultError) and self.retry is not None:
                        # transient load fault: clean up through the
                        # abort_prefetch path and retry the load with
                        # backoff instead of failing the head packet
                        ev = self._load_fault(q, pkt, stall, end)
                        if ev is not None:
                            return ev
                    # the load can never succeed (e.g. all regions pinned,
                    # or the retry budget ran out): surface it to the
                    # waiter instead of re-stalling forever
                    return self._fail(q, pkt, stall.error, end)

        # speculate for blocked queues before granting: a prefetch issued at
        # the same virtual instant never delays this step's grants, and the
        # reconfiguration engine ordering still favors demand because flowing
        # queues contribute no candidates
        ev = self._issue_prefetches(now)
        if ev is not None:
            return ev

        # pump registered refill sources at the same point in the step: a
        # parked request scheduled for resume is a "role named in a
        # lookahead window" one tier down, and its H2D refill is issued on
        # the transfer engine ahead of the resume that would stall on it
        for pump in self._refill_sources:
            pump()

        order = self._grant_order
        width = len(order)
        if self.policy == RANDOM:
            probes = list(range(width))
            self._rng.shuffle(probes)          # seeded: reproducible schedules
        else:
            probes = [(self._grant_ptr + k) % width for k in range(width)]
        for gi in probes:
            qi = order[gi]
            q = self.queues[qi]
            if q.name in self._stalls or q.name in self._quarantined:
                continue
            if self._backoff_until.get(q.name, 0.0) > now:
                continue
            pkt = q.peek()
            if pkt is None:
                continue
            if not self._deps_zero(pkt.deps):
                continue
            if self.policy != RANDOM:
                self._grant_ptr = (gi + 1) % width
            return self._grant(q, pkt, now)

        # nothing ready now: on a virtual clock, jump to the next retire
        # (stall, in-flight prefetch, or retry-backoff expiry — whichever
        # completes first)
        if self._virtual:
            targets = (
                [s.end_t for s in self._stalls.values()]
                + [p.end_t for p in self._prefetches.values()]
                + [b for b in self._backoff_until.values() if b > now]
            )
            if targets:
                self.clock.advance_to(min(targets))
                return self._step_locked()

        if (
            self._virtual
            and not self._stalls
            and not self._prefetches
            and any(q.pending() for q in self.queues)
        ):
            # on the virtual clock every producer has already run: a non-ready
            # head can never become ready.  On a wall clock another producer
            # thread may still satisfy the dependency — just report no progress.
            heads = [
                f"{q.name}:{q.peek().__class__.__name__}"
                for q in self.queues if q.pending()
            ]
            raise SchedulerDeadlock(
                f"pending packets can never become ready: {heads} "
                "(dependency signal never reaches 0)"
            )
        return None

    def _grant(self, q: Queue, pkt: Packet, now: float) -> SchedEvent:
        """Process one granted packet — and, when it opened a burst, keep
        draining that burst in the same wakeup (burst AQL submission: one
        doorbell delivered N packets, so one grant pass retires up to N).

        The drain stops at the first packet that cannot flow — stalled on a
        reconfiguration, or deps unsatisfied — and never crosses a burst
        boundary, so round-robin fairness is preserved at burst granularity
        (a tenant's turn covers its burst, not its whole queue).
        """
        ev = self._process(q, pkt, now)
        bid = getattr(pkt, "burst_id", None)
        if not self.burst_grants or bid is None:
            return ev
        while (
            q.name not in self._stalls
            and self._backoff_until.get(q.name, 0.0) <= self.clock.now()
        ):
            nxt = q.peek()
            if nxt is None or getattr(nxt, "burst_id", None) != bid:
                break
            if not self._deps_zero(nxt.deps):
                break
            ev = self._process(q, nxt, self.clock.now())
        return ev

    # -- reconfiguration prefetch (the lookahead pipeline) -----------------------

    #: raw packets peeked per distinct-role window slot: consecutive
    #: same-role packets collapse into one *group* (they share a stall, so
    #: depth counts role switches, not packets), and the raw peek must be a
    #: multiple of the group window to see past a burst of repeats
    SCAN_BURST_FACTOR = 4

    def _scan_windows(self) -> tuple[dict, list]:
        """One pass over the stalls and every queue's lookahead window.

        Returns ``(ranks, candidates)``: roles demanded by in-flight stalls
        (rank -1) or queued packets, ranked by first-use distance (lower =
        sooner) — the victim search avoids these, and when it can't, evicts
        the one needed furthest in the future (approximate Bélády, the future
        read straight off the queues) — plus the ``(queue, role_key)``
        prefetch candidates from *blocked* queues (stalled, or head waiting
        on dependency signals; a stalled head itself is excluded — its stall
        already owns the load).

        Distance is measured in *distinct-role groups*, not raw packets:
        a burst of same-role packets is one reconfiguration however long it
        is, so ``lookahead=1`` means "the immediately-next role switch" —
        indexing by raw position would let any burst longer than the window
        hide the next role from shallow depths entirely.
        """
        ranks: dict = {
            s.role_key: -1 for s in self._stalls.values() if s.role_key is not None
        }
        candidates: list[tuple[Queue, Any]] = []
        if self.lookahead > 0:
            depth = self.lookahead + 1
            for q in self.queues:
                pkts = q.peek_window(self.SCAN_BURST_FACTOR * depth)
                if not pkts:
                    continue
                stalled = q.name in self._stalls
                blocked = stalled or not self._deps_zero(pkts[0].deps)
                d = -1                     # distinct-role group index
                prev: Any = object()       # sentinel: != every role key
                for pkt in pkts:
                    rk = getattr(pkt, "role_key", None)
                    if rk is None:
                        continue
                    if rk != prev:
                        d += 1
                        prev = rk
                        if d >= depth:
                            break
                        if ranks.get(rk, d + 1) > d:
                            ranks[rk] = d
                        if blocked and not (d == 0 and stalled):
                            candidates.append((q, rk))
        return ranks, candidates

    def _protected_keys(self) -> dict:
        return self._scan_windows()[0]

    def _issue_prefetches(self, now: float) -> SchedEvent | None:
        """Issue at most one speculative load for a blocked queue's window.

        Only queues that cannot grant right now (stalled, or head waiting on
        dependency signals) contribute candidates: a flowing queue's next miss
        is imminent demand, and speculation must not steal the reconfiguration
        engine from it.  In-flight speculation is capped strictly below the
        region count so a demand miss always finds an evictable slot (a
        single-region device therefore never speculates).  The synchronous
        baseline (``overlap_reconfig=False``) models a device with no
        separate reconfiguration engine, so it never prefetches either.
        """
        la = self.lookahead
        if la <= 0 or not self.queues or not self.overlap_reconfig:
            return None
        # the cap counts pinned slots too: slots that are pinned or mid-load
        # can never be eviction victims, so leaving one evictable slot for
        # demand requires in-flight < regions - pinned - 1
        cap = self.regions.num_regions - self.regions.pinned_count - 1
        if len(self._prefetches) >= cap:
            return None
        stalled_keys = {
            s.role_key for s in self._stalls.values() if s.role_key is not None
        }
        protect, candidates = self._scan_windows()

        for q, key in candidates:
            if key in self._prefetches or key in stalled_keys:
                continue
            if self.regions.is_resident(key) or self.regions.is_prefetching(key):
                continue
            try:
                role = self.library.get(key)
            except KeyError:
                continue                       # demand path surfaces unknown roles
            start = max(now, self._reconfig_free_t)
            if self._reconfig_pool is not None and not self._virtual:
                fut = self._reconfig_pool.submit(
                    self._do_prefetch, role, q.name, protect, protect.get(key)
                )
                self._prefetches[key] = _Prefetch(
                    role=role, role_key=key, queue=q.name,
                    start_t=start, end_t=float("inf"), future=fut,
                )
                self.stats[q.name].prefetches += 1
                return self._log(start, "prefetch_start", q.name, role.name)
            try:
                res = self.regions.begin_prefetch(
                    role, queue=q.name, protect=protect,
                    target_rank=protect.get(key),
                )
            except FaultError:
                # injected load fault on a *speculative* load: account it
                # (it is a real fault of the reconfig engine) but don't
                # punish the beneficiary queue — demand will retry properly
                self.ledger.record(
                    ledger_mod.FAULT, 0.0, queue=q.name, what=role.name,
                    kind="load",
                )
                self.ledger.record_fault(kind="load")
                self._log(start, "fault", q.name, f"{role.name}!load")
                continue
            except RuntimeError:
                continue    # structural (all pinned): the demand path fails it
            if res is None:
                continue    # no evictable region right now: best effort only
            dur = self.cost_model("reconfig", role.name, res.reconfig_s)
            end = start + dur
            self._reconfig_free_t = end
            self._prefetches[key] = _Prefetch(
                role=role, role_key=key, queue=q.name, start_t=start, end_t=end,
            )
            self.stats[q.name].prefetches += 1
            return self._log(start, "prefetch_start", q.name, role.name)
        return None

    def _do_prefetch(
        self, role: Any, qname: str, protect: dict, target_rank: int | None = None
    ) -> tuple[float, BaseException | None, bool]:
        """Threaded speculative load; (measured seconds, error, started)."""
        try:
            res = self.regions.begin_prefetch(
                role, queue=qname, protect=protect, target_rank=target_rank
            )
            if res is None:
                return 0.0, None, False
            return res.reconfig_s, None, True
        except BaseException as e:
            return 0.0, e, False

    def _retire_prefetches(self, now: float) -> None:
        for key, pf in list(self._prefetches.items()):
            if pf.future is not None:
                if not pf.future.done():
                    continue
                end = self.clock.now()
                _, pf.error, pf.started = pf.future.result()
            elif pf.end_t <= now:
                end = pf.end_t
            else:
                continue
            del self._prefetches[key]
            self._finish_prefetch(pf, end)

    def _finish_prefetch(self, pf: _Prefetch, end: float) -> None:
        st = self.stats.get(pf.queue)
        if pf.error is not None:
            self.regions.abort_prefetch(pf.role_key)
            if isinstance(pf.error, FaultError):
                self.ledger.record(
                    ledger_mod.FAULT, 0.0, queue=pf.queue, what=pf.role.name,
                    kind="load",
                )
                self.ledger.record_fault(kind="load")
            self._log(end, "prefetch_end", pf.queue, f"{pf.role.name}!error")
            return
        if not pf.started:
            if st is not None:
                st.prefetches -= 1         # the worker declined: never issued
            self._log(end, "prefetch_end", pf.queue, f"{pf.role.name}!skipped")
            return
        if not self.regions.complete_prefetch(pf.role_key, fresh=not pf.joined):
            # the in-flight entry was flushed meanwhile: the load produced no
            # resident role, so there is no hidden time to credit (flush
            # already counted it as wasted)
            self._log(end, "prefetch_end", pf.queue, f"{pf.role.name}!flushed")
            return
        if pf.future is not None:
            # threaded joins can't precompute their exposure (the load's end
            # is unknown at join time): claim it now from the live joined
            # stalls so the overlap window isn't double-counted as both
            # exposed and hidden
            for stall in self._stalls.values():
                if stall.joined and stall.role_key == pf.role_key:
                    pf.exposed_s = max(pf.exposed_s, end - stall.start_t)
        hidden = max(0.0, (end - pf.start_t) - pf.exposed_s)
        self.ledger.record(
            ledger_mod.RECONFIG_HIDDEN, hidden, queue=pf.queue, role=pf.role.name,
        )
        if st is not None:
            st.reconfig_hidden_s += hidden
        self._log(end, "prefetch_end", pf.queue, pf.role.name)

    def _join_prefetch(
        self, q: Queue, pkt: KernelDispatchPacket, role: Any, pf: _Prefetch,
        now: float,
    ) -> SchedEvent:
        """A demand miss found its role already in flight: ride the prefetch
        instead of double-loading (the lookahead pipeline's payoff)."""
        pkt._reconfigured = True
        self.stats[q.name].prefetch_hits += 1
        start = max(now, self._deps_time(pkt.deps, now))
        if pf.future is None and pf.end_t <= max(start, self._compute_free_t):
            # load finishes before this packet could execute anyway: fully
            # hidden.  Retire the prefetch (its end is in the causal past)
            # and execute without stalling the queue.  First-touch accounting
            # in the exec path counts the prefetch hit.
            del self._prefetches[role.key]
            self._finish_prefetch(pf, pf.end_t)
            self._log(start, "prefetch_hit", q.name, role.name)
            return self._exec(q, pkt, role, now)
        pf.joined = True
        self.regions.note_prefetch_join(role.key)
        exposed = (
            max(0.0, pf.end_t - max(start, self._compute_free_t))
            if pf.future is None else 0.0
        )
        # every joiner's exposure window ends at pf.end_t, so overlapping
        # joins nest: the union (max), not the sum, is what the load hid
        pf.exposed_s = max(pf.exposed_s, exposed)
        self._stalls[q.name] = _Stall(
            role.name, start, pf.end_t, future=pf.future, role_key=role.key,
            joined=True, exposed_s=exposed,
        )
        return self._log(start, "prefetch_hit", q.name, role.name)

    # -- packet processing -------------------------------------------------------

    def _process(self, q: Queue, pkt: Packet, now: float) -> SchedEvent:
        if isinstance(pkt, BarrierAndPacket):
            q.pop()
            t = self._deps_time(pkt.deps, now)
            err = self._deps_error(pkt.deps)
            self.stats[q.name].barriers += 1
            self._note_done(pkt)
            what = f"and[{len(pkt.deps)}]" + ("!error" if err is not None else "")
            ev = self._log(t, "barrier", q.name, what)
            self._complete(pkt.completion, t, error=err)
            return ev

        assert isinstance(pkt, KernelDispatchPacket)
        dep_err = self._deps_error(pkt.deps)
        if dep_err is not None:
            # an upstream dependency failed: this packet must not run on its
            # (missing) result — fail it with the propagated error, which its
            # own completion signal carries onward through the chain
            return self._fail(q, pkt, dep_err, now)
        role = None
        if pkt.role_key is not None:
            try:
                role = self.library.get(pkt.role_key)
            except KeyError as e:
                return self._fail(q, pkt, e, now)
            if not self.regions.is_resident(role.key):
                pf = self._prefetches.get(role.key)
                if pf is not None and pf.error is None:
                    return self._join_prefetch(q, pkt, role, pf, now)
                # not resident — even if a prior stall loaded it and another
                # tenant evicted it since: stall (again) with full accounting
                # rather than reloading invisibly at exec time
                return self._begin_reconfig(q, pkt, role, now)
        return self._exec(q, pkt, role, now)

    def _fail(self, q: Queue, pkt: KernelDispatchPacket, err: BaseException,
              now: float) -> SchedEvent:
        q.pop()
        pkt.out.error = err
        self._note_done(pkt)
        ev = self._log(now, "error", q.name, pkt.what)
        self._complete(pkt.completion, now, error=err)
        return ev

    # -- fault handling (retry / backoff / watchdog / quarantine) ---------------

    _WATCHDOG_FALLBACK = RetryPolicy()

    def _watchdog_s(self, what: str) -> float:
        """Watchdog window for one launch of ``what`` — how long a wedged
        launch occupies the compute engine before being killed."""
        e = self.expected_exec_s
        expected = 0.0 if e is None else (e(what) if callable(e) else float(e))
        policy = self.retry if self.retry is not None else self._WATCHDOG_FALLBACK
        return policy.watchdog_deadline(expected)

    def _handle_fault(self, q: Queue, pkt: KernelDispatchPacket,
                      err: BaseException, *, kind: str, seconds: float,
                      t: float) -> SchedEvent:
        """A launch attempt died to a hardware-class fault (already popped):
        account it, then retry in place with backoff or fail the packet."""
        permanent = isinstance(err, PermanentFault)
        self.ledger.record(
            ledger_mod.FAULT, seconds, queue=q.name, what=pkt.what, kind=kind,
        )
        self.ledger.record_fault(kind=kind, permanent=permanent)
        self._log(t, "fault", q.name, f"{pkt.what}!{kind}")
        k = self._consecutive_faults.get(q.name, 0) + 1
        self._consecutive_faults[q.name] = k

        attempts = getattr(pkt, "_attempts", 1)
        retryable = (
            self.retry is not None
            and not permanent
            and attempts <= self.retry.max_retries
        )
        if retryable:
            pkt._attempts = attempts + 1
            pkt.out.error = None
            q.requeue_head(pkt)
            backoff = self.retry.backoff(attempts)
            self._backoff_until[q.name] = max(
                self._backoff_until.get(q.name, 0.0), t + backoff
            )
            self.ledger.record(
                ledger_mod.RETRY, backoff, queue=q.name, what=pkt.what,
            )
            self.ledger.record_retry()
            ev = self._log(t, "retry", q.name, f"{pkt.what}#{attempts}")
        else:
            pkt.out.error = err
            self._note_done(pkt)
            ev = self._log(t, "error", q.name, pkt.what)
            self._complete(pkt.completion, t, error=err)
        self._maybe_quarantine(q, k, t)
        return ev

    def _load_fault(self, q: Queue, pkt: KernelDispatchPacket, stall: _Stall,
                    t: float) -> SchedEvent | None:
        """A demand region load died to a transient fault.  Clean up through
        the abort_prefetch path and retry the load (the head packet stays
        queued; the grant loop re-stalls it after the backoff).  Returns None
        when the retry budget is exhausted — the caller fails the packet."""
        attempts = getattr(pkt, "_attempts", 1)
        self.ledger.record(
            ledger_mod.FAULT, max(0.0, t - stall.start_t), queue=q.name,
            what=stall.role_name, kind="load",
        )
        self.ledger.record_fault(kind="load")
        self._log(t, "fault", q.name, f"{stall.role_name}!load")
        k = self._consecutive_faults.get(q.name, 0) + 1
        self._consecutive_faults[q.name] = k
        if attempts > self.retry.max_retries:
            self._maybe_quarantine(q, k, t)
            return None
        if stall.role_key is not None:
            self.regions.abort_prefetch(stall.role_key)
        pkt._attempts = attempts + 1
        backoff = self.retry.backoff(attempts)
        self._backoff_until[q.name] = max(
            self._backoff_until.get(q.name, 0.0), t + backoff
        )
        self.ledger.record(
            ledger_mod.RETRY, backoff, queue=q.name, what=stall.role_name,
        )
        self.ledger.record_retry()
        ev = self._log(t, "retry", q.name, f"{stall.role_name}#{attempts}")
        self._maybe_quarantine(q, k, t)
        return ev

    def _maybe_quarantine(self, q: Queue, consecutive: int, t: float) -> None:
        if (
            self.retry is None
            or self.retry.quarantine_after <= 0
            or consecutive < self.retry.quarantine_after
            or q.name in self._quarantined
        ):
            return
        siblings = [
            qq for qq in self.queues
            if qq.name != q.name and qq.name not in self._quarantined
        ]
        if not siblings:
            # a lone queue has nowhere to send its packets: keep serving it
            # (resetting the streak so the check doesn't fire every fault)
            self._consecutive_faults[q.name] = 0
            return
        self._quarantined.add(q.name)
        self._backoff_until.pop(q.name, None)
        n = self._migrate_pending(q)
        self.ledger.record_quarantine(migrated=n)
        self._log(t, "quarantine", q.name, f"migrated[{n}]")

    def _migrate_pending(self, q: Queue) -> int:
        """Round-robin every pending packet of ``q`` onto non-quarantined
        sibling queues.  Packets keep their enqueue_t (WAIT accounting spans
        the migration) and are tagged with their origin so ``drain(q)`` still
        waits for them."""
        siblings = [
            qq for qq in self.queues
            if qq.name != q.name and qq.name not in self._quarantined
        ]
        if not siblings:
            return 0
        n = 0
        while True:
            pkt = q.pop()
            if pkt is None:
                break
            if getattr(pkt, "_migrated_from", None) is None:
                pkt._migrated_from = q.name
                self._migrated_counts[q.name] = (
                    self._migrated_counts.get(q.name, 0) + 1
                )
            siblings[n % len(siblings)].submit(pkt)
            n += 1
        return n

    def reinstate(self, name: str) -> None:
        """Lift a queue's quarantine (operator action / sibling recovered)."""
        self._quarantined.discard(name)
        self._consecutive_faults.pop(name, None)

    @property
    def quarantined_queues(self) -> frozenset[str]:
        return frozenset(self._quarantined)

    def _begin_reconfig(self, q: Queue, pkt: KernelDispatchPacket, role: Any,
                        now: float) -> SchedEvent:
        """Stall *this queue only* while the role loads into a region."""
        pkt._reconfigured = True
        engine_free = (
            self._reconfig_free_t if self.overlap_reconfig else self._compute_free_t
        )
        # deps gate the grant in *virtual* time too: eligibility is checked on
        # live signal state, which runs ahead of the simulated timeline
        start = max(now, engine_free, self._deps_time(pkt.deps, now))
        protect = self._protected_keys()

        if self._reconfig_pool is not None and not self._virtual:
            fut = self._reconfig_pool.submit(self._do_reconfig, role, q.name, protect)
            self._stalls[q.name] = _Stall(
                role.name, start, float("inf"), future=fut, role_key=role.key,
            )
            return self._log(start, "reconfig_start", q.name, role.name)

        measured, err, _ = self._do_reconfig(role, q.name, protect)
        dur = self.cost_model("reconfig", role.name, measured)
        end = start + dur
        if self.overlap_reconfig:
            self._reconfig_free_t = end
        else:
            self._compute_free_t = end        # sync baseline: device does the load
        self._stalls[q.name] = _Stall(
            role.name, start, end, error=err, role_key=role.key,
        )
        return self._log(start, "reconfig_start", q.name, role.name)

    def _do_reconfig(
        self, role: Any, qname: str, protect: dict | frozenset = frozenset()
    ) -> tuple[float, BaseException | None, bool]:
        """Load the role; returns (measured seconds, error-or-None, started)."""
        try:
            res = self.regions.ensure_resident(role, queue=qname, protect=protect)
            return res.reconfig_s, None, True
        except BaseException as e:
            return 0.0, e, False

    def _exec(self, q: Queue, pkt: KernelDispatchPacket, role: Any,
              now: float) -> SchedEvent:
        g0 = time.perf_counter_ns()        # grant leg: pick-up -> launch returned
        start = max(now, self._compute_free_t, self._deps_time(pkt.deps, now))
        q.pop()
        st = self.stats[q.name]
        if getattr(pkt, "_attempts", 1) == 1:
            # retries keep the original enqueue_t; WAIT is the first attempt's
            # (the retry delay is priced separately as RETRY backoff)
            wait = max(
                0.0,
                start - (pkt.enqueue_t if pkt.enqueue_t is not None else start),
            )
            st.wait_s += wait
            self.ledger.record(
                ledger_mod.WAIT, wait, queue=q.name, what=pkt.what,
                producer=pkt.producer,
            )
        self._log(start, "exec_start", q.name, pkt.what)

        fault = (
            self.faults.draw_exec(pkt.what, queue=q.name)
            if self.faults is not None else None
        )
        wedged = isinstance(fault, WedgedLaunch)
        measured = 0.0
        if fault is not None:
            pkt.out.error = fault
        else:
            try:
                t0 = time.perf_counter_ns()
                if role is not None:
                    if getattr(pkt, "_reconfigured", False):
                        # stall already accounted this packet's lookup; if the role
                        # was evicted meanwhile (or its reconfig failed), re-load
                        # properly instead of executing outside region management
                        if not self.regions.touch(role.key):
                            # lazy protect: the window scan only runs if this
                            # lookup actually misses and must evict
                            self.regions.ensure_resident(
                                role, queue=q.name, protect=self._protected_keys
                            )
                    else:
                        self.regions.ensure_resident(
                            role, queue=q.name, protect=self._protected_keys
                        )
                    out = role(*pkt.args)
                else:
                    out = pkt.fn(*pkt.args)
                t1 = time.perf_counter_ns()
                self.ledger.record(
                    ledger_mod.DISPATCH, (t1 - t0) * 1e-9,
                    role=pkt.what, producer=pkt.producer, queue=q.name,
                )
                self.ledger.record(
                    ledger_mod.DISPATCH_GRANT, (t1 - g0) * 1e-9,
                    role=pkt.what, producer=pkt.producer, queue=q.name,
                    burst=pkt.burst_n,
                )
                out = jax.block_until_ready(out)
                t2 = time.perf_counter_ns()
                self.ledger.record(
                    ledger_mod.EXEC, (t2 - t1) * 1e-9, role=pkt.what, queue=q.name
                )
                measured = (t2 - t0) * 1e-9
                pkt.out.value = out
            except BaseException as e:      # surface to waiter, don't kill the loop
                pkt.out.error = e

        if wedged:
            # the launch never completes: only the watchdog ends it, and the
            # attempt is charged its full deadline window on the timeline
            dur = self._watchdog_s(pkt.what)
        else:
            # keyed by role.name to match the reconfig path (calibration dicts
            # use role names, not shape-specialized key strings)
            dur = self.cost_model(
                "exec", role.name if role is not None else pkt.what, measured
            )
        end = start + dur
        self._compute_free_t = end
        self._busy_s += dur

        err = pkt.out.error
        if isinstance(err, FaultError):
            kind = ("wedge" if wedged
                    else "load" if isinstance(err, InjectedLoadFault)
                    else "exec")
            return self._handle_fault(q, pkt, err, kind=kind, seconds=dur, t=end)
        self._consecutive_faults.pop(q.name, None)
        st.exec_s += dur
        st.dispatched += 1
        self._note_done(pkt)
        ev = self._log(end, "exec_end", q.name, pkt.what)
        self._complete(pkt.completion, end, error=err)
        return ev

    # -- cooperative driving -------------------------------------------------------

    def run_until_idle(self, max_steps: int = 1_000_000) -> int:
        """Drive the loop until every queue is empty; returns packets completed."""
        before = self._completed
        for _ in range(max_steps):
            ev = self.step()
            if ev is None:
                if self._await_stall():
                    continue
                if any(q.pending() for q in self.queues):
                    # wall clock: a dependency owned by another producer thread
                    # may clear any moment (legacy drain blocked here too)
                    self.clock.sleep(0.0002)
                    continue
                break
        else:
            raise RuntimeError(f"scheduler did not go idle in {max_steps} steps")
        return self._completed - before

    def _await_stall(self) -> bool:
        """Block on an in-flight threaded reconfig or prefetch (lock-safe peek)."""
        with self._step_lock:
            fut = next(
                (s.future for s in self._stalls.values() if s.future is not None),
                None,
            ) or next(
                (p.future for p in self._prefetches.values() if p.future is not None),
                None,
            )
        if fut is None:
            return False
        fut.result()
        return True

    def drain(self, queue: Queue | None = None, max_steps: int = 1_000_000) -> int:
        """Synchronously run until ``queue`` is empty (all queues when None).

        Unlike ``run_until_idle`` this does not insist the *other* tenants'
        queues go idle: a dep-blocked packet on someone else's queue must not
        wedge this producer's drain.  Returns packets completed meanwhile
        (other queues' packets may ride along — one compute engine).
        """
        if queue is None:
            return self.run_until_idle(max_steps)
        if all(q is not queue for q in self.queues):
            self.add_queue(queue)
        before = self._completed
        for _ in range(max_steps):
            if (
                queue.pending() == 0
                and queue.name not in self._stalls
                and not self._migrated_counts.get(queue.name)
            ):
                break
            ev = self.step()
            if ev is None and not self._await_stall():
                self.clock.sleep(0.0002)      # wall clock: await foreign producer
        else:
            raise RuntimeError(f"queue {queue.name} did not drain in {max_steps} steps")
        return self._completed - before

    @property
    def running(self) -> bool:
        """True while the threaded worker owns the consume side."""
        return self._worker is not None

    # -- threaded driving ----------------------------------------------------------

    def start(self, poll_s: float = 0.0005, reconfig_workers: int = 1) -> None:
        if self._worker is not None:
            raise RuntimeError("scheduler already running")
        if self._virtual:
            raise RuntimeError("threaded mode requires a wall clock")
        self._stop.clear()
        self._reconfig_pool = ThreadPoolExecutor(
            max_workers=reconfig_workers, thread_name_prefix="hsa-reconfig"
        )

        def loop() -> None:
            last = -1
            while not self._stop.is_set():
                try:
                    progressed = self.step() is not None
                except SchedulerDeadlock:
                    progressed = False        # producers may still unblock us
                if progressed:
                    continue
                with self._work:
                    if self._doorbell_counter == last:
                        self._work.wait(timeout=poll_s)
                    last = self._doorbell_counter

        self._worker = threading.Thread(target=loop, name="hsa-scheduler", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        if self._worker is not None:
            self._stop.set()
            self._ring()
            self._worker.join(timeout=5.0)
            self._worker = None
        if self._reconfig_pool is not None:
            self._reconfig_pool.shutdown(wait=True)
            self._reconfig_pool = None

    # -- reporting ------------------------------------------------------------------

    def event_log(self) -> list[SchedEvent]:
        """Events in timeline order (stable on simultaneous timestamps)."""
        return sorted(self.events, key=lambda e: (e.t, e.seq))

    def timeline(self) -> dict[str, float]:
        """Makespan / busy / idle accounting for the device's compute engine."""
        end = max(
            [self._compute_free_t, self.clock.now()]
            + [s.end_t for s in self._stalls.values() if s.end_t != float("inf")]
        )
        makespan = max(0.0, end - self._t0)
        busy = self._busy_s
        return {
            "makespan_s": makespan,
            "busy_s": busy,
            "idle_s": max(0.0, makespan - busy),
            "idle_fraction": (max(0.0, makespan - busy) / makespan) if makespan else 0.0,
        }

    def queue_report(self) -> dict[str, dict[str, float]]:
        return {
            name: {
                "wait_s": st.wait_s,
                "exec_s": st.exec_s,
                "reconfig_s": st.reconfig_s,
                "reconfig_hidden_s": st.reconfig_hidden_s,
                "dispatched": float(st.dispatched),
                "barriers": float(st.barriers),
                "reconfigs": float(st.reconfigs),
                "prefetches": float(st.prefetches),
                "prefetch_hits": float(st.prefetch_hits),
            }
            for name, st in self.stats.items()
        }

    def exposed_reconfig_s(self) -> float:
        """Total queue-stalling (exposed) reconfiguration time — the quantity
        the lookahead prefetcher drives toward zero (paper Table II row 2)."""
        return sum(st.reconfig_s for st in self.stats.values())
