"""Async multi-queue packet scheduler — the runtime half of transparent dispatch.

The paper's FPGA is shared dynamically at runtime: kernels arrive on HSA
user-level queues from several producers at once (the TensorFlow engine,
OpenCL/OpenMP clients), and the device reconfigures regions on demand.  This
scheduler is that sharing layer:

  - N *soft queues* per agent; AQL packets carry completion signals, and
    kernel packets / barrier-AND packets carry dependency signals.
  - A doorbell-driven loop round-robins (or weight-round-robins) *ready*
    packets across queues: a packet is ready when its queue is not stalled
    and every dependency signal reads 0.
  - Reconfiguration stalls only the queue that missed residency.  The
    reconfiguration engine (the FPGA's ICAP; here the XLA load path) is
    modeled separately from the compute engine, so an independent queue keeps
    executing while another queue's region loads.  ``overlap_reconfig=False``
    recovers the synchronous baseline where reconfiguration occupies the
    device — the comparison benchmarks/table4 measures.
  - Per-queue wait / exec / reconfig time lands in the overhead ledger
    (``queue=`` meta → ``OverheadLedger.queue_breakdown()``).

Determinism: the scheduler takes an injectable clock.  With a
:class:`~repro.core.hsa.clock.VirtualClock` the whole schedule is a
discrete-event simulation — no threads, no sleeps — and the event log is
bit-for-bit reproducible, which is what the interleaving tests assert.
Durations on the virtual timeline come from ``cost_model(kind, what,
measured_s)``; by default the actually-measured execution time is used.
With a :class:`WallClock` the same code path runs threaded (``start()``)
with reconfigurations offloaded to a background worker.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Iterable

import jax

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.hsa.clock import Clock, VirtualClock, WallClock
from repro.core.hsa.queue import BarrierAndPacket, KernelDispatchPacket, Packet, Queue
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary

ROUND_ROBIN = "round_robin"
WEIGHTED = "weighted"
RANDOM = "random"
POLICIES = (ROUND_ROBIN, WEIGHTED, RANDOM)


class SchedulerDeadlock(RuntimeError):
    """No packet can ever become ready (unsatisfiable dependency)."""


@dataclasses.dataclass(frozen=True)
class SchedEvent:
    """One entry of the deterministic event log."""

    t: float
    kind: str  # exec_start | exec_end | reconfig_start | reconfig_end | barrier | error
    queue: str
    what: str
    seq: int = 0

    def brief(self) -> tuple[str, str, str]:
        return (self.kind, self.queue, self.what)


@dataclasses.dataclass
class QueueStats:
    wait_s: float = 0.0
    exec_s: float = 0.0
    reconfig_s: float = 0.0
    dispatched: int = 0
    barriers: int = 0
    reconfigs: int = 0


@dataclasses.dataclass
class _Stall:
    """An in-progress reconfiguration attributed to one queue."""

    role_name: str
    start_t: float
    end_t: float                      # virtual end (cooperative) / inf (threaded)
    future: Future | None = None      # threaded mode only
    error: BaseException | None = None  # load failed: fail the head packet at retire


def _default_cost(kind: str, what: str, measured_s: float) -> float:
    del kind, what
    return measured_s


class Scheduler:
    """Doorbell-driven multi-queue packet scheduler over one agent's engines."""

    def __init__(
        self,
        regions: RegionManager,
        library: RoleLibrary,
        *,
        ledger: OverheadLedger = GLOBAL_LEDGER,
        clock: Clock | None = None,
        policy: str = ROUND_ROBIN,
        seed: int = 0,
        cost_model: Callable[[str, str, float], float] | None = None,
        overlap_reconfig: bool = True,
        keep_events: int = 100_000,
    ) -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choose from {POLICIES}")
        self.regions = regions
        self.library = library
        self.ledger = ledger
        self.clock: Clock = clock if clock is not None else WallClock()
        # honor the Clock protocol's `virtual` flag so user-supplied
        # deterministic clocks get virtual-time semantics too
        self._virtual = bool(getattr(self.clock, "virtual", False))
        self.policy = policy
        self.cost_model = cost_model or _default_cost
        self.overlap_reconfig = overlap_reconfig
        self.keep_events = keep_events

        self.queues: list[Queue] = []
        self.stats: dict[str, QueueStats] = {}
        self.events: list[SchedEvent] = []
        self.dropped_events = 0

        self._rng = random.Random(seed)
        self._grant_order: list[int] = []
        self._grant_ptr = 0
        self._stalls: dict[str, _Stall] = {}       # queue name -> reconfig in flight
        self._seq = 0
        self._t0 = self.clock.now()
        self._compute_free_t = self._t0
        self._reconfig_free_t = self._t0
        self._busy_s = 0.0
        self._completed = 0

        self._doorbell_counter = 0
        self._work = threading.Condition()
        # serializes consumers: the worker thread and a legacy synchronous
        # drain() may step concurrently; peek-then-pop must stay atomic
        self._step_lock = threading.RLock()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        self._reconfig_pool: ThreadPoolExecutor | None = None

    # -- queue management -----------------------------------------------------

    def add_queue(self, queue: Queue) -> Queue:
        if any(q.name == queue.name for q in self.queues):
            raise ValueError(f"duplicate queue name {queue.name!r}")
        queue.clock = self.clock
        queue._notify = self._ring                 # doorbell fan-in
        self.queues.append(queue)
        self.stats[queue.name] = QueueStats()
        self._rebuild_grants()
        return queue

    def create_queue(
        self, agent: Any = None, *, name: str | None = None, size: int = 256,
        weight: int = 1,
    ) -> Queue:
        return self.add_queue(Queue(agent, size, name=name, weight=weight))

    def _rebuild_grants(self) -> None:
        order: list[int] = []
        for i, q in enumerate(self.queues):
            order.extend([i] * (q.weight if self.policy == WEIGHTED else 1))
        self._grant_order = order
        self._grant_ptr = self._grant_ptr % max(1, len(order))

    def _ring(self) -> None:
        with self._work:
            self._doorbell_counter += 1
            self._work.notify_all()

    # -- readiness ------------------------------------------------------------

    def _deps_zero(self, deps: Iterable[Any]) -> bool:
        return all(d.load() == 0 for d in deps)

    def _deps_time(self, deps: Iterable[Any], now: float) -> float:
        # completion times ride on the signal objects themselves: lifetime is
        # exactly the signal's, so no unbounded id-keyed map / stale-id reuse
        return max([now] + [getattr(d, "_complete_t", now) for d in deps])

    def _complete(self, sig: Any, t: float) -> None:
        if sig is not None:
            sig._complete_t = t
            sig.store(0)

    def _log(self, t: float, kind: str, queue: str, what: str) -> SchedEvent:
        ev = SchedEvent(t=t, kind=kind, queue=queue, what=what, seq=self._seq)
        self._seq += 1
        if len(self.events) < self.keep_events:
            self.events.append(ev)
        else:
            self.dropped_events += 1
        return ev

    # -- the scheduling step ----------------------------------------------------

    def step(self) -> SchedEvent | None:
        """Process at most one packet (or retire one stall); None when idle.

        Cooperative core shared by ``run_until_idle`` (virtual clock,
        deterministic) and the background worker (wall clock).
        """
        with self._step_lock:
            return self._step_locked()

    def _step_locked(self) -> SchedEvent | None:
        now = self.clock.now()
        n = len(self.queues)
        if n == 0:
            return None

        # retire finished stalls first so their queues become eligible
        for qname, stall in list(self._stalls.items()):
            if stall.future is not None:
                if not stall.future.done():
                    continue
                end = self.clock.now()
                _, stall.error = stall.future.result()
            elif stall.end_t <= now:
                end = stall.end_t
            else:
                continue
            del self._stalls[qname]
            st = self.stats[qname]
            st.reconfigs += 1
            st.reconfig_s += end - stall.start_t
            self._log(end, "reconfig_end", qname, stall.role_name)
            if stall.error is not None:
                # the load can never succeed (e.g. all regions pinned):
                # surface it to the waiter instead of re-stalling forever
                q = next(qq for qq in self.queues if qq.name == qname)
                pkt = q.peek()
                if isinstance(pkt, KernelDispatchPacket):
                    return self._fail(q, pkt, stall.error, end)

        order = self._grant_order
        width = len(order)
        if self.policy == RANDOM:
            probes = list(range(width))
            self._rng.shuffle(probes)          # seeded: reproducible schedules
        else:
            probes = [(self._grant_ptr + k) % width for k in range(width)]
        for gi in probes:
            qi = order[gi]
            q = self.queues[qi]
            if q.name in self._stalls:
                continue
            pkt = q.peek()
            if pkt is None:
                continue
            if not self._deps_zero(pkt.deps):
                continue
            if self.policy != RANDOM:
                self._grant_ptr = (gi + 1) % width
            return self._process(q, pkt, now)

        # nothing ready now: on a virtual clock, jump to the next stall retire
        if self._virtual and self._stalls:
            target = min(s.end_t for s in self._stalls.values())
            self.clock.advance_to(target)
            return self._step_locked()

        if (
            self._virtual
            and not self._stalls
            and any(q.pending() for q in self.queues)
        ):
            # on the virtual clock every producer has already run: a non-ready
            # head can never become ready.  On a wall clock another producer
            # thread may still satisfy the dependency — just report no progress.
            heads = [
                f"{q.name}:{q.peek().__class__.__name__}"
                for q in self.queues if q.pending()
            ]
            raise SchedulerDeadlock(
                f"pending packets can never become ready: {heads} "
                "(dependency signal never reaches 0)"
            )
        return None

    # -- packet processing -------------------------------------------------------

    def _process(self, q: Queue, pkt: Packet, now: float) -> SchedEvent:
        if isinstance(pkt, BarrierAndPacket):
            q.pop()
            t = self._deps_time(pkt.deps, now)
            self.stats[q.name].barriers += 1
            self._completed += 1
            ev = self._log(t, "barrier", q.name, f"and[{len(pkt.deps)}]")
            self._complete(pkt.completion, t)
            return ev

        assert isinstance(pkt, KernelDispatchPacket)
        role = None
        if pkt.role_key is not None:
            try:
                role = self.library.get(pkt.role_key)
            except KeyError as e:
                return self._fail(q, pkt, e, now)
            if not self.regions.is_resident(role.key):
                # not resident — even if a prior stall loaded it and another
                # tenant evicted it since: stall (again) with full accounting
                # rather than reloading invisibly at exec time
                return self._begin_reconfig(q, pkt, role, now)
        return self._exec(q, pkt, role, now)

    def _fail(self, q: Queue, pkt: KernelDispatchPacket, err: BaseException,
              now: float) -> SchedEvent:
        q.pop()
        pkt.out.error = err
        self._completed += 1
        ev = self._log(now, "error", q.name, pkt.what)
        self._complete(pkt.completion, now)
        return ev

    def _begin_reconfig(self, q: Queue, pkt: KernelDispatchPacket, role: Any,
                        now: float) -> SchedEvent:
        """Stall *this queue only* while the role loads into a region."""
        pkt._reconfigured = True
        engine_free = (
            self._reconfig_free_t if self.overlap_reconfig else self._compute_free_t
        )
        # deps gate the grant in *virtual* time too: eligibility is checked on
        # live signal state, which runs ahead of the simulated timeline
        start = max(now, engine_free, self._deps_time(pkt.deps, now))

        if self._reconfig_pool is not None and not self._virtual:
            fut = self._reconfig_pool.submit(self._do_reconfig, role, q.name)
            self._stalls[q.name] = _Stall(role.name, start, float("inf"), future=fut)
            return self._log(start, "reconfig_start", q.name, role.name)

        measured, err = self._do_reconfig(role, q.name)
        dur = self.cost_model("reconfig", role.name, measured)
        end = start + dur
        if self.overlap_reconfig:
            self._reconfig_free_t = end
        else:
            self._compute_free_t = end        # sync baseline: device does the load
        self._stalls[q.name] = _Stall(role.name, start, end, error=err)
        return self._log(start, "reconfig_start", q.name, role.name)

    def _do_reconfig(self, role: Any, qname: str) -> tuple[float, BaseException | None]:
        """Load the role; returns (measured seconds, error-or-None)."""
        try:
            res = self.regions.ensure_resident(role, queue=qname)
            return res.reconfig_s, None
        except BaseException as e:
            return 0.0, e

    def _exec(self, q: Queue, pkt: KernelDispatchPacket, role: Any,
              now: float) -> SchedEvent:
        start = max(now, self._compute_free_t, self._deps_time(pkt.deps, now))
        q.pop()
        st = self.stats[q.name]
        wait = max(0.0, start - (pkt.enqueue_t if pkt.enqueue_t is not None else start))
        st.wait_s += wait
        self.ledger.record(
            ledger_mod.WAIT, wait, queue=q.name, what=pkt.what, producer=pkt.producer
        )
        self._log(start, "exec_start", q.name, pkt.what)

        measured = 0.0
        try:
            t0 = time.perf_counter_ns()
            if role is not None:
                if getattr(pkt, "_reconfigured", False):
                    # stall already accounted this packet's lookup; if the role
                    # was evicted meanwhile (or its reconfig failed), re-load
                    # properly instead of executing outside region management
                    if not self.regions.touch(role.key):
                        self.regions.ensure_resident(role, queue=q.name)
                else:
                    self.regions.ensure_resident(role, queue=q.name)
                out = role(*pkt.args)
            else:
                out = pkt.fn(*pkt.args)
            t1 = time.perf_counter_ns()
            self.ledger.record(
                ledger_mod.DISPATCH, (t1 - t0) * 1e-9,
                role=pkt.what, producer=pkt.producer, queue=q.name,
            )
            out = jax.block_until_ready(out)
            t2 = time.perf_counter_ns()
            self.ledger.record(
                ledger_mod.EXEC, (t2 - t1) * 1e-9, role=pkt.what, queue=q.name
            )
            measured = (t2 - t0) * 1e-9
            pkt.out.value = out
        except BaseException as e:          # surface to waiter, don't kill the loop
            pkt.out.error = e

        # keyed by role.name to match the reconfig path (calibration dicts use
        # role names, not shape-specialized key strings)
        dur = self.cost_model(
            "exec", role.name if role is not None else pkt.what, measured
        )
        end = start + dur
        self._compute_free_t = end
        self._busy_s += dur
        st.exec_s += dur
        st.dispatched += 1
        self._completed += 1
        ev = self._log(end, "exec_end", q.name, pkt.what)
        self._complete(pkt.completion, end)
        return ev

    # -- cooperative driving -------------------------------------------------------

    def run_until_idle(self, max_steps: int = 1_000_000) -> int:
        """Drive the loop until every queue is empty; returns packets completed."""
        before = self._completed
        for _ in range(max_steps):
            ev = self.step()
            if ev is None:
                if self._await_stall():
                    continue
                if any(q.pending() for q in self.queues):
                    # wall clock: a dependency owned by another producer thread
                    # may clear any moment (legacy drain blocked here too)
                    self.clock.sleep(0.0002)
                    continue
                break
        else:
            raise RuntimeError(f"scheduler did not go idle in {max_steps} steps")
        return self._completed - before

    def _await_stall(self) -> bool:
        """Block on an in-flight threaded reconfig, if any (lock-safe peek)."""
        with self._step_lock:
            fut = next(
                (s.future for s in self._stalls.values() if s.future is not None),
                None,
            )
        if fut is None:
            return False
        fut.result()
        return True

    def drain(self, queue: Queue | None = None, max_steps: int = 1_000_000) -> int:
        """Synchronously run until ``queue`` is empty (all queues when None).

        Unlike ``run_until_idle`` this does not insist the *other* tenants'
        queues go idle: a dep-blocked packet on someone else's queue must not
        wedge this producer's drain.  Returns packets completed meanwhile
        (other queues' packets may ride along — one compute engine).
        """
        if queue is None:
            return self.run_until_idle(max_steps)
        if all(q is not queue for q in self.queues):
            self.add_queue(queue)
        before = self._completed
        for _ in range(max_steps):
            if queue.pending() == 0 and queue.name not in self._stalls:
                break
            ev = self.step()
            if ev is None and not self._await_stall():
                self.clock.sleep(0.0002)      # wall clock: await foreign producer
        else:
            raise RuntimeError(f"queue {queue.name} did not drain in {max_steps} steps")
        return self._completed - before

    @property
    def running(self) -> bool:
        """True while the threaded worker owns the consume side."""
        return self._worker is not None

    # -- threaded driving ----------------------------------------------------------

    def start(self, poll_s: float = 0.0005, reconfig_workers: int = 1) -> None:
        if self._worker is not None:
            raise RuntimeError("scheduler already running")
        if self._virtual:
            raise RuntimeError("threaded mode requires a wall clock")
        self._stop.clear()
        self._reconfig_pool = ThreadPoolExecutor(
            max_workers=reconfig_workers, thread_name_prefix="hsa-reconfig"
        )

        def loop() -> None:
            last = -1
            while not self._stop.is_set():
                try:
                    progressed = self.step() is not None
                except SchedulerDeadlock:
                    progressed = False        # producers may still unblock us
                if progressed:
                    continue
                with self._work:
                    if self._doorbell_counter == last:
                        self._work.wait(timeout=poll_s)
                    last = self._doorbell_counter

        self._worker = threading.Thread(target=loop, name="hsa-scheduler", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        if self._worker is not None:
            self._stop.set()
            self._ring()
            self._worker.join(timeout=5.0)
            self._worker = None
        if self._reconfig_pool is not None:
            self._reconfig_pool.shutdown(wait=True)
            self._reconfig_pool = None

    # -- reporting ------------------------------------------------------------------

    def event_log(self) -> list[SchedEvent]:
        """Events in timeline order (stable on simultaneous timestamps)."""
        return sorted(self.events, key=lambda e: (e.t, e.seq))

    def timeline(self) -> dict[str, float]:
        """Makespan / busy / idle accounting for the device's compute engine."""
        end = max(
            [self._compute_free_t, self.clock.now()]
            + [s.end_t for s in self._stalls.values() if s.end_t != float("inf")]
        )
        makespan = max(0.0, end - self._t0)
        busy = self._busy_s
        return {
            "makespan_s": makespan,
            "busy_s": busy,
            "idle_s": max(0.0, makespan - busy),
            "idle_fraction": (max(0.0, makespan - busy) / makespan) if makespan else 0.0,
        }

    def queue_report(self) -> dict[str, dict[str, float]]:
        return {
            name: {
                "wait_s": st.wait_s,
                "exec_s": st.exec_s,
                "reconfig_s": st.reconfig_s,
                "dispatched": float(st.dispatched),
                "barriers": float(st.barriers),
                "reconfigs": float(st.reconfigs),
            }
            for name, st in self.stats.items()
        }
