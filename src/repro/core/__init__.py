"""repro.core — the paper's contribution: transparent accelerator dispatch.

Public surface:

  - ``dispatch.op(name, *args)`` / ``dispatch.use(...)`` — transparent op
    dispatch with scoped policy (the TF-frontend property),
  - ``registry`` — kernel registration (reference / xla / pallas sources),
  - ``hsa`` — agents, queues, signals, executor (the HSA runtime),
  - ``roles`` / ``reconfig`` — presynthesized programs + LRU region residency
    (the partial-reconfiguration model),
  - ``ledger`` — Table II overhead accounting,
  - ``policy`` — the generic-vs-fixed-weight role planner.
"""

from repro.core import dispatch, ledger, policy, reconfig, registry, roles
from repro.core.dispatch import DispatchContext, DispatchTrace, op, use
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.reconfig import RegionManager, ResidencyResult, ResidencyStats
from repro.core.registry import (
    FIXED_WEIGHT,
    GENERIC,
    GLOBAL_REGISTRY,
    KernelImpl,
    KernelRegistry,
    ResourceFootprint,
)
from repro.core.roles import ONLINE, PRESYNTHESIZED, Role, RoleKey, RoleLibrary

__all__ = [
    "dispatch",
    "ledger",
    "policy",
    "reconfig",
    "registry",
    "roles",
    "DispatchContext",
    "DispatchTrace",
    "op",
    "use",
    "GLOBAL_LEDGER",
    "OverheadLedger",
    "RegionManager",
    "ResidencyResult",
    "ResidencyStats",
    "FIXED_WEIGHT",
    "GENERIC",
    "GLOBAL_REGISTRY",
    "KernelImpl",
    "KernelRegistry",
    "ResourceFootprint",
    "ONLINE",
    "PRESYNTHESIZED",
    "Role",
    "RoleKey",
    "RoleLibrary",
]
