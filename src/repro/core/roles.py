"""Roles: shape-specialized, presynthesized accelerator programs.

Paper mapping
-------------
An FPGA *role* is a presynthesized partial bitstream implementing one kernel,
registered with TensorFlow and loaded into a reconfigurable region on demand.
The TPU-native analogue implemented here:

  - *synthesis*   = trace + lower to StableHLO (``jit(fn).lower(*abstract)``).
    This is the expensive, offline, HLS-like step.  The lowered artifact is the
    "bitstream": device-agnostic, storable, registered in the role library.
  - *reconfiguration / load* = ``lowered.compile()`` — turning the stored
    artifact into a device-loaded executable.  On a real TPU fleet with a warm
    persistent compilation cache this is dominated by program upload; on this
    host it is the measured XLA-backend load.  Eviction (``unload``) drops the
    executable, freeing the region.
  - *dispatch*    = calling the loaded executable (async, HSA-queue mediated).

Two sources, as in the paper:
  - ``presynthesized`` roles lower at library-build time (``synthesize()``),
  - ``online`` roles lower lazily on first load ("runtime synthesis" — the
    flexible-but-costly OpenCL path the paper describes and then avoids for
    the mobile use case).

Roles are keyed by (op, abstract arg signature, specialization): like
bitstreams, they are shape- and dtype-specialized.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

from repro.core import ledger as ledger_mod
from repro.core.ledger import GLOBAL_LEDGER, OverheadLedger
from repro.core.registry import GENERIC, KernelImpl

PRESYNTHESIZED = "presynthesized"
ONLINE = "online"


def _sig_of(aval: jax.ShapeDtypeStruct) -> tuple[tuple[int, ...], str]:
    return (tuple(aval.shape), np.dtype(aval.dtype).name)


@dataclasses.dataclass(frozen=True)
class RoleKey:
    op: str
    signature: tuple[tuple[tuple[int, ...], str], ...]
    specialization: str = GENERIC

    def __str__(self) -> str:
        shapes = ",".join("x".join(map(str, s)) + d for s, d in self.signature)
        return f"{self.op}[{shapes}]{'' if self.specialization == GENERIC else '#' + self.specialization}"


class Role:
    """One shape-specialized accelerator program."""

    def __init__(
        self,
        impl: KernelImpl,
        abstract_args: Sequence[jax.ShapeDtypeStruct],
        *,
        static_kwargs: Mapping[str, Any] | None = None,
        source: str = PRESYNTHESIZED,
        name: str | None = None,
    ) -> None:
        if source not in (PRESYNTHESIZED, ONLINE):
            raise ValueError(f"bad role source {source!r}")
        self.impl = impl
        self.abstract_args = tuple(abstract_args)
        self.static_kwargs = dict(static_kwargs or {})
        self.source = source
        self.key = RoleKey(
            op=impl.op,
            signature=tuple(_sig_of(a) for a in self.abstract_args),
            specialization=impl.specialization,
        )
        self.name = name or str(self.key)
        self._lowered: Any = None          # the "bitstream"
        self._executable: Any = None       # loaded into a region
        self.synthesis_s: float | None = None
        self.load_count = 0

    # -- lifecycle -----------------------------------------------------------

    def _jitted(self) -> Any:
        kw = self.static_kwargs

        def call(*args: Any) -> Any:
            return self.impl.fn(*args, **kw)

        return jax.jit(call)

    def synthesize(self) -> float:
        """Trace + lower (the offline 'HLS' step). Idempotent; returns seconds."""
        if self._lowered is None:
            t0 = time.perf_counter_ns()
            self._lowered = self._jitted().lower(*self.abstract_args)
            self.synthesis_s = (time.perf_counter_ns() - t0) * 1e-9
        return self.synthesis_s or 0.0

    def load(self) -> Any:
        """Compile/load the artifact into a 'region'. Returns the executable."""
        if self._executable is None:
            if self._lowered is None:
                # online synthesis at dispatch time (the flexible OpenCL path)
                self.synthesize()
            self._executable = self._lowered.compile()
            self.load_count += 1
        return self._executable

    def unload(self) -> None:
        """Eviction: free the region. The lowered artifact (bitstream) survives."""
        self._executable = None

    @property
    def resident(self) -> bool:
        return self._executable is not None

    # -- execution ------------------------------------------------------------

    def __call__(self, *args: Any) -> Any:
        exe = self.load()
        return exe(*args)

    # -- reporting (paper Table I analogue) ------------------------------------

    def footprint(self) -> dict[str, float]:
        arg_bytes = sum(
            int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize for a in self.abstract_args
        )
        fp = self.impl.footprint
        out: dict[str, float] = {
            "arg_bytes": float(arg_bytes),
            "vmem_bytes": float(fp.vmem_bytes),
            "vmem_pct": 100.0 * fp.vmem_fraction(),
        }
        if self._executable is not None:
            try:
                ma = self._executable.memory_analysis()
                out["temp_bytes"] = float(ma.temp_size_in_bytes)
                out["code_bytes"] = float(ma.generated_code_size_in_bytes)
            except Exception:  # backend may not support it
                pass
        return out


class RoleLibrary:
    """All roles known to the runtime; the paper's registered-bitstream store."""

    def __init__(self, ledger: OverheadLedger = GLOBAL_LEDGER) -> None:
        self._roles: dict[RoleKey, Role] = {}
        self.ledger = ledger

    def add(self, role: Role) -> Role:
        if role.key in self._roles:
            return self._roles[role.key]
        self._roles[role.key] = role
        return role

    def make_role(
        self,
        impl: KernelImpl,
        abstract_args: Sequence[jax.ShapeDtypeStruct],
        **kw: Any,
    ) -> Role:
        return self.add(Role(impl, abstract_args, **kw))

    def get(self, key: RoleKey) -> Role:
        return self._roles[key]

    def __len__(self) -> int:
        return len(self._roles)

    def __iter__(self):
        return iter(self._roles.values())

    def synthesize_all(self) -> float:
        """Presynthesize every presynthesized-source role (device/kernel setup).

        Recorded under the ledger's SETUP category — the paper's one-time cost.
        """
        total = 0.0
        with self.ledger.timed(ledger_mod.SETUP, what="synthesize_all", n=len(self._roles)):
            for role in self._roles.values():
                if role.source == PRESYNTHESIZED:
                    total += role.synthesize()
        return total
