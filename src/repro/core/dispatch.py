"""Transparent op dispatch — the "no secondary toolchain" property.

Model code calls ``dispatch.op("matmul", x, w)`` instead of a concrete
implementation.  Under ``jax.jit`` this function runs at *trace time*, so the
resolved implementation is baked into the compiled program with zero runtime
indirection — the TPU-idiomatic translation of TensorFlow looking up a
registered HSA kernel in its executor.

The active :class:`DispatchContext` selects the device kind and source
preference.  Flipping ``prefer=("pallas", "xla", "reference")`` retargets an
entire model to hand-written Pallas roles without touching model code; that
one-flag switch is the paper's transparency claim.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Callable, Iterator, Sequence

from repro.core.registry import GLOBAL_REGISTRY, KernelImpl, KernelRegistry


@dataclasses.dataclass(frozen=True)
class DispatchContext:
    device_kind: str = "tpu"
    prefer: tuple[str, ...] = ("xla", "reference")
    registry: KernelRegistry = GLOBAL_REGISTRY
    interpret: bool = False          # forwarded to pallas impls (CPU validation)
    trace: "DispatchTrace | None" = None
    # resolution memo: device_kind/prefer/registry are frozen per context, so
    # (op, specialization) fully determines the resolved impl — hot trace
    # loops (one dispatch.op per layer per step) stop re-walking the
    # preference order.  Entries carry the registry version so a late
    # registration invalidates them.
    _resolve_cache: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False, hash=False
    )

    def resolve(self, op: str, *, specialization: str | None = None) -> KernelImpl:
        key = (op, specialization)
        version = self.registry.version
        hit = self._resolve_cache.get(key)
        if hit is not None and hit[0] == version:
            return hit[1]
        impl = self.registry.resolve(
            op, self.device_kind, self.prefer, specialization=specialization
        )
        self._resolve_cache[key] = (version, impl)
        return impl


class DispatchTrace:
    """Records the sequence of resolved ops (role keys) during a trace.

    The role planner (:mod:`repro.core.policy`) consumes this to decide the
    generic-vs-fixed-weight split under a region budget.
    """

    def __init__(self) -> None:
        self.events: list[tuple[str, str]] = []   # (op, impl name)

    def record(self, op: str, impl: KernelImpl) -> None:
        self.events.append((op, impl.name))

    def op_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for op_name, _ in self.events:
            counts[op_name] = counts.get(op_name, 0) + 1
        return counts


_DEFAULT = DispatchContext()
_CTX: contextvars.ContextVar[DispatchContext] = contextvars.ContextVar(
    "repro_dispatch_ctx", default=_DEFAULT
)


def current() -> DispatchContext:
    return _CTX.get()


@contextlib.contextmanager
def use(
    *,
    device_kind: str | None = None,
    prefer: Sequence[str] | None = None,
    registry: KernelRegistry | None = None,
    interpret: bool | None = None,
    trace: DispatchTrace | None = None,
) -> Iterator[DispatchContext]:
    """Scoped dispatch policy, like the paper's device annotation in user code."""
    base = _CTX.get()
    ctx = DispatchContext(
        device_kind=device_kind if device_kind is not None else base.device_kind,
        prefer=tuple(prefer) if prefer is not None else base.prefer,
        registry=registry if registry is not None else base.registry,
        interpret=interpret if interpret is not None else base.interpret,
        trace=trace if trace is not None else base.trace,
    )
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def op(name: str, *args: Any, specialization: str | None = None, **kwargs: Any) -> Any:
    """Dispatch a logical op through the active context (trace-time resolved)."""
    ctx = _CTX.get()
    impl = ctx.resolve(name, specialization=specialization)
    if ctx.trace is not None:
        ctx.trace.record(name, impl)
    if impl.source == "pallas" and ctx.interpret:
        kwargs = dict(kwargs, interpret=True)
    return impl.fn(*args, **kwargs)


def resolve(name: str, *, specialization: str | None = None) -> KernelImpl:
    return _CTX.get().resolve(name, specialization=specialization)


def policy_from_flag(policy: str) -> tuple[str, ...]:
    """Map a CLI ``--policy`` flag to a source-preference order."""
    orders = {
        "reference": ("reference",),
        "xla": ("xla", "reference"),
        "pallas": ("pallas", "xla", "reference"),
        "pallas-strict": ("pallas",),
    }
    if policy not in orders:
        raise ValueError(f"unknown policy {policy!r}; choose from {sorted(orders)}")
    return orders[policy]
