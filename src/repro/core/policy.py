"""Role planner: the paper's generic-vs-fixed-weight trade-off, made concrete.

Paper §IV: "TF can consider this trade-off to either generate a lower number of
generic roles or fix layer weights to have more efficient hardware."  A generic
role (weights as operands) is shared by every layer that invokes the op, so it
stays resident; fixing weights yields one role *per layer* — each faster, but
with more roles than regions the LRU starts thrashing and every layer pays a
reconfiguration.

The planner takes a dispatch trace (the op sequence of one model step), a
region budget, and a measured cost model, simulates LRU residency for each
assignment of {generic, fixed_weight} per op type, and picks the assignment
with the lowest predicted steady-state step time.  Op-type counts are small,
so exhaustive search is exact; a greedy fallback covers wide op sets.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from collections import OrderedDict
from typing import Hashable, Sequence

from repro.core.registry import FIXED_WEIGHT, GENERIC


@dataclasses.dataclass(frozen=True)
class PrefetchPolicy:
    """Lookahead-depth knob for the reconfiguration-prefetch pipeline.

    ``lookahead`` is how many queued packets (per queue, from the head) the
    scheduler scans for roles to load ahead of demand — the software ICAP
    pipeline depth.  0 recovers the purely reactive PR-1 scheduler.  The same
    knob parameterizes :func:`simulate_lru`, so the role planner can predict
    *exposed* (queue-stalling) rather than total reconfiguration cost when a
    prefetching scheduler will run the plan.
    """

    lookahead: int = 0

    def __post_init__(self) -> None:
        if self.lookahead < 0:
            raise ValueError(f"lookahead must be >= 0, got {self.lookahead}")

    @classmethod
    def of(cls, value: "PrefetchPolicy | int | None") -> "PrefetchPolicy":
        if value is None:
            return cls(0)
        if isinstance(value, PrefetchPolicy):
            return value
        return cls(int(value))


@dataclasses.dataclass(frozen=True)
class FusionPolicy:
    """Pick the decode fusion depth K for a serving engine.

    One fused launch generates up to K tokens per slot in a single packet
    round trip, amortizing the per-packet invocation overhead (Table II row
    3) K-fold.  The trade-offs the policy balances:

      - **mean request length** caps useful depth: scanning past every live
        slot's remaining budget burns masked (wasted) decode steps;
      - **queue depth** (packets other tenants have pending on the shared
        device) argues for *smaller* K: one fused launch occupies the compute
        engine for K tokens, so deep foreign backlogs halve K per
        ``fairness_depth`` pending packets — the batch-vs-latency knob the
        toolflow surveys frame as launch amortization vs responsiveness.

    **Feedback mode** (``feedback=True``) closes the loop the launch-time
    queue depth only approximates: instead of guessing how much a deep
    backlog *will* hurt the other tenants, it reads how much serving
    already *is* hurting them — the ledger's observed p99 foreign
    ``dispatch_wait`` (the producer-blocked leg of their packet round
    trips).  K halves once per doubling of the observed p99 over
    ``target_wait_s``, so a foreign tenant whose waits blow past the target
    pulls fusion down even when its queue happens to be shallow at launch
    time, and an idle ledger lets K ride at the amortization optimum.

    The result is rounded down to a power of two so the engine's jitted
    fused-decode trace cache stays small (same reasoning as prompt
    bucketing: a distinct K is a distinct trace is a re-synthesis).
    """

    max_fusion: int = 8
    min_fusion: int = 1
    fairness_depth: int = 8
    feedback: bool = False
    target_wait_s: float = 1e-3          # foreign p99 dispatch_wait budget

    def __post_init__(self) -> None:
        if self.min_fusion < 1:
            raise ValueError(f"min_fusion must be >= 1, got {self.min_fusion}")
        if self.max_fusion < self.min_fusion:
            raise ValueError(
                f"max_fusion {self.max_fusion} < min_fusion {self.min_fusion}"
            )
        if self.fairness_depth < 0:
            raise ValueError(f"fairness_depth must be >= 0, got {self.fairness_depth}")
        if self.target_wait_s <= 0:
            raise ValueError(f"target_wait_s must be > 0, got {self.target_wait_s}")

    @classmethod
    def of(cls, value: "FusionPolicy | int | None") -> "FusionPolicy":
        if value is None:
            return cls(1, 1)
        if isinstance(value, FusionPolicy):
            return value
        k = int(value)
        return cls(max_fusion=max(1, k), min_fusion=max(1, k))

    def choose_k(self, *, queue_depth: int = 0,
                 mean_request_len: float = 0.0,
                 observed_wait_s: float | None = None) -> int:
        k = self.max_fusion
        if mean_request_len > 0:
            k = min(k, max(self.min_fusion, int(mean_request_len)))
        if self.feedback and observed_wait_s is not None:
            # measured-contention feedback: halve K per doubling of the
            # observed foreign p99 wait over target.  Takes precedence over
            # the queue-depth guess when a measurement exists.
            over = observed_wait_s / self.target_wait_s
            while over > 1.0 and k > 1:
                k >>= 1
                over /= 2.0
        elif self.fairness_depth > 0 and queue_depth > 0:
            # halve once per fairness_depth foreign packets pending (capped so
            # the shift below stays defined for absurd backlogs)
            k >>= min(queue_depth // self.fairness_depth, k.bit_length())
        k = max(self.min_fusion, min(k, self.max_fusion))
        p = 1
        while p * 2 <= k:
            p *= 2
        return max(self.min_fusion, p)     # the floor wins over pow2 rounding


@dataclasses.dataclass(frozen=True)
class ChunkPolicy:
    """Pick the prefill chunk size for continuous batching.

    Whole-prompt prefill makes one monolithic launch per admission: a long
    prompt monopolizes the compute engine for its full length, so every
    other request's first token (and every in-flight request's next token)
    waits behind it — the paper's "simultaneously from other sources" fails
    exactly at admission time.  Chunked prefill splits the prompt into
    ``chunk``-token pieces that interleave with the fused decode launches,
    bounding how long any single prefill piece can occupy the device.

    The trade-off mirrors :class:`FusionPolicy` from the other side: decode
    fusion makes decode launches *longer* to amortize packet overhead, while
    prefill chunking makes prefill launches *shorter* to bound latency — and
    the two meet in the step loop, where one step carries one chunk per
    prefilling slot plus one fused decode.  ``decode_taper`` shrinks the
    chunk as live decode slots pile up (their TPOT is what a fat chunk
    stretches); ``fusion_taper`` shrinks it under deep decode fusion (the
    step is already long, so the prefill share must not double it).

    Chunk sizes are powers of two for the same reason fusion depths are:
    every distinct (chunk, start) pair is a distinct jitted trace, and pow2
    chunks over pow2-bucketed prompts keep the trace count at
    ``log2(max_len)``-ish instead of per-prompt-length.
    """

    max_chunk: int = 64
    min_chunk: int = 16
    decode_taper: int = 0        # halve chunk per this many live decode slots
    fusion_taper: int = 0        # halve chunk per this many fused decode steps

    def __post_init__(self) -> None:
        for name in ("max_chunk", "min_chunk"):
            v = getattr(self, name)
            if v < 1 or (v & (v - 1)):
                raise ValueError(f"{name} must be a power of two >= 1, got {v}")
        if self.max_chunk < self.min_chunk:
            raise ValueError(
                f"max_chunk {self.max_chunk} < min_chunk {self.min_chunk}"
            )
        if self.decode_taper < 0 or self.fusion_taper < 0:
            raise ValueError("tapers must be >= 0")

    @classmethod
    def of(cls, value: "ChunkPolicy | int | None") -> "ChunkPolicy | None":
        if value is None or isinstance(value, ChunkPolicy):
            return value
        c = int(value)
        return cls(max_chunk=c, min_chunk=c)

    def choose_chunk(self, *, live_decode: int = 0, fusion_k: int = 1) -> int:
        """Chunk size for one request, fixed at its prefill start (a chunk
        that changed mid-prefill would fragment the trace cache for no
        latency gain — the knob reacts at admission granularity)."""
        c = self.max_chunk
        if self.decode_taper > 0 and live_decode > 0:
            c >>= min(live_decode // self.decode_taper, c.bit_length() - 1)
        if self.fusion_taper > 0 and fusion_k > 1:
            c >>= min(fusion_k // self.fusion_taper, c.bit_length() - 1)
        return max(self.min_chunk, c)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Admit a request into the paged serving engine?

    The dense engine's admission test was "is a slot free?" — the page pool
    makes that insufficient: a free slot with an empty pool just deadlocks
    later.  Admission instead reasons over **free pages minus the projected
    growth of the requests already running**: each active request will still
    map up to (projection − already-mapped) pages before it finishes, and
    those future claims must stay funded or on-demand growth starts failing
    mid-decode.

    ``growth_reserve`` scales the projection of a request's decode budget:

      - 1.0 (default) projects the worst case (``prompt + max_new_tokens``),
        which makes :class:`~repro.serve.paged.PagePoolExhausted`
        *unreachable* — every page a request can ever touch is accounted at
        admission;
      - < 1.0 overcommits (requests usually finish early — EOS, truncation),
        admitting more concurrency at the risk of mid-decode exhaustion.

    ``watermark_pages`` holds back a safety floor for in-flight growth.
    """

    growth_reserve: float = 1.0
    watermark_pages: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.growth_reserve <= 1.0:
            raise ValueError(
                f"growth_reserve must be in [0, 1], got {self.growth_reserve}"
            )
        if self.watermark_pages < 0:
            raise ValueError(
                f"watermark_pages must be >= 0, got {self.watermark_pages}"
            )

    def projected_pages(self, prompt_len: int, max_new_tokens: int,
                        page_size: int) -> int:
        """Pages this request is projected to map over its life.

        Counts *written* rows: generating ``g`` tokens writes ``prompt + g
        - 1`` KV rows (the final sampled token is never fed back), so at
        ``growth_reserve=1.0`` the projection equals
        :meth:`worst_case_pages` exactly — which is what makes exhaustion
        unreachable at full reserve without over-reserving a page at exact
        page boundaries."""
        projected = prompt_len + max(
            1, int(math.ceil(self.growth_reserve * max_new_tokens))
        ) - 1
        return -(-max(1, projected) // page_size)

    def worst_case_pages(self, prompt_len: int, max_new_tokens: int,
                         page_size: int) -> int:
        """Pages the request maps if it runs its *full* budget — the
        ``growth_reserve``-independent figure.  A request whose worst case
        exceeds the pool can never complete, not even alone with every other
        tenant preempted, so this (not the reserve-scaled projection) is what
        permanent rejection must test under overcommit.

        Exact, not conservative: the final sampled token is never fed back,
        so its KV row is never written — the cache tops out at ``prompt +
        max_new - 1`` rows.  Rounding up here would falsely *permanently*
        reject boundary-straddling requests that complete fine alone."""
        return -(-(prompt_len + max(1, max_new_tokens) - 1) // page_size)

    @property
    def overcommitted(self) -> bool:
        """True when admission funds less than the full decode budget —
        the regime where mid-flight exhaustion (hence preemption) is live."""
        return self.growth_reserve < 1.0

    def admit(self, *, free_pages: int, projected_growth_pages: int,
              request_pages: int) -> bool:
        """``free_pages`` from the allocator, ``projected_growth_pages`` the
        summed unmapped remainder of already-admitted requests."""
        available = free_pages - projected_growth_pages - self.watermark_pages
        return request_pages <= available


#: resume modes a preempted request can come back through
RESUME_REPREFILL = "reprefill"    # recompute prompt + replay generated tokens
RESUME_SNAPSHOT = "snapshot"      # restore the host-side KV page snapshot


@dataclasses.dataclass(frozen=True)
class PreemptionCandidate:
    """What the :class:`PreemptionPolicy` sees of one active request.

    ``mapped_pages`` is what parking it returns to the pool; ``tokens_done``
    (prompt + generated rows in its cache) is what a re-prefill resume has to
    recompute — the wasted work the victim order tries to minimize."""

    uid: int
    mapped_pages: int
    tokens_done: int


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Who gets parked when the page pool runs dry, and how they come back.

    The paper's fabric is shared "simultaneously from other sources": a
    region a tenant holds can be demanded back at runtime.  For overcommitted
    paged serving that means a mid-decode request's KV pages are reclaimable
    — the engine parks **victims** (pages back to the pool, generated-so-far
    tokens kept) instead of letting :class:`~repro.serve.paged.PagePoolExhausted`
    escape, and resumes them when pages free up.

    ``order`` ranks victims:

      - ``"youngest"`` (default) — latest-admitted first.  The oldest request
        is never preempted while a younger one holds pages, so the head of
        the line always drains and admission order stays livelock-free.
      - ``"oldest"`` — earliest-admitted first (drain-and-restart flavor).
      - ``"most_pages"`` — largest page holding first (fewest victims per
        reclaim, at the cost of evicting the most expensive cache to rebuild).

    Resume picks the cheaper of two paths per victim, by cost at park time:

      - **re-prefill** (always available): recompute the prompt and replay
        the generated tokens through the normal decode path — costs
        ``tokens_done`` of recompute, holds no host memory;
      - **snapshot** (``allow_snapshot``): copy the victim's live KV pages to
        host at park and scatter them back at resume — zero recompute, costs
        two page-pool copies plus host bytes while parked.

    ``snapshot_threshold_tokens`` is the crossover: a victim with at least
    this many cached rows snapshots (recompute grows linearly with rows;
    the copy is bandwidth-priced), a shorter one re-prefills.
    """

    order: str = "youngest"
    allow_snapshot: bool = True
    snapshot_threshold_tokens: int = 24

    _ORDERS = ("youngest", "oldest", "most_pages")

    def __post_init__(self) -> None:
        if self.order not in self._ORDERS:
            raise ValueError(
                f"order must be one of {self._ORDERS}, got {self.order!r}"
            )
        if self.snapshot_threshold_tokens < 0:
            raise ValueError(
                "snapshot_threshold_tokens must be >= 0, got "
                f"{self.snapshot_threshold_tokens}"
            )

    def victims(self, candidates: Sequence[PreemptionCandidate],
                pages_needed: int) -> list[int]:
        """Uids to park, in order, until ``pages_needed`` pages are covered.

        Returns the shortest prefix of the ranked candidates whose summed
        ``mapped_pages`` reaches ``pages_needed`` — or every candidate when
        even that falls short (the engine then re-plans with what it got)."""
        if pages_needed <= 0:
            return []
        if self.order == "youngest":
            ranked = sorted(candidates, key=lambda c: -c.uid)
        elif self.order == "oldest":
            ranked = sorted(candidates, key=lambda c: c.uid)
        else:                                   # most_pages; uid tiebreak
            ranked = sorted(candidates, key=lambda c: (-c.mapped_pages, c.uid))
        out: list[int] = []
        covered = 0
        for c in ranked:
            if covered >= pages_needed:
                break
            out.append(c.uid)
            covered += c.mapped_pages
        return out

    def resume_mode(self, *, tokens_done: int) -> str:
        """``RESUME_SNAPSHOT`` or ``RESUME_REPREFILL`` for a victim with
        ``tokens_done`` cached rows at park time."""
        if self.allow_snapshot and tokens_done >= self.snapshot_threshold_tokens:
            return RESUME_SNAPSHOT
        return RESUME_REPREFILL


@dataclasses.dataclass(frozen=True)
class SpillCandidate:
    """What the :class:`SpillPolicy` sees of one parked snapshot.

    ``arena_bytes`` is what demoting it returns to the host budget;
    ``tokens_done`` is what the demotion costs later — the re-prefill
    replay a snapshot resume would have avoided."""

    uid: int
    arena_bytes: int
    tokens_done: int


@dataclasses.dataclass(frozen=True)
class SpillPolicy:
    """Who loses their host snapshot when the arena passes its byte budget,
    and how far ahead of need refills are issued.

    The device tier already degrades gracefully (pool pressure parks
    victims, :class:`PreemptionPolicy`); this policy is the same discipline
    one tier down.  When a new snapshot does not fit the
    ``host_budget_bytes`` arena, parked snapshots are **demoted** to
    re-prefill replay — their arena bytes are dropped and the request keeps
    only its committed token prefix, which the replay path regenerates
    bitwise-identically.  Work is rejected only when replay is disabled
    (``allow_replay=False``), in which case the over-budget store raises
    :class:`~repro.serve.paged.HostArenaExhausted`.

    ``order`` ranks demotion victims:

      - ``"cheapest_replay"`` (default) — fewest ``tokens_done`` first: the
        resume-cost crossover.  A snapshot's whole value is the recompute it
        avoids, which grows linearly with cached rows, so the snapshot
        worth the least is the first to give its bytes back.
      - ``"largest"`` — most ``arena_bytes`` first (fewest victims per
        reclaim, at the cost of demoting the most valuable snapshot).
      - ``"oldest"`` — earliest-parked first (store-order eviction, the
        arena's native ``eviction_order``).

    ``refill_lookahead`` is the ahead-of-need depth: how many parked
    snapshots from the resume head get their H2D refill issued on the
    transfer engine *before* the resume step would stall on it — a parked
    request scheduled for resume is a "role named in a lookahead window",
    and this is its prefetch.  0 disables (refill on demand, fully
    exposed).
    """

    order: str = "cheapest_replay"
    refill_lookahead: int = 4
    allow_replay: bool = True

    _ORDERS = ("cheapest_replay", "largest", "oldest")

    def __post_init__(self) -> None:
        if self.order not in self._ORDERS:
            raise ValueError(
                f"order must be one of {self._ORDERS}, got {self.order!r}"
            )
        if self.refill_lookahead < 0:
            raise ValueError(
                f"refill_lookahead must be >= 0, got {self.refill_lookahead}"
            )

    @classmethod
    def of(cls, value: "SpillPolicy | None") -> "SpillPolicy":
        """``None`` means the defaults (demote cheapest replay, lookahead 4)."""
        return value if isinstance(value, SpillPolicy) else cls()

    def victims(self, candidates: Sequence[SpillCandidate],
                bytes_needed: int) -> list[int]:
        """Uids to demote, in order, until ``bytes_needed`` is covered.

        Returns the shortest prefix of the ranked candidates whose summed
        ``arena_bytes`` reaches ``bytes_needed`` — or every candidate when
        even that falls short (the caller then demotes the incoming
        snapshot itself)."""
        if bytes_needed <= 0:
            return []
        if self.order == "cheapest_replay":
            ranked = sorted(candidates, key=lambda c: (c.tokens_done, c.uid))
        elif self.order == "largest":
            ranked = sorted(candidates, key=lambda c: (-c.arena_bytes, c.uid))
        else:                                   # oldest; uid is park order
            ranked = sorted(candidates, key=lambda c: c.uid)
        out: list[int] = []
        covered = 0
        for c in ranked:
            if covered >= bytes_needed:
                break
            out.append(c.uid)
            covered += c.arena_bytes
        return out


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the runtime absorbs faults before the user ever sees one.

    The paper's promise is a runtime that "hides the complexity of
    controlling new hardware" — and real accelerator hardware faults: kernel
    launches error, partial-bitstream loads abort, doorbells wedge.  This
    policy spans the three recovery layers:

      - **scheduler** — a faulted packet is retried in place (``requeue_head``,
        so queue order is preserved) up to ``max_retries`` times with
        exponential backoff (``backoff_s * backoff_factor**attempt``, capped
        at ``max_backoff_s``); a launch whose completion never fires is
        killed by a watchdog after :meth:`watchdog_deadline` of its expected
        duration; a queue that faults ``quarantine_after`` consecutive times
        is quarantined — its pending packets migrate to sibling queues;
      - **reconfig** — a failed region load retries through the
        ``abort_prefetch`` cleanup path instead of failing the head packet;
      - **engine** — a launch that exhausts its packet budget (or faults
        permanently) parks the affected requests via the preemption
        machinery and resumes them by re-prefill replay, at most
        ``max_request_recoveries`` times per request, keeping completed
        streams bitwise-identical to fault-free runs.
    """

    max_retries: int = 3
    backoff_s: float = 1e-3
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0
    watchdog_factor: float = 8.0
    watchdog_floor_s: float = 1e-3
    quarantine_after: int = 3            # K consecutive faults; 0 disables
    max_request_recoveries: int = 2      # engine-level park/replay budget

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}"
            )
        if self.max_backoff_s < self.backoff_s:
            raise ValueError(
                f"max_backoff_s {self.max_backoff_s} < backoff_s {self.backoff_s}"
            )
        if self.watchdog_factor < 1.0:
            raise ValueError(
                f"watchdog_factor must be >= 1, got {self.watchdog_factor}"
            )
        if self.watchdog_floor_s < 0:
            raise ValueError(
                f"watchdog_floor_s must be >= 0, got {self.watchdog_floor_s}"
            )
        if self.quarantine_after < 0:
            raise ValueError(
                f"quarantine_after must be >= 0, got {self.quarantine_after}"
            )
        if self.max_request_recoveries < 0:
            raise ValueError(
                "max_request_recoveries must be >= 0, got "
                f"{self.max_request_recoveries}"
            )

    @classmethod
    def of(cls, value: "RetryPolicy | int | None") -> "RetryPolicy | None":
        """``None`` keeps retries off (legacy fail-fast semantics); an int is
        a plain ``max_retries`` with the other knobs at their defaults."""
        if value is None or isinstance(value, RetryPolicy):
            return value
        return cls(max_retries=int(value))

    def backoff(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based: the delay
        between the first fault and the second try is ``backoff(1)``)."""
        if attempt < 1:
            raise ValueError(f"attempt must be >= 1, got {attempt}")
        return min(
            self.max_backoff_s,
            self.backoff_s * self.backoff_factor ** (attempt - 1),
        )

    def watchdog_deadline(self, expected_s: float) -> float:
        """How long a launch may run before the watchdog declares it wedged.

        Derived from the caller's expected duration (the engine's
        ``step_time_model`` or a measured exec cost), floored so a
        nominally-instant launch still gets a real window."""
        return max(self.watchdog_floor_s, self.watchdog_factor * expected_s)


@dataclasses.dataclass(frozen=True)
class IntegrityPolicy:
    """When and how the serving path verifies the bytes it trusts.

    The fault layer (PR 7) covers *fail-stop* faults — a launch that errors
    or wedges.  This policy covers the quieter failure mode: state that is
    silently wrong.  Four tiers are verifiable — device KV pages (digest
    stamped at every write boundary), host-arena blocks (digest stamped at
    ``store()``), DMA payloads (digest carried on the transfer), and loaded
    region images.  Each knob gates one verification site:

    - ``verify_reads``: after every decode launch, re-hash the sealed pages
      the attention kernel just read and park any slot whose pages mismatch
      *before* its tokens commit.  This is the structural zero-escape
      guarantee — corruption is caught before it influences a sampled token.
    - ``verify_transfers``: check the payload digest on every H2D refill at
      ``wait()`` and every D2H spill at ``issue()`` (spills complete at
      issue; they are never waited).
    - ``verify_regions``: check the region-image digest after every
      reconfiguration load and again at ``complete_prefetch``, so a stale
      image is caught before any packet executes against it.
    - ``scrub_pages_per_step``: budgeted background audit — re-hash up to
      this many cold targets (sealed device pages + parked arena blocks,
      round-robin cursor) per engine step.  Scrubbing does not change what
      escapes (the read/transfer/region checks already bound that at zero);
      it bounds *detection latency*, so a corrupted parked snapshot is
      demoted before the engine wastes a refill on it.  0 disables.

    Passing ``integrity=None`` to the engine skips the whole layer — no
    digests, no hashing, bit-for-bit the pre-integrity hot path.
    """

    scrub_pages_per_step: int = 0
    verify_transfers: bool = True
    verify_regions: bool = True
    verify_reads: bool = True

    def __post_init__(self):
        if self.scrub_pages_per_step < 0:
            raise ValueError(
                f"scrub_pages_per_step must be >= 0, got {self.scrub_pages_per_step}")

    @classmethod
    def of(cls, value: "IntegrityPolicy | bool | None") -> "IntegrityPolicy | None":
        """Normalize an engine-constructor argument.

        ``None``/``False`` → disabled (``None``); ``True`` → all
        verification on with scrubbing off; an ``IntegrityPolicy`` passes
        through.
        """
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(f"expected IntegrityPolicy, bool, or None, got {value!r}")


@dataclasses.dataclass(frozen=True)
class PrefixPolicy:
    """When a new request may attach to already-resident prompt pages.

    Prefix sharing is the Table II ``if_not_configured`` hit applied to KV
    state: a request whose prompt prefix is already paged in attaches to
    those pages at +1 refcount instead of re-prefilling them, and admission
    charges only the unshared remainder.  Two knobs bound the mechanism:

    - ``min_prefix_pages``: shortest shared prefix (in full pages) worth
      attaching.  Below this the bookkeeping (refcounts, CoW on the
      park/quarantine paths) outweighs the prefill saved.
    - ``max_refs``: cap on readers per physical page.  Bounds the blast
      radius of one quarantined page (every reader parks through the
      ``RESUME_REPREFILL`` lane) and keeps a single viral prefix from
      serializing the whole pool's fault recovery.
    """

    min_prefix_pages: int = 1
    max_refs: int = 64

    def __post_init__(self):
        if self.min_prefix_pages < 1:
            raise ValueError(
                f"min_prefix_pages must be >= 1, got {self.min_prefix_pages}")
        if self.max_refs < 2:
            raise ValueError(f"max_refs must be >= 2, got {self.max_refs}")

    @classmethod
    def of(cls, value: "PrefixPolicy | bool | None") -> "PrefixPolicy | None":
        """Normalize an engine-constructor argument: ``None``/``False`` →
        sharing off, ``True`` → defaults, a ``PrefixPolicy`` passes
        through."""
        if value is None or value is False:
            return None
        if value is True:
            return cls()
        if isinstance(value, cls):
            return value
        raise TypeError(f"expected PrefixPolicy, bool, or None, got {value!r}")


@dataclasses.dataclass(frozen=True)
class Invocation:
    """One op call site in a model step: (op type, site id e.g. layer index)."""

    op: str
    site: Hashable


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Measured per-category costs in seconds (from the overhead ledger)."""

    reconfig_s: float
    dispatch_s: float
    exec_generic_s: dict[str, float]       # op -> seconds
    exec_fixed_s: dict[str, float]         # op -> seconds (faster: weights baked)

    def exec_s(self, op: str, spec: str) -> float:
        table = self.exec_fixed_s if spec == FIXED_WEIGHT else self.exec_generic_s
        return table[op]


@dataclasses.dataclass
class SimResult:
    total_s: float
    hits: int
    misses: int
    distinct_roles: int
    exposed_s: float = 0.0      # reconfig time the compute timeline waited on
    hidden_s: float = 0.0       # reconfig time overlapped by lookahead prefetch

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


def role_sequence(
    trace: Sequence[Invocation], assignment: dict[str, str]
) -> list[Hashable]:
    """Map invocations to role identities under an assignment.

    Generic ops share one role per op type; fixed-weight ops get one role per
    call site.
    """
    seq: list[Hashable] = []
    for inv in trace:
        spec = assignment.get(inv.op, GENERIC)
        seq.append((inv.op, GENERIC) if spec == GENERIC else (inv.op, inv.site))
    return seq


def simulate_lru(
    roles: Sequence[Hashable],
    budget: int,
    cost: CostModel,
    spec_of: dict[Hashable, str],
    op_of: dict[Hashable, str],
    *,
    repeats: int = 2,
    lookahead: "PrefetchPolicy | int" = 0,
) -> SimResult:
    """Steady-state LRU simulation over ``repeats`` passes of the role sequence.

    The first pass is compulsory-miss dominated; reporting the *last* pass
    gives the steady-state step cost the planner optimizes.

    With ``lookahead`` L > 0 the simulation models the prefetching scheduler's
    two engines: a miss's load may start on the reconfiguration engine as soon
    as the access entered the L-deep lookahead window, so only the part of the
    load not overlapped by earlier compute is *exposed* on the compute
    timeline, and the LRU victim search skips roles needed within the next L
    accesses (the approximate Bélády oracle).  L = 0 reduces exactly to the
    serial reactive model.
    """
    depth = PrefetchPolicy.of(lookahead).lookahead
    resident: "OrderedDict[Hashable, None]" = OrderedDict()
    last = SimResult(0.0, 0, 0, len(set(roles)))
    for _ in range(max(1, repeats)):
        compute_t = reconfig_free = 0.0
        exposed = hidden = 0.0
        hits, misses = 0, 0
        starts: list[float] = []          # compute time when access i began
        for i, r in enumerate(roles):
            starts.append(compute_t)
            if r in resident:
                resident.move_to_end(r)
                hits += 1
            else:
                misses += 1
                if len(resident) >= budget:
                    upcoming = roles[i + 1 : i + 1 + depth] if depth else ()
                    window: dict[Hashable, int] = {}
                    for j, rr in enumerate(upcoming):
                        window.setdefault(rr, j)
                    victim = next((k for k in resident if k not in window), None)
                    if victim is None:
                        # every region demanded soon: evict the one needed
                        # furthest in the future (Bélády, as the scheduler does)
                        victim = max(resident, key=lambda k: window[k])
                    resident.pop(victim)
                visible_t = starts[max(0, i - depth)]
                load_start = max(reconfig_free, visible_t)
                ready = load_start + cost.reconfig_s
                exp = max(0.0, ready - compute_t)
                exposed += exp
                hidden += max(0.0, cost.reconfig_s - exp)
                compute_t = max(compute_t, ready)
                reconfig_free = ready
                resident[r] = None
            compute_t += cost.dispatch_s + cost.exec_s(op_of[r], spec_of[r])
        last = SimResult(compute_t, hits, misses, len(set(roles)), exposed, hidden)
    return last


@dataclasses.dataclass
class Plan:
    assignment: dict[str, str]             # op -> GENERIC | FIXED_WEIGHT
    predicted: SimResult
    alternatives: list[tuple[dict[str, str], float]] = dataclasses.field(
        default_factory=list
    )


def _evaluate(
    trace: Sequence[Invocation],
    assignment: dict[str, str],
    budget: int,
    cost: CostModel,
    repeats: int,
    lookahead: "PrefetchPolicy | int" = 0,
) -> SimResult:
    roles = role_sequence(trace, assignment)
    spec_of = {}
    op_of = {}
    for inv, r in zip(trace, roles):
        spec_of[r] = assignment.get(inv.op, GENERIC)
        op_of[r] = inv.op
    return simulate_lru(
        roles, budget, cost, spec_of, op_of, repeats=repeats, lookahead=lookahead
    )


def plan_roles(
    trace: Sequence[Invocation],
    budget: int,
    cost: CostModel,
    *,
    repeats: int = 2,
    exhaustive_limit: int = 12,
    lookahead: "PrefetchPolicy | int" = 0,
) -> Plan:
    """Choose generic vs fixed-weight per op type to minimize step latency.

    ``lookahead`` predicts the plan under a prefetching scheduler of that
    depth (exposed reconfiguration only) instead of the reactive one."""
    ops = sorted({inv.op for inv in trace})
    best: tuple[float, dict[str, str], SimResult] | None = None
    alts: list[tuple[dict[str, str], float]] = []

    if len(ops) <= exhaustive_limit:
        choices = itertools.product((GENERIC, FIXED_WEIGHT), repeat=len(ops))
        for combo in choices:
            assignment = dict(zip(ops, combo))
            sim = _evaluate(trace, assignment, budget, cost, repeats, lookahead)
            alts.append((assignment, sim.total_s))
            if best is None or sim.total_s < best[0]:
                best = (sim.total_s, assignment, sim)
    else:
        # Greedy: start all-generic, flip the op with the best marginal gain.
        assignment = {op: GENERIC for op in ops}
        sim = _evaluate(trace, assignment, budget, cost, repeats, lookahead)
        best = (sim.total_s, dict(assignment), sim)
        improved = True
        while improved:
            improved = False
            for op in ops:
                trial = dict(assignment)
                trial[op] = FIXED_WEIGHT if trial[op] == GENERIC else GENERIC
                s = _evaluate(trace, trial, budget, cost, repeats, lookahead)
                if s.total_s < best[0]:
                    best = (s.total_s, trial, s)
                    assignment = trial
                    improved = True

    assert best is not None
    alts.sort(key=lambda p: p[1])
    return Plan(assignment=best[1], predicted=best[2], alternatives=alts[:8])
