"""Role planner: the paper's generic-vs-fixed-weight trade-off, made concrete.

Paper §IV: "TF can consider this trade-off to either generate a lower number of
generic roles or fix layer weights to have more efficient hardware."  A generic
role (weights as operands) is shared by every layer that invokes the op, so it
stays resident; fixing weights yields one role *per layer* — each faster, but
with more roles than regions the LRU starts thrashing and every layer pays a
reconfiguration.

The planner takes a dispatch trace (the op sequence of one model step), a
region budget, and a measured cost model, simulates LRU residency for each
assignment of {generic, fixed_weight} per op type, and picks the assignment
with the lowest predicted steady-state step time.  Op-type counts are small,
so exhaustive search is exact; a greedy fallback covers wide op sets.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict
from typing import Hashable, Sequence

from repro.core.registry import FIXED_WEIGHT, GENERIC


@dataclasses.dataclass(frozen=True)
class Invocation:
    """One op call site in a model step: (op type, site id e.g. layer index)."""

    op: str
    site: Hashable


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Measured per-category costs in seconds (from the overhead ledger)."""

    reconfig_s: float
    dispatch_s: float
    exec_generic_s: dict[str, float]       # op -> seconds
    exec_fixed_s: dict[str, float]         # op -> seconds (faster: weights baked)

    def exec_s(self, op: str, spec: str) -> float:
        table = self.exec_fixed_s if spec == FIXED_WEIGHT else self.exec_generic_s
        return table[op]


@dataclasses.dataclass
class SimResult:
    total_s: float
    hits: int
    misses: int
    distinct_roles: int

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


def role_sequence(
    trace: Sequence[Invocation], assignment: dict[str, str]
) -> list[Hashable]:
    """Map invocations to role identities under an assignment.

    Generic ops share one role per op type; fixed-weight ops get one role per
    call site.
    """
    seq: list[Hashable] = []
    for inv in trace:
        spec = assignment.get(inv.op, GENERIC)
        seq.append((inv.op, GENERIC) if spec == GENERIC else (inv.op, inv.site))
    return seq


def simulate_lru(
    roles: Sequence[Hashable],
    budget: int,
    cost: CostModel,
    spec_of: dict[Hashable, str],
    op_of: dict[Hashable, str],
    *,
    repeats: int = 2,
) -> SimResult:
    """Steady-state LRU simulation over ``repeats`` passes of the role sequence.

    The first pass is compulsory-miss dominated; reporting the *last* pass
    gives the steady-state step cost the planner optimizes.
    """
    resident: "OrderedDict[Hashable, None]" = OrderedDict()
    last = SimResult(0.0, 0, 0, len(set(roles)))
    for _ in range(max(1, repeats)):
        total, hits, misses = 0.0, 0, 0
        for r in roles:
            if r in resident:
                resident.move_to_end(r)
                hits += 1
            else:
                misses += 1
                if len(resident) >= budget:
                    resident.popitem(last=False)
                resident[r] = None
                total += cost.reconfig_s
            total += cost.dispatch_s + cost.exec_s(op_of[r], spec_of[r])
        last = SimResult(total, hits, misses, len(set(roles)))
    return last


@dataclasses.dataclass
class Plan:
    assignment: dict[str, str]             # op -> GENERIC | FIXED_WEIGHT
    predicted: SimResult
    alternatives: list[tuple[dict[str, str], float]] = dataclasses.field(
        default_factory=list
    )


def _evaluate(
    trace: Sequence[Invocation],
    assignment: dict[str, str],
    budget: int,
    cost: CostModel,
    repeats: int,
) -> SimResult:
    roles = role_sequence(trace, assignment)
    spec_of = {}
    op_of = {}
    for inv, r in zip(trace, roles):
        spec_of[r] = assignment.get(inv.op, GENERIC)
        op_of[r] = inv.op
    return simulate_lru(roles, budget, cost, spec_of, op_of, repeats=repeats)


def plan_roles(
    trace: Sequence[Invocation],
    budget: int,
    cost: CostModel,
    *,
    repeats: int = 2,
    exhaustive_limit: int = 12,
) -> Plan:
    """Choose generic vs fixed-weight per op type to minimize step latency."""
    ops = sorted({inv.op for inv in trace})
    best: tuple[float, dict[str, str], SimResult] | None = None
    alts: list[tuple[dict[str, str], float]] = []

    if len(ops) <= exhaustive_limit:
        choices = itertools.product((GENERIC, FIXED_WEIGHT), repeat=len(ops))
        for combo in choices:
            assignment = dict(zip(ops, combo))
            sim = _evaluate(trace, assignment, budget, cost, repeats)
            alts.append((assignment, sim.total_s))
            if best is None or sim.total_s < best[0]:
                best = (sim.total_s, assignment, sim)
    else:
        # Greedy: start all-generic, flip the op with the best marginal gain.
        assignment = {op: GENERIC for op in ops}
        sim = _evaluate(trace, assignment, budget, cost, repeats)
        best = (sim.total_s, dict(assignment), sim)
        improved = True
        while improved:
            improved = False
            for op in ops:
                trial = dict(assignment)
                trial[op] = FIXED_WEIGHT if trial[op] == GENERIC else GENERIC
                s = _evaluate(trace, trial, budget, cost, repeats)
                if s.total_s < best[0]:
                    best = (s.total_s, trial, s)
                    assignment = trial
                    improved = True

    assert best is not None
    alts.sort(key=lambda p: p[1])
    return Plan(assignment=best[1], predicted=best[2], alternatives=alts[:8])
