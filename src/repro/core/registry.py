"""Kernel registry: the heart of transparent acceleration.

The paper registers presynthesized FPGA bitstreams as TensorFlow kernels; TF's
executor looks up a registered kernel implementation for the HSA device type and
dispatches it through the HSA runtime.  Here the registry maps a logical op name
(``"matmul"``, ``"flash_attention"``, ...) plus a device kind to a ranked list of
implementations.  Each implementation is tagged with a *source*:

  - ``"reference"`` — pure-jnp oracle (always correct, never fast),
  - ``"xla"``       — XLA-optimized jnp/lax formulation,
  - ``"pallas"``    — hand-written Pallas TPU kernel (the "presynthesized role").

Resolution is policy driven (see :mod:`repro.core.dispatch`): a preference order
over sources, like the paper's choice between online-synthesized OpenCL kernels
and presynthesized bitstreams.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Iterable, Sequence

Sources = ("pallas", "xla", "reference")

GENERIC = "generic"
FIXED_WEIGHT = "fixed_weight"


@dataclasses.dataclass(frozen=True)
class ResourceFootprint:
    """Static resource claim of an implementation (paper Table I analogue).

    ``vmem_bytes`` is the VMEM working set implied by the kernel's BlockSpecs;
    ``dsp_equiv`` counts MXU passes per block as the moral equivalent of DSP
    slices.  Purely informational for reference/xla impls.
    """

    vmem_bytes: int = 0
    hbm_bytes: int = 0
    mxu_tiles: int = 0

    def vmem_fraction(self, vmem_capacity: int = 128 * 1024 * 1024) -> float:
        return self.vmem_bytes / float(vmem_capacity)


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of a logical op."""

    op: str
    device_kind: str
    source: str                      # "pallas" | "xla" | "reference"
    fn: Callable[..., Any]
    name: str = ""
    specialization: str = GENERIC    # GENERIC | FIXED_WEIGHT
    priority: int = 0                # higher wins within a source
    footprint: ResourceFootprint = ResourceFootprint()
    tags: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if self.source not in Sources:
            raise ValueError(f"unknown source {self.source!r}; expected one of {Sources}")
        if not self.name:
            object.__setattr__(self, "name", f"{self.op}:{self.source}:{self.specialization}")


class KernelRegistry:
    """Thread-safe registry of kernel implementations.

    Mirrors TF's per-device kernel registry: ``register`` at import time,
    ``resolve`` at op-dispatch time.  ``snapshot``/``restore`` support
    hermetic tests.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._impls: dict[tuple[str, str], list[KernelImpl]] = {}
        self._version = 0      # bumped on any mutation; resolve caches key on it

    @property
    def version(self) -> int:
        """Monotonic mutation counter.  Resolution caches (e.g. the
        DispatchContext memo) key their entries on this so a late
        registration invalidates them without a registry round-trip."""
        with self._lock:
            return self._version

    # -- registration ------------------------------------------------------

    def register(self, impl: KernelImpl, *, allow_override: bool = False) -> KernelImpl:
        key = (impl.op, impl.device_kind)
        with self._lock:
            bucket = self._impls.setdefault(key, [])
            existing = [i for i in bucket if i.name == impl.name]
            if existing and not allow_override:
                raise ValueError(f"kernel {impl.name!r} already registered for {key}")
            for old in existing:
                bucket.remove(old)
            bucket.append(impl)
            # Stable resolution order: source preference is applied at resolve
            # time; within a bucket keep highest priority first.
            bucket.sort(key=lambda i: -i.priority)
            self._version += 1
        return impl

    def define(
        self,
        op: str,
        *,
        device_kind: str = "tpu",
        source: str,
        name: str = "",
        specialization: str = GENERIC,
        priority: int = 0,
        footprint: ResourceFootprint = ResourceFootprint(),
        tags: Sequence[str] = (),
        allow_override: bool = False,
    ) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
        """Decorator form: ``@registry.define("matmul", source="pallas")``."""

        def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
            self.register(
                KernelImpl(
                    op=op,
                    device_kind=device_kind,
                    source=source,
                    fn=fn,
                    name=name,
                    specialization=specialization,
                    priority=priority,
                    footprint=footprint,
                    tags=tuple(tags),
                ),
                allow_override=allow_override,
            )
            return fn

        return deco

    # -- resolution --------------------------------------------------------

    def resolve(
        self,
        op: str,
        device_kind: str,
        prefer: Sequence[str] = ("xla", "reference"),
        *,
        specialization: str | None = None,
        require: bool = True,
    ) -> KernelImpl | None:
        """Find the best implementation under a source-preference order.

        Falls back through ``prefer`` in order; within one source the highest
        priority impl wins.  ``specialization`` filters (e.g. a fixed-weight
        role requested by the role planner).
        """
        with self._lock:
            bucket = list(self._impls.get((op, device_kind), ()))
            if device_kind != "any":
                bucket += list(self._impls.get((op, "any"), ()))
        if specialization is not None:
            bucket = [i for i in bucket if i.specialization == specialization]
        for source in prefer:
            matches = [i for i in bucket if i.source == source]
            if matches:
                return max(matches, key=lambda i: i.priority)
        if require:
            have = sorted({i.source for i in bucket})
            raise KeyError(
                f"no kernel for op={op!r} device_kind={device_kind!r} under "
                f"prefer={tuple(prefer)}; registered sources: {have}"
            )
        return None

    def lookup(self, op: str, device_kind: str = "tpu") -> list[KernelImpl]:
        with self._lock:
            out = list(self._impls.get((op, device_kind), ()))
            if device_kind != "any":
                out += list(self._impls.get((op, "any"), ()))
            return out

    def ops(self) -> list[str]:
        with self._lock:
            return sorted({op for (op, _k) in self._impls})

    # -- test support ------------------------------------------------------

    def snapshot(self) -> dict[tuple[str, str], list[KernelImpl]]:
        with self._lock:
            return {k: list(v) for k, v in self._impls.items()}

    def restore(self, snap: dict[tuple[str, str], list[KernelImpl]]) -> None:
        with self._lock:
            self._impls = {k: list(v) for k, v in snap.items()}
            self._version += 1

    def clear(self) -> None:
        with self._lock:
            self._impls.clear()
            self._version += 1


GLOBAL_REGISTRY = KernelRegistry()


def register(impl: KernelImpl, **kw: Any) -> KernelImpl:
    return GLOBAL_REGISTRY.register(impl, **kw)


def define(op: str, **kw: Any) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
    return GLOBAL_REGISTRY.define(op, **kw)
