"""Config registry: ``--arch <id>`` resolution."""

from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, reduced
from repro.configs import (
    deepseek_v3_671b,
    granite_3_8b,
    hymba_1_5b,
    internvl2_76b,
    llama3_2_1b,
    llama4_maverick,
    mamba2_780m,
    whisper_large_v3,
    yi_6b,
    yi_9b,
)

ARCHS: dict[str, ArchConfig] = {
    cfg.name: cfg
    for cfg in (
        yi_9b.CONFIG,
        llama3_2_1b.CONFIG,
        yi_6b.CONFIG,
        granite_3_8b.CONFIG,
        internvl2_76b.CONFIG,
        hymba_1_5b.CONFIG,
        deepseek_v3_671b.CONFIG,
        llama4_maverick.CONFIG,
        mamba2_780m.CONFIG,
        whisper_large_v3.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch × shape) runnable? long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{arch.name} is pure full-attention (skip noted in DESIGN.md)"
        )
    return True, ""


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "get_arch",
    "get_shape",
    "cell_supported",
    "reduced",
]
