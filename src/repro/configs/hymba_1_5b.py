"""Hymba-1.5B — hybrid: parallel attention + mamba heads in every layer
[arXiv:2411.13676].

Sliding-window attention (1024) on all layers makes the hybrid sub-quadratic,
which is what qualifies it for the long_500k shape (the SSM branch carries
global context; the attention branch is local — the Hymba design point).
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    parallel_ssm=True,
    attn_window=1024,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, num_groups=1),
)
