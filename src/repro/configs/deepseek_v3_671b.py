"""DeepSeek-V3-671B — MLA + MoE (1 shared + 256 routed, top-8)
[arXiv:2412.19437].

MLA (multi-head latent attention) compresses the KV cache to
``kv_lora_rank + qk_rope_dim`` floats/token; decode uses the absorbed-weight
formulation.  First 3 layers are dense FFN (d_ff 18432); the remaining 58 are
MoE with expert d_ff 2048.  MTP (multi-token prediction) is exposed as an
optional training head (see models/transformer.py mtp support note).
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                 # dense layers (first_k_dense)
    vocab_size=129280,
    head_dim=128,
    moe=MoEConfig(
        num_experts=256,
        experts_per_token=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_k_dense=3,
        layer_period=1,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    rope_theta=10_000.0,
)
