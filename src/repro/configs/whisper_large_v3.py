"""Whisper-large-v3 — audio encoder-decoder [arXiv:2212.04356].

Backbone only: the conv frontend is a STUB — ``input_specs`` provides
precomputed frame embeddings [B, 1500, d_model] as the encoder input.
Decoder: causal self-attention (KV cache) + cross-attention over the encoder
memory (cross-KV computed once at prefill).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,               # decoder
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    cross_attention=True,
    frontend="audio_frames",
    frontend_seq=1500,
)
