"""Mamba2-780M — attention-free SSD (state-space duality) [arXiv:2405.21060]."""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,                # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, num_groups=1),
    tie_embeddings=True,
)
