"""InternVL2-76B — VLM [arXiv:2404.16821].

Backbone-only per the brief: the InternViT frontend is a STUB; ``input_specs``
provides precomputed patch embeddings (``frontend_seq`` positions of the token
sequence carry patch embeddings instead of token embeddings — early fusion).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    frontend="vision_patches",
    frontend_seq=256,
)
