"""Architecture + shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; every input shape
is a :class:`ShapeConfig`.  A (arch × shape) pair fully determines what the
launcher lowers: ``train_step`` for training shapes, ``prefill_step`` /
``decode_step`` for inference shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_k_dense: int = 0            # leading dense layers (deepseek: 3)
    layer_period: int = 1             # 1 = every layer MoE; 2 = alternating (llama4)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    router_z_weight: float = 0.0001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int                     # N
    head_dim: int = 64                 # P
    expand: int = 2                    # d_inner = expand * d_model
    num_groups: int = 1                # G (B/C groups)
    conv_kernel: int = 4
    chunk: int = 256

    def num_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (hymba): parallel attn + ssm heads within one layer
    parallel_ssm: bool = False
    attn_window: int | None = None     # sliding-window attention (None = full)
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub (vlm/audio): precomputed embeddings prepended
    frontend: str | None = None        # "vision_patches" | "audio_frames"
    frontend_seq: int = 0
    # numerics
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"                # "none" | "full" | "dots"

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(1, self.num_kv_heads):
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # -- derived sizes ---------------------------------------------------------

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: attention-free, or windowed attention."""
        if self.family == "ssm":
            return True
        return self.attn_window is not None

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (
                m.qk_nope_dim + m.qk_rope_dim
            )
            kv = d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank * self.num_heads * (
                m.qk_nope_dim + m.v_head_dim
            )
            o = self.num_heads * m.v_head_dim * d
            return q + kv + o
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        s = self.ssm
        d = self.d_model
        d_in = s.d_inner(d)
        h = s.num_heads(d)
        proj_in = d * (2 * d_in + 2 * s.num_groups * s.state_dim + h)
        conv = (d_in + 2 * s.num_groups * s.state_dim) * s.conv_kernel
        return proj_in + conv + 2 * h + d_in + d_in * d   # +a_log,D,norm,out_proj

    def _ffn_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff                    # swiglu: gate, up, down

    def layer_params(self, layer_idx: int) -> int:
        """Parameter count of one decoder layer (norms excluded, negligible)."""
        p = 0
        if self.family == "ssm":
            return self._ssm_params()
        p += self._attn_params()
        if self.parallel_ssm:
            p += self._ssm_params()
        if self.moe is not None and self.is_moe_layer(layer_idx):
            m = self.moe
            p += (m.num_experts + m.num_shared_experts) * 3 * self.d_model * m.d_ff_expert
            p += self.d_model * m.num_experts             # router
        else:
            p += self._ffn_params(self.d_ff)
        return p

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None or layer_idx < self.moe.first_k_dense:
            return False
        return (layer_idx - self.moe.first_k_dense) % self.moe.layer_period == 0

    def total_params(self) -> int:
        p = self.vocab_size * self.d_model                # embedding
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model           # unembed
        for i in range(self.num_layers):
            p += self.layer_params(i)
        if self.encoder_layers:
            enc_layer = self._attn_params() + self._ffn_params(self.d_ff)
            cross = self._attn_params() if self.cross_attention else 0
            p += self.encoder_layers * enc_layer + self.num_layers * cross
        return p

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.total_params()
        p = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        m = self.moe
        for i in range(self.num_layers):
            p += self._attn_params()
            if self.parallel_ssm:
                p += self._ssm_params()
            if self.is_moe_layer(i):
                p += (m.experts_per_token + m.num_shared_experts) * 3 * self.d_model * m.d_ff_expert
                p += self.d_model * m.num_experts
            else:
                p += self._ffn_params(self.d_ff)
        return p


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 128,
            vocab: int = 256) -> ArchConfig:
    """Smoke-test-sized config of the same family (CPU-runnable)."""
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, heads // max(1, cfg.num_heads // max(1, cfg.num_kv_heads)))
    if heads % kv:
        kv = 1
    changes: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=d_model * 2,
        vocab_size=vocab,
        head_dim=d_model // heads,
        frontend_seq=8 if cfg.frontend else 0,
        encoder_layers=min(2, cfg.encoder_layers),
        attn_window=(32 if cfg.attn_window else None),
        remat="none",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            experts_per_token=min(2, cfg.moe.experts_per_token),
            d_ff_expert=d_model,
            first_k_dense=min(1, cfg.moe.first_k_dense),
        )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=d_model // heads,
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk=16
        )
    return dataclasses.replace(cfg, **changes)
