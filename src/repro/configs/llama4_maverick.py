"""Llama-4 Maverick 400B-A17B — MoE, 128 routed experts top-1 + shared,
alternating dense/MoE layers [hf:meta-llama/Llama-4 family].

Early fusion is a frontend property; per the brief's [moe] tag this config is
the text backbone (the VLM stub pattern is exercised by internvl2-76b).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=16384,                 # dense (non-MoE) layers
    vocab_size=202048,
    head_dim=128,
    moe=MoEConfig(
        num_experts=128,
        experts_per_token=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        first_k_dense=0,
        layer_period=2,          # every other layer is MoE
    ),
    rope_theta=500_000.0,
)
