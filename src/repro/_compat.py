"""Forward-compat aliases so the codebase runs on older jax (0.4.x).

The code targets the modern mesh API (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``).  On
runtimes that predate it, install equivalent aliases once at package import.
All shims are no-ops when the real API exists.
"""

from __future__ import annotations

import contextlib
import inspect

import jax
import jax.sharding as _shd

if not hasattr(_shd, "AxisType"):
    try:
        from jax._src import mesh as _mesh_lib

        _shd.AxisType = _mesh_lib.AxisTypes
    except (ImportError, AttributeError):  # pragma: no cover - very old jax
        import enum

        _shd.AxisType = enum.Enum("AxisType", ["Auto", "User", "Collective"])

if not hasattr(jax, "set_mesh"):

    @contextlib.contextmanager
    def _set_mesh(mesh):
        # Mesh is itself a context manager for the ambient physical mesh;
        # explicit NamedShardings carry the mesh, so this is all we need.
        with mesh:
            yield mesh

    jax.set_mesh = _set_mesh

if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        # psum of a unit constant folds to the static axis size
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = _axis_size

if not hasattr(jax, "make_mesh"):            # pragma: no cover - very old jax

    def _make_mesh_fallback(axis_shapes, axis_names, *, devices=None,
                            axis_types=None):
        del axis_types
        import numpy as _np

        devs = list(devices) if devices is not None else jax.devices()
        return _shd.Mesh(
            _np.asarray(devs[: int(_np.prod(axis_shapes))]).reshape(axis_shapes),
            axis_names,
        )

    jax.make_mesh = _make_mesh_fallback
elif "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _orig_make_mesh = jax.make_mesh

    def _make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types                       # pre-AxisType jax: always Auto
        return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh
