"""Training step builder: loss → grads → optimizer under the production mesh.

Produces a jitted, donated, fully-sharded ``train_step(params, opt_state,
batch) -> (params, opt_state, metrics)``.  Sharding comes entirely from the
rules' in/out shardings; intermediates are GSPMD-propagated.  Gradient
accumulation (microbatching) runs as a ``lax.scan`` over batch slices with an
f32 accumulator.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist import act
from repro.dist.sharding import ShardingRules
from repro.models.model import DecoderLM, EncDecLM
from repro.models.moe import MoeMeshInfo
from repro.optim.adamw import OptConfig, opt_init, opt_state_specs, opt_update


def moe_mesh_info(cfg: ArchConfig, rules: ShardingRules, *,
                  for_decode: bool = False) -> MoeMeshInfo | None:
    if cfg.moe is None:
        return None
    mesh = rules.mesh
    axes = mesh.axis_names
    ep = rules.ep_axes()
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    psum_axes = None

    if for_decode and rules.serving:
        # Serving decode: tokens are tiny (B×1) — replicate them over the EP
        # axes and psum the combine.  Expert weights never move: either E
        # shards over every chip, or E over "model" with the FFN dim over
        # "data" (partial-f contributions also land in the psum).
        mode = "tp"
        ff = rules.logical_to_physical.get("expert_ff", ())
        if ff:                                     # f-sharded serving layout
            ep = ("model",)
            psum_axes = ("model",) + ff
            espec = {
                "wg": P("model", None, ff[0]),
                "wu": P("model", None, ff[0]),
                "wd": P("model", ff[0], None),
            }
        else:                                      # E sharded over data×model
            ep = tuple(a for a in ("data", "model") if a in axes)
            espec = {
                "wg": P(ep if len(ep) > 1 else ep[0], None, None),
                "wu": P(ep if len(ep) > 1 else ep[0], None, None),
                "wd": P(ep if len(ep) > 1 else ep[0], None, None),
            }
        token_spec = P(None, None, None)
    elif ep == ("model",) or len(ep) <= 1:
        mode = "tp"
        ep = ("model",) if "model" in axes else ep
        # [B, S, d]: B over dp, tokens replicated over the expert (model) axis
        token_spec = P(dp_entry, None, None)
        espec = {
            "wg": P(ep[0], None, None),
            "wu": P(ep[0], None, None),
            "wd": P(ep[0], None, None),
        }
    else:
        mode = "all"
        # [B, S, d]: B over dp, S over model — local flatten gives full-mesh
        # token sharding without a global reshape+reshard
        token_spec = P(dp_entry, "model", None)
        ep_sp: Any = ep if len(ep) > 1 else ep[0]
        espec = {
            "wg": P(ep_sp, None, None),
            "wu": P(ep_sp, None, None),
            "wd": P(ep_sp, None, None),
        }
    expert_specs = {"router": P(None, None), "experts": espec}
    return MoeMeshInfo(
        mesh=mesh, ep_axes=ep, mode=mode, token_spec=token_spec,
        expert_spec_tree=expert_specs, psum_axes=psum_axes,
    )


def auto_microbatches(global_batch: int, seq_len: int, rules: ShardingRules,
                      *, cfg: ArchConfig | None = None,
                      stack_budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation depth.

    The backward pass saves one residual-stream tensor per layer
    (L × tokens_per_dev × d_model × 2 bytes under full remat); choose the
    microbatch count that keeps that stack under ``stack_budget_bytes``.
    """
    import numpy as np

    dp = rules.logical_to_physical["batch"]
    dp_size = int(np.prod([rules.mesh.shape[a] for a in dp])) if dp else 1
    if global_batch % dp_size:
        dp_size = 1
    b_loc = global_batch // dp_size
    if cfg is not None:
        layers_total = cfg.num_layers + cfg.encoder_layers
        per_token = layers_total * cfg.d_model * 2
        target = max(1024, int(stack_budget_bytes / per_token))
    else:
        target = 16384
    m = 1
    while b_loc % (m * 2) == 0 and (b_loc // m) * seq_len > target:
        m *= 2
    return m


def batch_shardings(cfg: ArchConfig, rules: ShardingRules, global_batch: int) -> dict:
    mesh = rules.mesh
    out = {"tokens": NamedSharding(mesh, rules.batch_pspec(global_batch, 1))}
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = NamedSharding(mesh, rules.batch_pspec(global_batch, 2))
    if cfg.frontend == "audio_frames":
        out["frames"] = NamedSharding(mesh, rules.batch_pspec(global_batch, 2))
    return out


def make_train_step(
    model: DecoderLM | EncDecLM,
    opt_cfg: OptConfig,
    rules: ShardingRules,
    *,
    global_batch: int,
    microbatches: int = 1,
    donate: bool = True,
):
    """Returns (jitted step fn, param shardings, opt shardings, batch shardings)."""
    cfg = model.cfg
    mesh = rules.mesh
    spec_tree = model.param_specs()
    p_shard = rules.sharding_tree(spec_tree)
    o_pspec = opt_state_specs(opt_cfg, spec_tree, rules.pspec)
    o_shard = jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), o_pspec,
        is_leaf=lambda x: isinstance(x, P),
    )
    b_shard = batch_shardings(cfg, rules, global_batch)
    minfo = moe_mesh_info(cfg, rules)

    def loss_fn(params, batch):
        with act.use_rules(rules):
            return model.loss(params, batch, moe_info=minfo)

    def whole_batch_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return grads, metrics

    accum_dtype = jnp.dtype(opt_cfg.accum_dtype)

    def microbatched_grads(params, batch):
        def reshape(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(reshape, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)

        def body(acc, one):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, one
            )
            acc = jax.tree.map(
                lambda a, g: a + (g / microbatches).astype(accum_dtype), acc, grads
            )
            return acc, metrics

        grads, metrics = jax.lax.scan(body, g0, mb)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            grads, metrics = microbatched_grads(params, batch)
        else:
            grads, metrics = whole_batch_grads(params, batch)
        params, opt_state, om = opt_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om}

    step = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return step, p_shard, o_shard, b_shard


def init_train_state(model, opt_cfg: OptConfig, rules: ShardingRules, rng):
    """Materialize params + opt state with their production shardings.

    Only used at small scale (examples/tests); the dry-run never calls this.
    """
    from repro.models.params import init_params

    spec_tree = model.param_specs()
    params = init_params(spec_tree, rng)
    p_shard = rules.sharding_tree(spec_tree)
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = opt_init(opt_cfg, params)
    return params, opt_state
