"""Fault-tolerant training loop.

Production behaviours implemented and tested:

  - **checkpoint/restart**: atomic checkpoints every ``ckpt_every`` steps;
    on start, auto-resume from the newest valid checkpoint (data pipeline
    regenerates its stream from the step counter — no loader state).
  - **preemption**: SIGTERM/SIGINT trigger a final checkpoint before exit
    (the TPU-pod eviction contract).
  - **straggler watchdog**: per-step wall time tracked with an EWMA; steps
    slower than ``straggler_factor ×`` the EWMA are logged with their step
    index.  At real scale the hook re-routes to the pod scheduler; here it
    feeds the metrics log so tests can assert detection.
  - **NaN guard**: non-finite loss aborts with the last good checkpoint
    intact (never checkpoints a poisoned state).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.checkpoint import (
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    resumed_from: int = 0
    stragglers: list[int] = dataclasses.field(default_factory=list)
    last_metrics: dict = dataclasses.field(default_factory=dict)
    step_times_s: list[float] = dataclasses.field(default_factory=list)
    preempted: bool = False


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable[..., tuple[Any, Any, dict]],
        batch_at: Callable[[int], dict],
        cfg: LoopConfig,
        *,
        log: Callable[[str], None] = print,
    ):
        self.step_fn = step_fn
        self.batch_at = batch_at
        self.cfg = cfg
        self.log = log
        self._preempt = False

    def _install_handlers(self):
        def handler(signum, frame):
            self._preempt = True
        prev = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                prev[sig] = signal.signal(sig, handler)
            except ValueError:          # non-main thread (tests)
                pass
        return prev

    def _restore_handlers(self, prev):
        for sig, h in prev.items():
            signal.signal(sig, h)

    def run(self, params, opt_state) -> tuple[Any, Any, LoopReport]:
        cfg = self.cfg
        report = LoopReport()
        start_step = 0

        if cfg.ckpt_dir:
            path = latest_checkpoint(cfg.ckpt_dir)
            if path is not None:
                (params, opt_state), manifest = restore_checkpoint(
                    path, (params, opt_state)
                )
                start_step = int(manifest["step"])
                report.resumed_from = start_step
                self.log(f"[loop] resumed from {path} at step {start_step}")

        prev_handlers = self._install_handlers()
        ewma = None
        try:
            for step in range(start_step, cfg.total_steps):
                t0 = time.perf_counter()
                batch = self.batch_at(step)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.perf_counter() - t0
                report.step_times_s.append(dt)
                report.steps_run += 1
                report.last_metrics = {
                    k: float(np.asarray(jax.device_get(v)).mean())
                    for k, v in metrics.items()
                }

                if not np.isfinite(loss):
                    raise FloatingPointError(
                        f"non-finite loss at step {step}; last checkpoint intact"
                    )

                # straggler watchdog
                if ewma is None:
                    ewma = dt
                elif dt > cfg.straggler_factor * ewma and step > start_step + 2:
                    report.stragglers.append(step)
                    self.log(f"[loop] straggler suspected: step {step} took "
                             f"{dt:.3f}s vs EWMA {ewma:.3f}s")
                ewma = dt if ewma is None else (
                    cfg.ewma_alpha * dt + (1 - cfg.ewma_alpha) * ewma
                )

                if cfg.log_every and step % cfg.log_every == 0:
                    self.log(f"[loop] step {step} loss {loss:.4f} "
                             f"({dt*1e3:.0f} ms)")

                done = step + 1
                if cfg.ckpt_dir and (
                    done % cfg.ckpt_every == 0 or done == cfg.total_steps
                    or self._preempt
                ):
                    save_checkpoint(cfg.ckpt_dir, done, (params, opt_state),
                                    keep=cfg.keep)
                if self._preempt:
                    report.preempted = True
                    self.log(f"[loop] preemption: checkpointed at step {done}")
                    break
        finally:
            self._restore_handlers(prev_handlers)
        return params, opt_state, report
