from repro.train.loop import LoopConfig, LoopReport, TrainLoop
from repro.train.step import init_train_state, make_train_step, moe_mesh_info
