"""Model assembly: all 10 assigned architectures from shared blocks.

Layers are grouped into homogeneous **segments** scanned with ``lax.scan``
(stacked params → O(1) HLO size in depth; the only sane way to compile 80
dry-run cells).  Segment plans per family:

  dense/vlm:  [dense × L]
  moe (ds-v3): [dense × 3, moe × 58]
  moe (llama4):[(moe, dense) × 24]            (alternating unit)
  ssm:        [ssm × 48]
  hybrid:     [hybrid × 32]
  audio:      encoder [enc × 32] + decoder [dec × 32]

Three execution modes share the same layer code: ``full`` (training),
``prefill`` (full + emit KV/state caches), ``decode`` (one token, carry
caches).  MoE layers take a :class:`MoeMeshInfo` to run expert-parallel under
the active mesh (None = single-device smoke path).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.dist.act import shard_act
from repro.models import layers, mla, moe, ssm
from repro.models.params import ParamSpec, stack_specs

Params = Any

AUX_KEYS = ("load_balance", "router_z", "dropped_frac")


def _zero_aux() -> dict[str, jax.Array]:
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


# ---------------------------------------------------------------------------
# segment planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]           # layer kinds within one scanned unit
    count: int                       # scan length


def plan_segments(cfg: ArchConfig) -> list[Segment]:
    if cfg.family == "ssm":
        return [Segment(("ssm",), cfg.num_layers)]
    if cfg.family == "hybrid":
        return [Segment(("hybrid",), cfg.num_layers)]
    if cfg.moe is not None:
        k1 = cfg.moe.first_k_dense
        period = cfg.moe.layer_period
        segs = []
        if k1:
            segs.append(Segment(("dense",), k1))
        unit = ("moe",) + ("dense",) * (period - 1)
        segs.append(Segment(unit, (cfg.num_layers - k1) // period))
        return segs
    return [Segment(("dense",), cfg.num_layers)]


# ---------------------------------------------------------------------------
# per-kind layer specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ArchConfig) -> Params:
    if cfg.mla is not None:
        return {"mla": mla.mla_specs(cfg)}
    return {"attn": layers.attention_specs(cfg)}


def layer_specs(cfg: ArchConfig, kind: str) -> Params:
    d = cfg.d_model
    if kind == "ssm":
        return {"ln1": layers.norm_spec(d), "mamba": ssm.ssm_specs(cfg)}
    if kind == "hybrid":
        return {
            "ln1": layers.norm_spec(d),
            "attn": layers.attention_specs(cfg),
            "mamba": ssm.ssm_specs(cfg),
            "attn_norm": layers.norm_spec(d),
            "ssm_norm": layers.norm_spec(d),
            "ln2": layers.norm_spec(d),
            "mlp": layers.mlp_specs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": layers.norm_spec(d),
            **_attn_specs(cfg),
            "ln2": layers.norm_spec(d),
            "moe": moe.moe_specs(cfg),
        }
    if kind == "dense":
        return {
            "ln1": layers.norm_spec(d),
            **_attn_specs(cfg),
            "ln2": layers.norm_spec(d),
            "mlp": layers.mlp_specs(cfg),
        }
    if kind == "enc":
        return {
            "ln1": layers.norm_spec(d),
            "attn": layers.attention_specs(cfg),
            "ln2": layers.norm_spec(d),
            "mlp": layers.mlp_specs(cfg),
        }
    if kind == "dec":
        return {
            "ln1": layers.norm_spec(d),
            "attn": layers.attention_specs(cfg),
            "lnx": layers.norm_spec(d),
            "xattn": layers.cross_attention_specs(cfg),
            "ln2": layers.norm_spec(d),
            "mlp": layers.mlp_specs(cfg),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# per-kind layer application (full / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_attn_full(cfg, p, h, positions, *, causal=True):
    if cfg.mla is not None:
        y, ckv, krope = mla.mla_full(p["mla"], h, cfg, positions=positions)
        return y, {"ckv": ckv, "krope": krope}
    y, k, v = layers.attention_full(
        p["attn"], h, cfg, positions=positions, window=cfg.attn_window,
        causal=causal,
    )
    return y, {"k": k, "v": v}


def apply_layer_full(cfg: ArchConfig, kind: str, p: Params, x: jax.Array,
                     positions: jax.Array, moe_info=None,
                     memory=None) -> tuple[jax.Array, dict]:
    aux = _zero_aux()
    if kind == "ssm":
        x = x + ssm.ssm_full(p["mamba"], layers.apply_norm(p["ln1"], x, cfg.norm_eps), cfg)
        return x, aux
    if kind == "hybrid":
        h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
        a, _ = _apply_attn_full(cfg, p, h, positions)
        s = ssm.ssm_full(p["mamba"], h, cfg)
        merged = 0.5 * (
            layers.apply_norm(p["attn_norm"], a, cfg.norm_eps)
            + layers.apply_norm(p["ssm_norm"], s, cfg.norm_eps)
        )
        x = x + merged
        x = x + layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln2"], x, cfg.norm_eps))
        return x, aux
    # attention families
    h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
    causal = kind != "enc"
    a, _ = _apply_attn_full(cfg, p, h, positions, causal=causal)
    x = x + a
    if kind == "dec":
        hx = layers.apply_norm(p["lnx"], x, cfg.norm_eps)
        mem_k, mem_v = layers.encode_memory(p["xattn"], memory, cfg)
        x = x + layers.cross_attention(p["xattn"], hx, mem_k, mem_v, cfg)
    h2 = layers.apply_norm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = moe.apply_moe(p["moe"], h2, cfg, mesh_info=moe_info)
        aux = {**_zero_aux(), **{k: jnp.asarray(v, jnp.float32) for k, v in aux.items()}}
    else:
        y = layers.apply_mlp(p["mlp"], h2)
    return x + y, aux


def apply_layer_prefill(cfg, kind, p, x, positions, moe_info=None, memory=None):
    """Like full, but also returns the layer cache."""
    aux = _zero_aux()
    cache: dict[str, jax.Array] = {}
    if kind == "ssm":
        h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
        y, state, tail = ssm.ssm_full(p["mamba"], h, cfg, return_state=True)
        return x + y, {"ssm_state": state, "conv_tail": tail}, aux
    if kind == "hybrid":
        h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
        a, kv = _apply_attn_full(cfg, p, h, positions)
        s, state, tail = ssm.ssm_full(p["mamba"], h, cfg, return_state=True)
        merged = 0.5 * (
            layers.apply_norm(p["attn_norm"], a, cfg.norm_eps)
            + layers.apply_norm(p["ssm_norm"], s, cfg.norm_eps)
        )
        x = x + merged
        x = x + layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln2"], x, cfg.norm_eps))
        cache = {**_window_clip(cfg, kv), "ssm_state": state, "conv_tail": tail}
        return x, cache, aux
    h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
    causal = kind != "enc"
    a, kv = _apply_attn_full(cfg, p, h, positions, causal=causal)
    x = x + a
    cache = _window_clip(cfg, kv)
    if kind == "dec":
        hx = layers.apply_norm(p["lnx"], x, cfg.norm_eps)
        mem_k, mem_v = layers.encode_memory(p["xattn"], memory, cfg)
        x = x + layers.cross_attention(p["xattn"], hx, mem_k, mem_v, cfg)
        cache.update({"mem_k": mem_k, "mem_v": mem_v})
    h2 = layers.apply_norm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux_ = moe.apply_moe(p["moe"], h2, cfg, mesh_info=moe_info)
        aux = {**_zero_aux(), **{k: jnp.asarray(v, jnp.float32) for k, v in aux_.items()}}
    else:
        y = layers.apply_mlp(p["mlp"], h2)
    return x + y, cache, aux


def apply_layer_prefill_chunk(cfg, kind, p, x, cache, start, moe_info=None):
    """One prompt chunk through one layer against the partially-filled cache.

    Only plain dense GQA layers are chunk-safe: MoE capacity routing depends
    on the *other* tokens in the call (a chunk routes differently than the
    full prompt — not row-local, so not bitwise-reproducible), recurrent
    state folds sequentially, and windowed/ring caches clip by position.
    The engine gates chunking on the segment plan; this raise is the
    backstop for direct callers.
    """
    if kind != "dense" or cfg.mla is not None or cfg.attn_window is not None:
        raise ValueError(
            f"chunked prefill supports plain dense GQA layers only, not "
            f"{kind!r} (mla={cfg.mla is not None}, window={cfg.attn_window})"
        )
    h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
    a, ck, cv = layers.attention_prefill_chunk(
        p["attn"], h, cache["k"], cache["v"], start, cfg
    )
    x = x + a
    h2 = layers.apply_norm(p["ln2"], x, cfg.norm_eps)
    y = layers.apply_mlp(p["mlp"], h2)
    return x + y, {"k": ck, "v": cv}


def _pad_cache_time(cfg: ArchConfig, caches, cache_len: int):
    """Pad prefill KV/latent caches along the time axis to ``cache_len``."""
    import jax.tree_util as jtu

    time_keys = {"k", "v", "ckv", "krope"}

    def fn(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key not in time_keys:
            return x
        target = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        cur = x.shape[-2]
        if cur >= target:
            return x
        pad = [(0, 0)] * x.ndim
        pad[-2] = (0, target - cur)
        return jnp.pad(x, pad)

    return jtu.tree_map_with_path(fn, caches)


def _window_clip(cfg: ArchConfig, kv: dict) -> dict:
    """Ring-buffer clip of prefill KV to the attention window."""
    if cfg.attn_window is None or "k" not in kv:
        return kv
    w = cfg.attn_window
    S = kv["k"].shape[2]
    if S <= w:
        return kv
    # last `w` positions land at slots (S-w+i) % w — a roll of the tail
    tail_k, tail_v = kv["k"][:, :, -w:], kv["v"][:, :, -w:]
    shift = (S - w) % w
    return {
        "k": jnp.roll(tail_k, shift=shift, axis=2),
        "v": jnp.roll(tail_v, shift=shift, axis=2),
    }


def apply_layer_decode(cfg, kind, p, x, cache, pos, moe_info=None,
                       block_table=None):
    """One-token step. Returns (x, new_cache).

    With ``block_table`` the layer's k/v leaves are page *pools* ([P, Hkv,
    page_size, hd]) shared by the whole batch, and attention routes through
    the paged write + block-table kernel.  Only plain position-indexed GQA
    caches support paging; recurrent / latent / windowed layouts raise.
    """
    if block_table is not None and (kind not in ("dense", "moe")
                                    or cfg.mla is not None
                                    or cfg.attn_window is not None):
        raise ValueError(
            f"paged decode supports plain GQA KV caches only, not {kind!r} "
            f"(mla={cfg.mla is not None}, window={cfg.attn_window})"
        )
    if kind == "ssm":
        h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
        y, state, tail = ssm.ssm_decode(
            p["mamba"], h, cache["ssm_state"], cache["conv_tail"], cfg
        )
        return x + y, {"ssm_state": state, "conv_tail": tail}
    if kind == "hybrid":
        h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
        a, ck, cv = layers.attention_decode(
            p["attn"], h, cache["k"], cache["v"], pos, cfg, window=cfg.attn_window
        )
        s, state, tail = ssm.ssm_decode(
            p["mamba"], h, cache["ssm_state"], cache["conv_tail"], cfg
        )
        merged = 0.5 * (
            layers.apply_norm(p["attn_norm"], a, cfg.norm_eps)
            + layers.apply_norm(p["ssm_norm"], s, cfg.norm_eps)
        )
        x = x + merged
        x = x + layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln2"], x, cfg.norm_eps))
        return x, {"k": ck, "v": cv, "ssm_state": state, "conv_tail": tail}
    h = layers.apply_norm(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if cfg.mla is not None:
        a, ckv, krope = mla.mla_decode(
            p["mla"], h, cache["ckv"], cache["krope"], pos, cfg
        )
        new_cache.update({"ckv": ckv, "krope": krope})
    elif block_table is not None:
        a, pk, pv = layers.attention_decode_paged(
            p["attn"], h, cache["k"], cache["v"], block_table, pos, cfg
        )
        new_cache.update({"k": pk, "v": pv})
    else:
        a, ck, cv = layers.attention_decode(
            p["attn"], h, cache["k"], cache["v"], pos, cfg, window=cfg.attn_window
        )
        new_cache.update({"k": ck, "v": cv})
    x = x + a
    if kind == "dec":
        hx = layers.apply_norm(p["lnx"], x, cfg.norm_eps)
        x = x + layers.cross_attention(
            p["xattn"], hx, cache["mem_k"], cache["mem_v"], cfg
        )
    h2 = layers.apply_norm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        # decode routes only a handful of tokens: dropless is mandatory
        y, _ = moe.apply_moe(p["moe"], h2, cfg, mesh_info=moe_info, dropless=True)
    else:
        y = layers.apply_mlp(p["mlp"], h2)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------


def layer_cache_specs(cfg: ArchConfig, kind: str, batch: int, cache_len: int,
                      mem_len: int = 0) -> dict:
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if kind in ("ssm", "hybrid"):
        out.update(ssm.init_ssm_cache_specs(cfg, batch))
    if kind == "ssm":
        return out
    eff = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    if cfg.mla is not None:
        m = cfg.mla
        out["ckv"] = jax.ShapeDtypeStruct((batch, cache_len, m.kv_lora_rank),
                                          layers.COMPUTE_DTYPE)
        out["krope"] = jax.ShapeDtypeStruct((batch, cache_len, m.qk_rope_dim),
                                            layers.COMPUTE_DTYPE)
    else:
        kvshape = (batch, cfg.num_kv_heads, eff, cfg.head_dim)
        out["k"] = jax.ShapeDtypeStruct(kvshape, layers.COMPUTE_DTYPE)
        out["v"] = jax.ShapeDtypeStruct(kvshape, layers.COMPUTE_DTYPE)
    if kind == "dec":
        ms = (batch, cfg.num_kv_heads, mem_len, cfg.head_dim)
        out["mem_k"] = jax.ShapeDtypeStruct(ms, layers.COMPUTE_DTYPE)
        out["mem_v"] = jax.ShapeDtypeStruct(ms, layers.COMPUTE_DTYPE)
    return out


# ---------------------------------------------------------------------------
# the models
# ---------------------------------------------------------------------------


def _scan(body, carry, xs, *, remat: str, unroll: bool):
    """lax.scan, or a Python unroll (used by the roofline cost extrapolation:
    XLA's cost_analysis counts while-loop bodies once, so per-layer costs are
    recovered from small unrolled variants)."""
    if not unroll:
        return jax.lax.scan(_remat(body, remat), carry, xs)
    body_r = _remat(body, remat)         # match the scanned program's remat cost
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body_r(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def chunked_ce(embed_params: Params, h: jax.Array, labels: jax.Array,
               *, chunk: int = 1024) -> jax.Array:
    """Next-token cross entropy without materializing [B, S, V] logits.

    The unembed matmul + log-softmax run per sequence-chunk inside a
    rematerialized scan: peak memory is O(B·chunk·V) instead of O(B·S·V) —
    the difference between 2 GB and 500 GB at 1M tokens × 128k vocab.
    """
    B, S, _ = h.shape
    h_in = h[:, :-1]
    tgt = labels[:, 1:]
    n = S - 1
    c = min(chunk, n)
    pad = (-n) % c                       # S-1 is odd: pad the tail chunk
    if pad:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
    n_chunks = (n + pad) // c

    def body(carry, i):
        hc = jax.lax.dynamic_slice_in_dim(h_in, i * c, c, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(tgt, i * c, c, axis=1)
        logits = layers.unembed(embed_params, hc)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
        valid = (i * c + jnp.arange(c)) < n   # mask the padded tail
        return carry + jnp.sum(nll * valid[None, :]), None

    from repro.roofline.unrolling import inner_loops_unrolled

    if inner_loops_unrolled():          # cost-mode: count every chunk's FLOPs
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total, _ = body(total, jnp.asarray(i))
    else:
        total, _ = jax.lax.scan(
            jax.checkpoint(body), jnp.zeros((), jnp.float32), jnp.arange(n_chunks)
        )
    return total / (B * n)


def _merge_aux(a: dict, b: dict) -> dict:
    return {k: a[k] + b[k] for k in a}


class DecoderLM:
    """Decoder-only LM: dense / vlm / moe / ssm / hybrid families."""

    def __init__(self, cfg: ArchConfig, *, plan: list[Segment] | None = None,
                 unroll: bool = False):
        self.cfg = cfg
        self.segments = plan if plan is not None else plan_segments(cfg)
        self.unroll = unroll

    # -- parameters --------------------------------------------------------

    def param_specs(self) -> Params:
        cfg = self.cfg
        segs = []
        for seg in self.segments:
            unit = {str(i): layer_specs(cfg, kind) for i, kind in enumerate(seg.kinds)}
            segs.append(stack_specs(unit, seg.count, logical="layers"))
        return {
            "embed": layers.embed_specs(cfg),
            "segments": segs,
            "ln_f": layers.norm_spec(cfg.d_model),
        }

    # -- embedding ------------------------------------------------------------

    def _embed_inputs(self, params: Params, batch: dict) -> jax.Array:
        h = layers.embed_tokens(params["embed"], batch["tokens"])
        if self.cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            h = jax.lax.dynamic_update_slice(
                h, batch["patch_embeds"].astype(h.dtype), (0, 0, 0)
            )
        return shard_act(h, "batch", None, None)

    # -- full forward (training) ------------------------------------------------

    def backbone(self, params: Params, batch: dict, *, moe_info=None) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        S = h.shape[1]
        positions = jnp.arange(S)
        aux = _zero_aux()

        for seg, seg_params in zip(self.segments, params["segments"]):
            def body(carry, unit_params, _seg=seg):
                x, aux_c = carry
                dt0 = x.dtype
                for i, kind in enumerate(_seg.kinds):
                    x, aux_l = apply_layer_full(
                        cfg, kind, unit_params[str(i)], x, positions,
                        moe_info=moe_info,
                    )
                    x = shard_act(x.astype(dt0), "batch", None, None)
                    aux_c = _merge_aux(aux_c, aux_l)
                return (x, aux_c), None

            (h, aux), _ = _scan(body, (h, aux), seg_params,
                                remat=cfg.remat, unroll=self.unroll)

        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        return h, aux

    def forward(self, params: Params, batch: dict, *, moe_info=None):
        h, aux = self.backbone(params, batch, moe_info=moe_info)
        return layers.unembed(params["embed"], h), aux

    def loss(self, params: Params, batch: dict, *, moe_info=None) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        h, aux = self.backbone(params, batch, moe_info=moe_info)
        labels = batch.get("labels", batch["tokens"])
        loss = chunked_ce(params["embed"], h, labels)
        metrics = {"nll": loss, **aux}
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux["load_balance"]
            loss = loss + cfg.moe.router_z_weight * aux["router_z"]
        metrics["loss"] = loss
        return loss, metrics

    # -- prefill ------------------------------------------------------------------

    def prefill(self, params: Params, batch: dict, *, moe_info=None,
                cache_len: int | None = None):
        """Returns (last-token logits, cache). ``cache_len`` pre-allocates the
        KV/latent caches to the serving max length (ring-window caches stay at
        window size)."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        S = h.shape[1]
        positions = jnp.arange(S)
        caches = []

        for seg, seg_params in zip(self.segments, params["segments"]):
            def body(carry, unit_params, _seg=seg):
                x = carry
                dt0 = x.dtype
                unit_cache = {}
                for i, kind in enumerate(_seg.kinds):
                    x, c, _ = apply_layer_prefill(
                        cfg, kind, unit_params[str(i)], x, positions,
                        moe_info=moe_info,
                    )
                    x = shard_act(x.astype(dt0), "batch", None, None)
                    unit_cache[str(i)] = c
                return x, unit_cache

            h, seg_cache = _scan(body, h, seg_params, remat="none",
                                 unroll=self.unroll)
            caches.append(seg_cache)

        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], h[:, -1:])
        if cache_len is not None:
            caches = _pad_cache_time(cfg, caches, cache_len)
        cache = {"pos": jnp.asarray(S, jnp.int32), "segments": caches}
        return logits[:, 0], cache

    # -- chunked prefill ------------------------------------------------------------

    def prefill_chunk(self, params: Params, tokens: jax.Array, cache: dict, *,
                      start: int, moe_info=None):
        """One ``[B, Sc]`` prompt chunk at absolute positions
        ``[start, start + Sc)`` -> (logits of the chunk's last row [B, V],
        updated cache).

        The cache is a full-capacity staging cache (leaves ``[L, B, Hkv,
        max_len, hd]``); rows ``[0, start)`` hold the previous chunks' KV,
        this call writes ``[start, start + Sc)``.  ``start`` must be a
        static Python int (each (Sc, start) pair is one jitted trace — see
        :func:`repro.models.layers.attention_prefill_chunk`).  Row-for-row
        bitwise-identical to :meth:`prefill` over the whole prompt, which
        is what lets the serving engine interleave prefill chunks with
        decode without perturbing a single token stream.
        """
        cfg = self.cfg
        h = self._embed_inputs(params, {"tokens": tokens})
        new_segs = []

        for seg, seg_params, seg_cache in zip(
            self.segments, params["segments"], cache["segments"]
        ):
            def body(carry, xs, _seg=seg):
                x = carry
                dt0 = x.dtype
                unit_params, unit_cache = xs
                new_unit = {}
                for i, kind in enumerate(_seg.kinds):
                    x, c = apply_layer_prefill_chunk(
                        cfg, kind, unit_params[str(i)], x, unit_cache[str(i)],
                        start, moe_info=moe_info,
                    )
                    x = shard_act(x.astype(dt0), "batch", None, None)
                    new_unit[str(i)] = c
                return x, new_unit

            h, new_seg = _scan(body, h, (seg_params, seg_cache), remat="none",
                               unroll=self.unroll)
            new_segs.append(new_seg)

        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], h[:, -1:])
        end = start + tokens.shape[1]
        return logits[:, 0], {"pos": jnp.asarray(end, jnp.int32),
                              "segments": new_segs}

    # -- decode ---------------------------------------------------------------------

    def decode_step(self, params: Params, tokens: jax.Array, cache: dict, *,
                    moe_info=None):
        """tokens [B, 1] -> (logits [B, V], new cache).

        A ``cache["block_table"]`` entry ([B, NP] int32) switches the
        attention layers to the paged KV path: k/v leaves of ``segments``
        are then global page pools, written and read through the table (see
        :mod:`repro.serve.paged`).  The table itself is engine-owned and not
        part of the returned cache.
        """
        cfg = self.cfg
        h = layers.embed_tokens(params["embed"], tokens)
        pos = cache["pos"]
        block_table = cache.get("block_table")
        new_segs = []

        for seg, seg_params, seg_cache in zip(
            self.segments, params["segments"], cache["segments"]
        ):
            def body(carry, xs, _seg=seg):
                x = carry
                dt0 = x.dtype
                unit_params, unit_cache = xs
                new_unit = {}
                for i, kind in enumerate(_seg.kinds):
                    x, c = apply_layer_decode(
                        cfg, kind, unit_params[str(i)], x, unit_cache[str(i)],
                        pos, moe_info=moe_info, block_table=block_table,
                    )
                    x = shard_act(x.astype(dt0), "batch", None, None)
                    new_unit[str(i)] = c
                return x, new_unit

            h, new_seg = _scan(body, h, (seg_params, seg_cache), remat="none",
                               unroll=self.unroll)
            new_segs.append(new_seg)

        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], h)
        return logits[:, 0], {"pos": pos + 1, "segments": new_segs}

    # -- cache specs -------------------------------------------------------------------

    def cache_specs(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        segs = []
        for seg in self.segments:
            unit = {
                str(i): layer_cache_specs(cfg, kind, batch, cache_len)
                for i, kind in enumerate(seg.kinds)
            }
            segs.append(
                jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((seg.count, *s.shape), s.dtype),
                    unit,
                )
            )
        return {"pos": jax.ShapeDtypeStruct((), jnp.int32), "segments": segs}


class EncDecLM:
    """Whisper-style encoder-decoder (conv frontend stubbed: frame embeddings in)."""

    def __init__(self, cfg: ArchConfig, *, plan=None, unroll: bool = False):
        self.cfg = cfg
        self.unroll = unroll

    def param_specs(self) -> Params:
        cfg = self.cfg
        return {
            "embed": layers.embed_specs(cfg),
            "enc_pos": ParamSpec((cfg.frontend_seq, cfg.d_model), (None, "embed"),
                                 scale=0.02),
            "encoder": stack_specs(layer_specs(cfg, "enc"), cfg.encoder_layers,
                                   logical="layers"),
            "ln_enc": layers.norm_spec(cfg.d_model),
            "decoder": stack_specs(layer_specs(cfg, "dec"), cfg.num_layers,
                                   logical="layers"),
            "ln_f": layers.norm_spec(cfg.d_model),
        }

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = frames.astype(layers.COMPUTE_DTYPE) + params["enc_pos"].astype(layers.COMPUTE_DTYPE)
        positions = jnp.arange(h.shape[1])

        def body(x, lp):
            x, _ = apply_layer_full(cfg, "enc", lp, x, positions)
            return shard_act(x, "batch", None, None), None

        h, _ = _scan(body, h, params["encoder"], remat=cfg.remat,
                     unroll=self.unroll)
        return layers.apply_norm(params["ln_enc"], h, cfg.norm_eps)

    def _decode_full(self, params, tokens, memory, mode: str):
        cfg = self.cfg
        h = layers.embed_tokens(params["embed"], tokens)
        positions = jnp.arange(h.shape[1])

        if mode == "full":
            def body(x, lp):
                x, _ = apply_layer_full(cfg, "dec", lp, x, positions, memory=memory)
                return shard_act(x, "batch", None, None), None
            h, _ = _scan(body, h, params["decoder"], remat=cfg.remat,
                         unroll=self.unroll)
            return h, None

        def body(x, lp):
            x, c, _ = apply_layer_prefill(cfg, "dec", lp, x, positions, memory=memory)
            return shard_act(x, "batch", None, None), c
        h, cache = _scan(body, h, params["decoder"], remat="none",
                         unroll=self.unroll)
        return h, cache

    def loss(self, params: Params, batch: dict, *, moe_info=None):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        h, _ = self._decode_full(params, batch["tokens"], memory, "full")
        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        labels = batch.get("labels", batch["tokens"])
        loss = chunked_ce(params["embed"], h, labels)
        return loss, {"nll": loss, "loss": loss, **_zero_aux()}

    def prefill(self, params: Params, batch: dict, *, moe_info=None,
                cache_len: int | None = None):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        h, cache = self._decode_full(params, batch["tokens"], memory, "prefill")
        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], h[:, -1:])
        if cache_len is not None:
            # cross-attn memory caches are fixed-length; only self-attn pads
            def fn(path, x):
                import jax.tree_util as jtu  # noqa: F401
                key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
                if key in ("k", "v") and x.shape[-2] < cache_len:
                    pad = [(0, 0)] * x.ndim
                    pad[-2] = (0, cache_len - x.shape[-2])
                    return jnp.pad(x, pad)
                return x
            import jax.tree_util as jtu
            cache = jtu.tree_map_with_path(fn, cache)
        return logits[:, 0], {
            "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32),
            "segments": [cache],
        }

    def decode_step(self, params: Params, tokens: jax.Array, cache: dict, *,
                    moe_info=None):
        cfg = self.cfg
        h = layers.embed_tokens(params["embed"], tokens)
        pos = cache["pos"]

        def body(x, xs):
            lp, lc = xs
            x, c = apply_layer_decode(cfg, "dec", lp, x, lc, pos)
            return shard_act(x, "batch", None, None), c

        h, new_cache = _scan(body, h, (params["decoder"], cache["segments"][0]),
                             remat="none", unroll=self.unroll)
        h = layers.apply_norm(params["ln_f"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], h)
        return logits[:, 0], {"pos": pos + 1, "segments": [new_cache]}

    def cache_specs(self, batch: int, cache_len: int) -> dict:
        cfg = self.cfg
        unit = layer_cache_specs(cfg, "dec", batch, cache_len,
                                 mem_len=cfg.frontend_seq)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), unit
        )
        return {"pos": jax.ShapeDtypeStruct((), jnp.int32), "segments": [stacked]}


def build_model(cfg: ArchConfig, *, plan: list[Segment] | None = None,
                unroll: bool = False):
    if cfg.family == "audio":
        return EncDecLM(cfg, plan=plan, unroll=unroll)
    return DecoderLM(cfg, plan=plan, unroll=unroll)
