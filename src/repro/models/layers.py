"""Shared model building blocks.

Every compute hot-spot goes through ``dispatch.op`` — matmuls, norms,
attention, SSD — so the whole model zoo is transparently retargetable between
reference / XLA / Pallas kernels (the paper's property).  Functions are pure;
parameters are descriptor trees from :mod:`repro.models.params`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  (ensures registry population)
from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.dist.act import shard_act
from repro.models.params import ParamSpec

Params = Any
COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions: [..., dim/2]."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, D]; cos/sin: [S, D/2] (or broadcastable)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    # broadcast tables over head axis: [S, 1, D/2]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# elementary modules
# ---------------------------------------------------------------------------


def linear_spec(d_in: int, d_out: int, logical: tuple[str | None, str | None],
                scale: float | None = None) -> ParamSpec:
    return ParamSpec(
        shape=(d_in, d_out),
        logical=logical,
        scale=scale if scale is not None else 1.0 / np.sqrt(d_in),
    )


def norm_spec(d: int) -> ParamSpec:
    return ParamSpec(shape=(d,), logical=(None,), init="ones")


def apply_norm(p: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    return dispatch.op("rmsnorm", x, p, eps=eps)


def embed_specs(cfg: ArchConfig) -> Params:
    p: dict[str, ParamSpec] = {
        "tok": ParamSpec(
            shape=(cfg.vocab_size, cfg.d_model), logical=("vocab", "embed"),
            scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        p["unembed"] = linear_spec(cfg.d_model, cfg.vocab_size, ("embed", "vocab"))
    return p


def embed_tokens(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(COMPUTE_DTYPE)


def unembed(p: Params, h: jax.Array) -> jax.Array:
    if "unembed" in p:
        out = dispatch.op("matmul", h, p["unembed"], out_dtype=jnp.float32)
    else:
        out = jnp.einsum(
            "...d,vd->...v", h.astype(jnp.float32), p["tok"].astype(jnp.float32)
        )
    return shard_act(out, "batch", *([None] * (out.ndim - 2)), "vocab")


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_specs(cfg: ArchConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": linear_spec(d, cfg.num_heads * hd, ("embed", "heads")),
        "wk": linear_spec(d, cfg.num_kv_heads * hd, ("embed", "kv_heads")),
        "wv": linear_spec(d, cfg.num_kv_heads * hd, ("embed", "kv_heads")),
        "wo": linear_spec(cfg.num_heads * hd, d, ("heads", "embed")),
    }


def _qkv(p: Params, x: jax.Array, cfg: ArchConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dispatch.op("matmul", x, p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = dispatch.op("matmul", x, p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = dispatch.op("matmul", x, p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    q = shard_act(q, "batch", None, "heads", None)
    k = shard_act(k, "batch", None, "kv_heads", None)
    v = shard_act(v, "batch", None, "kv_heads", None)
    return q, k, v


def attention_full(
    p: Params,
    x: jax.Array,                      # [B, S, d]
    cfg: ArchConfig,
    *,
    positions: jax.Array,              # [S]
    window: int | None = None,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence attention (train/prefill). Returns (y, k, v) post-rope."""
    q, k, v = _qkv(p, x, cfg)
    cos, sin = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    out = dispatch.op(
        "flash_attention",
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal,
        window=window,
    ).swapaxes(1, 2)                    # [B, S, H, hd]
    B, S = x.shape[:2]
    y = dispatch.op("matmul", out.reshape(B, S, -1), p["wo"])
    return y, k.swapaxes(1, 2), v.swapaxes(1, 2)   # caches as [B, Hkv, S, hd]


def attention_prefill_chunk(
    p: Params,
    x: jax.Array,                      # [B, Sc, d] — prompt rows [start, start+Sc)
    cache_k: jax.Array,                # [B, Hkv, Tc, hd] staging cache
    cache_v: jax.Array,
    start: int,                        # static: chunk's absolute first position
    cfg: ArchConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One chunk of a split prefill against the partially-filled KV cache.

    Bitwise-identical to the corresponding rows of :func:`attention_full`
    over the whole prompt, with no new kernel: every per-row computation
    (qkv matmul, rope at absolute positions, rmsnorm) is row-local, and the
    ``flash_attention`` op already aligns a short query block to the *end*
    of its key sequence (``qpos = arange(Sc) + (T - Sc)``) — so feeding it
    the chunk's queries against the cache slice ``[:, :, :start+Sc]`` yields
    exactly the causal mask the full prefill applied to those rows.  Masked
    keys contribute an exact 0.0 after softmax, so the trailing
    already-cached rows change nothing bit for bit.

    ``start`` must be a static Python int: the cache slice bound is a trace
    constant, so each (Sc, start) pair is one jitted trace — bounded by
    ``max_len / chunk`` traces, the chunked analogue of prompt bucketing.
    """
    B, Sc, _ = x.shape
    hd = cfg.head_dim
    q, k, v = _qkv(p, x, cfg)
    positions = start + jnp.arange(Sc)
    cos, sin = rope_table(positions, hd, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.swapaxes(1, 2).astype(cache_k.dtype), (0, 0, start, 0)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.swapaxes(1, 2).astype(cache_v.dtype), (0, 0, start, 0)
    )
    end = start + Sc
    out = dispatch.op(
        "flash_attention",
        q.swapaxes(1, 2), cache_k[:, :, :end], cache_v[:, :, :end],
        causal=True,
    ).swapaxes(1, 2)                    # [B, Sc, H, hd]
    y = dispatch.op("matmul", out.reshape(B, Sc, -1), p["wo"])
    return y, cache_k, cache_v


def decode_positions(pos: jax.Array) -> jax.Array:
    """Rope positions for one decode step: pos scalar -> [1], [B] -> [B, 1]."""
    return pos[None] if pos.ndim == 0 else pos[:, None]


def write_kv(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write one token's KV [B, H, hd] into cache [B, H, Tc, hd] at ``slot``.

    ``slot`` scalar (uniform batch) or [B] (continuous batching: per-sequence
    positions).
    """
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(
            cache, new[:, :, None, :].astype(cache.dtype), (0, 0, slot, 0)
        )
    B = cache.shape[0]
    return cache.at[jnp.arange(B), :, slot].set(new.astype(cache.dtype))


def _sp_decode_body(q, k_new, v_new, ck, cv, pos, *, scale: float):
    """Sequence-parallel decode attention (inside shard_map over "model").

    The KV cache time axis is sharded; the new token's KV lands on exactly one
    owner shard (zero-comm masked write), local partial attention runs over
    the local T-chunk, and softmax statistics reduce with [B, H]-sized
    pmax/psum — the whole layer costs KBs of ICI traffic instead of gathering
    a multi-GiB cache.
    """
    B, Hq, hd = q.shape
    Hkv = ck.shape[1]
    T_loc = ck.shape[2]
    group = Hq // Hkv
    my = jax.lax.axis_index("model")
    owner = pos // T_loc
    slot = pos % T_loc

    # owner-masked write (hypothesis log §Perf: a slice-granular masked write
    # was tried and REFUTED — it added ops without reducing counted traffic)
    upd_k = jax.lax.dynamic_update_slice(
        ck, k_new[:, :, None, :].astype(ck.dtype), (0, 0, slot, 0))
    upd_v = jax.lax.dynamic_update_slice(
        cv, v_new[:, :, None, :].astype(cv.dtype), (0, 0, slot, 0))
    ck = jnp.where(my == owner, upd_k, ck)
    cv = jnp.where(my == owner, upd_v, cv)

    # grouped GQA einsum: the bf16 cache is read once, never repeated or
    # upcast — the repeat+f32 formulation touched group× more bytes
    qg = q.reshape(B, Hkv, group, hd)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    base = my * T_loc
    valid = (base + jnp.arange(T_loc))[None, None, None, :] <= pos
    logits = jnp.where(valid, logits, -1e30)                 # [B, Hkv, g, T_loc]

    m_loc = jnp.max(logits, axis=-1)
    m = jax.lax.pmax(m_loc, "model")                         # [B, Hkv, g]
    probs = jnp.exp(logits - m[..., None])
    denom = jax.lax.psum(jnp.sum(probs, axis=-1), "model")
    o_part = jnp.einsum("bkgt,bktd->bkgd", probs.astype(cv.dtype), cv,
                        preferred_element_type=jnp.float32)
    o = jax.lax.psum(o_part, "model") / denom[..., None]
    return o.reshape(B, Hq, hd).astype(q.dtype), ck, cv


def _sp_decode_attention(q, k, v, cache_k, cache_v, pos, cfg, rules):
    """shard_map wrapper for sequence-parallel decode attention."""
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B = q.shape[0]
    dpb = rules.batch_pspec(B, 0)[0]
    rep = P(dpb, None, None)
    cache_spec = P(dpb, None, "model", None)
    scale = 1.0 / float(np.sqrt(cfg.head_dim))

    body = functools.partial(_sp_decode_body, scale=scale)
    return shard_map(
        body,
        mesh=rules.mesh,
        in_specs=(rep, rep, rep, cache_spec, cache_spec, P()),
        out_specs=(rep, cache_spec, cache_spec),
        check_rep=False,
    )(q, k, v, cache_k, cache_v, pos)


def attention_decode(
    p: Params,
    x: jax.Array,                      # [B, 1, d]
    cache_k: jax.Array,                # [B, Hkv, Tc, hd]
    cache_v: jax.Array,
    pos: jax.Array,                    # scalar or [B]: tokens already cached
    cfg: ArchConfig,
    *,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode against a (ring-buffered, if windowed) KV cache."""
    from repro.dist import act

    B = x.shape[0]
    hd = cfg.head_dim
    Tc = cache_k.shape[2]
    q, k, v = _qkv(p, x, cfg)
    cos, sin = rope_table(decode_positions(pos), hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)[:, 0]                     # [B, H, hd]
    k = apply_rope(k, cos, sin)[:, 0]                     # [B, Hkv, hd]
    v = v[:, 0]

    # sequence-parallel path: serving, kv heads don't divide TP, full cache
    rules = act.current()
    model_size = rules.mesh.shape.get("model", 1) if rules is not None else 1
    if (rules is not None and rules.serving and model_size > 1
            and cfg.num_kv_heads % model_size != 0
            and Tc % model_size == 0 and window is None and pos.ndim == 0):
        out, cache_k, cache_v = _sp_decode_attention(
            q, k, v, cache_k, cache_v, pos, cfg, rules
        )
        y = dispatch.op("matmul", out.reshape(B, 1, -1)[:, 0], p["wo"])
        return y[:, None, :], cache_k, cache_v

    slot = pos % Tc                     # ring buffer when windowed; pos < Tc otherwise
    cache_k = write_kv(cache_k, k, slot)
    cache_v = write_kv(cache_v, v, slot)
    length = jnp.minimum(pos + 1, Tc)
    out = dispatch.op("decode_attention", q, cache_k, cache_v, length)
    y = dispatch.op("matmul", out.reshape(B, 1, -1)[:, 0], p["wo"])
    return y[:, None, :], cache_k, cache_v


def paged_write_kv(pool: jax.Array, new: jax.Array, page: jax.Array,
                   offset: jax.Array) -> jax.Array:
    """Write one token's KV [B, H, hd] into the pool [P, H, ps, hd] at each
    sequence's ``(page[b], offset[b])``.

    Live slots own disjoint pages, so batch writes never collide; masked
    (finished) slots are steered to the scratch page by their cleared block
    tables, where collisions are harmless.
    """
    return pool.at[page, :, offset].set(new.astype(pool.dtype))


def attention_decode_paged(
    p: Params,
    x: jax.Array,                      # [B, 1, d]
    k_pages: jax.Array,                # [P, Hkv, ps, hd] global block pool
    v_pages: jax.Array,
    block_table: jax.Array,            # [B, NP] page index -> pool page
    pos: jax.Array,                    # scalar or [B]: tokens already cached
    cfg: ArchConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode against a paged KV cache.

    Identical q/k/v/rope math to :func:`attention_decode`; the only change
    is *where* KV lives: the new token is written into the pool page the
    block table maps its position to, and attention runs via the
    ``paged_decode_attention`` op (whose XLA source gathers pages back into
    the dense layout and then executes the same dense decode-attention
    function — which is what makes paged serving bitwise-identical to
    dense, not merely allclose).
    """
    B = x.shape[0]
    hd = cfg.head_dim
    ps = k_pages.shape[2]
    q, k, v = _qkv(p, x, cfg)
    cos, sin = rope_table(decode_positions(pos), hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)[:, 0]                     # [B, H, hd]
    k = apply_rope(k, cos, sin)[:, 0]                     # [B, Hkv, hd]
    v = v[:, 0]

    posb = jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos
    page = jnp.take_along_axis(
        block_table, (posb // ps)[:, None], axis=1
    )[:, 0]
    k_pages = paged_write_kv(k_pages, k, page, posb % ps)
    v_pages = paged_write_kv(v_pages, v, page, posb % ps)
    out = dispatch.op(
        "paged_decode_attention", q, k_pages, v_pages, block_table, posb + 1
    )
    y = dispatch.op("matmul", out.reshape(B, 1, -1)[:, 0], p["wo"])
    return y[:, None, :], k_pages, v_pages


def cross_attention_specs(cfg: ArchConfig) -> Params:
    return attention_specs(cfg)


def cross_attention(
    p: Params,
    x: jax.Array,                      # [B, S, d] decoder side
    mem_k: jax.Array,                  # [B, Hkv, T_enc, hd] precomputed
    mem_v: jax.Array,
    cfg: ArchConfig,
) -> jax.Array:
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dispatch.op("matmul", x, p["wq"]).reshape(B, S, cfg.num_heads, hd)
    if S == 1:
        out = dispatch.op(
            "decode_attention", q[:, 0], mem_k, mem_v, mem_k.shape[2]
        )[:, None]
    else:
        out = dispatch.op(
            "flash_attention", q.swapaxes(1, 2), mem_k, mem_v, causal=False
        ).swapaxes(1, 2)
    return dispatch.op("matmul", out.reshape(B, S, -1), p["wo"])


def encode_memory(p: Params, memory: jax.Array, cfg: ArchConfig):
    """Precompute cross-attention K/V from encoder output ([B, T, d])."""
    B, T, _ = memory.shape
    hd = cfg.head_dim
    k = dispatch.op("matmul", memory, p["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = dispatch.op("matmul", memory, p["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    return k.swapaxes(1, 2), v.swapaxes(1, 2)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": linear_spec(d, f, ("embed", "mlp")),
        "wu": linear_spec(d, f, ("embed", "mlp")),
        "wd": linear_spec(f, d, ("mlp", "embed")),
    }


def apply_mlp(p: Params, x: jax.Array) -> jax.Array:
    g = dispatch.op("matmul", x, p["wg"], activation="silu")
    u = dispatch.op("matmul", x, p["wu"])
    h = shard_act(g * u, "batch", *([None] * (x.ndim - 2)), "mlp")
    return dispatch.op("matmul", h, p["wd"])
