"""Mamba-2 block (SSD core + depthwise causal conv + gated norm).

Layer structure (arXiv:2405.21060):

  u = in_proj(x)          -> [z | xBC | dt]
  xBC = silu(causal_conv1d(xBC))           (kernel 4, depthwise)
  y = SSD(x_heads, a_log, B, C, softplus(dt + dt_bias)) + D ⊙ x_heads
  out = out_proj(rmsnorm(y ⊙ silu(z)))

Decode carries two state tensors: the SSD state [B, H, P, N] and the conv
tail [B, K-1, conv_channels].
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SSMConfig
from repro.core import dispatch
from repro.dist.act import shard_act
from repro.models import layers
from repro.models.params import ParamSpec

Params = Any


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    assert s is not None
    d_in = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    conv_ch = d_in + 2 * s.num_groups * s.state_dim
    return s, d_in, H, conv_ch


def ssm_specs(cfg: ArchConfig) -> Params:
    s, d_in, H, conv_ch = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.num_groups * s.state_dim + H
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_inner"),
                             scale=1.0 / np.sqrt(d)),
        "conv_w": ParamSpec((s.conv_kernel, conv_ch), (None, "ssm_inner"),
                            scale=1.0 / np.sqrt(s.conv_kernel)),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((H,), (None,), init="ssm_a", dtype=jnp.float32),
        "skip_d": ParamSpec((H,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((H,), (None,), init="zeros", dtype=jnp.float32),
        "norm": layers.norm_spec(d_in),
        "out_proj": ParamSpec((d_in, d), ("ssm_inner", "embed"),
                              scale=1.0 / np.sqrt(d_in)),
    }


def _split_proj(u: jax.Array, cfg: ArchConfig):
    s, d_in, H, _ = _dims(cfg)
    gn = s.num_groups * s.state_dim
    z = u[..., :d_in]
    xbc = u[..., d_in: 2 * d_in + 2 * gn]
    dt = u[..., 2 * d_in + 2 * gn:]
    return z, xbc, dt


def _conv_full(xbc: jax.Array, w: jax.Array, b: jax.Array, K: int) -> jax.Array:
    """Causal depthwise conv over [B, S, C] with small static kernel K."""
    pads = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    S = xbc.shape[1]
    acc = jnp.zeros_like(xbc, shape=xbc.shape).astype(jnp.float32)
    for i in range(K):                       # static unroll, K = 4
        acc = acc + pads[:, i: i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = acc + b.astype(jnp.float32)
    return (y * jax.nn.sigmoid(y)).astype(xbc.dtype)            # silu


def _post(p: Params, y_heads: jax.Array, z: jax.Array, cfg: ArchConfig):
    """Skip, gated norm, output projection. y_heads [..., H, P]."""
    s, d_in, _, _ = _dims(cfg)
    y = y_heads.reshape(*y_heads.shape[:-2], d_in)
    zf = z.astype(jnp.float32)
    gated = y.astype(jnp.float32) * (zf * jax.nn.sigmoid(zf))
    normed = layers.apply_norm(p["norm"], gated.astype(y.dtype), cfg.norm_eps)
    return dispatch.op("matmul", normed, p["out_proj"])


def ssm_full(
    p: Params,
    x: jax.Array,                      # [B, S, d]
    cfg: ArchConfig,
    *,
    return_state: bool = False,
):
    """Train/prefill path via the chunked SSD op."""
    s, d_in, H, conv_ch = _dims(cfg)
    B, S, _ = x.shape
    u = dispatch.op("matmul", x, p["in_proj"])
    u = shard_act(u, "batch", None, "ssm_inner")
    z, xbc, dt = _split_proj(u, cfg)
    conv_tail = xbc[:, -(s.conv_kernel - 1):, :]                 # pre-activation tail
    xbc = _conv_full(xbc, p["conv_w"], p["conv_b"], s.conv_kernel)
    gn = s.num_groups * s.state_dim
    xs, bc = xbc[..., :d_in], xbc[..., d_in:]
    bmat = bc[..., :gn].reshape(B, S, s.num_groups, s.state_dim)
    cmat = bc[..., gn:].reshape(B, S, s.num_groups, s.state_dim)
    x_heads = shard_act(
        xs.reshape(B, S, H, s.head_dim), "batch", None, "ssm_heads", None
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    res = dispatch.op(
        "ssd", x_heads, p["a_log"], bmat, cmat, dt,
        chunk=s.chunk, return_state=return_state,
    )
    if return_state:
        y, state = res
    else:
        y, state = res, None
    y = y + (p["skip_d"][:, None] * x_heads.astype(jnp.float32)).astype(y.dtype)
    out = _post(p, y, z, cfg)
    if return_state:
        return out, state, conv_tail
    return out


def ssm_decode(
    p: Params,
    x: jax.Array,                      # [B, 1, d]
    ssm_state: jax.Array,              # [B, H, P, N] f32
    conv_tail: jax.Array,              # [B, K-1, conv_ch] (pre-activation)
    cfg: ArchConfig,
):
    from repro.kernels.ops import ssd_step

    s, d_in, H, conv_ch = _dims(cfg)
    B = x.shape[0]
    u = dispatch.op("matmul", x[:, 0], p["in_proj"])             # [B, proj]
    z, xbc_t, dt = _split_proj(u, cfg)
    window = jnp.concatenate([conv_tail, xbc_t[:, None, :]], axis=1)  # [B, K, C]
    yconv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(
        jnp.float32
    )
    yconv = (yconv * jax.nn.sigmoid(yconv)).astype(x.dtype)
    gn = s.num_groups * s.state_dim
    xs, bc = yconv[..., :d_in], yconv[..., d_in:]
    bvec = bc[..., :gn].reshape(B, s.num_groups, s.state_dim)
    cvec = bc[..., gn:].reshape(B, s.num_groups, s.state_dim)
    x_heads = xs.reshape(B, H, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    new_state, y = ssd_step(ssm_state, x_heads, p["a_log"], bvec, cvec, dt)
    y = y + (p["skip_d"][:, None] * x_heads.astype(jnp.float32)).astype(y.dtype)
    out = _post(p, y[:, None], z[:, None], cfg)
    new_tail = window[:, 1:, :].astype(conv_tail.dtype)
    return out, new_state, new_tail


def init_ssm_cache_specs(cfg: ArchConfig, batch: int):
    """ShapeDtypeStructs for one layer's SSM cache."""
    s, d_in, H, conv_ch = _dims(cfg)
    return {
        "ssm_state": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.state_dim),
                                          jnp.float32),
        "conv_tail": jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, conv_ch),
                                          layers.COMPUTE_DTYPE),
    }
