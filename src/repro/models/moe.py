"""Mixture-of-Experts with expert parallelism.

Two EP layouts, chosen per arch (see dist/sharding.py):

  - ``all`` (high fanout, e.g. DeepSeek-V3 256e top-8): experts sharded over
    ("data","model") — one expert per chip at the production mesh.  Tokens are
    resharded so every chip holds T/(P) tokens, dispatched into per-expert
    capacity buffers, exchanged with **all_to_all**, expert-FFN'd, and
    exchanged back.  Cross-pod traffic is avoided: the all_to_all axis group
    excludes "pod", so each pod runs an independent EP exchange (DCN carries
    only gradient all-reduce).

  - ``tp`` (low fanout, e.g. Llama-4 top-1): experts sharded over ("model",)
    with tokens replicated along it; each chip computes its local experts'
    contribution and a single **psum** over "model" combines — one collective
    instead of two all_to_alls, the right trade at top-1.

Dispatch uses GShard-style capacity buffers (scatter by expert rank with
overflow dropping, capacity_factor configurable); the dropped fraction is
reported in aux metrics.  Everything is differentiable (scatter-add / gather).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MoEConfig
from repro.core import dispatch
from repro.models import layers
from repro.models.params import ParamSpec

Params = Any


def moe_specs(cfg: ArchConfig) -> Params:
    m = cfg.moe
    assert m is not None
    d, f = cfg.d_model, m.d_ff_expert
    scale = 1.0 / np.sqrt(d)
    p: dict[str, Any] = {
        "router": ParamSpec((d, m.num_experts), ("embed", None), scale=scale,
                            dtype=jnp.float32),
        "experts": {
            "wg": ParamSpec((m.num_experts, d, f),
                            ("expert", "expert_embed", "expert_ff"), scale=scale),
            "wu": ParamSpec((m.num_experts, d, f),
                            ("expert", "expert_embed", "expert_ff"), scale=scale),
            "wd": ParamSpec((m.num_experts, f, d),
                            ("expert", "expert_ff", "expert_embed"),
                            scale=1.0 / np.sqrt(f)),
        },
    }
    if m.num_shared_experts:
        p["shared"] = layers.mlp_specs(cfg, m.d_ff_expert * m.num_shared_experts)
    return p


# ---------------------------------------------------------------------------
# local building blocks (used both standalone and inside shard_map)
# ---------------------------------------------------------------------------


def _route(x: jax.Array, router_w: jax.Array, m: MoEConfig):
    """Top-k routing. Returns (weights [T,k], ids [T,k], aux dict)."""
    logits = jnp.dot(x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    weights, ids = jax.lax.top_k(probs, m.experts_per_token)     # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    T, E = logits.shape
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = counts / (T * m.experts_per_token)
    mean_prob = jnp.mean(probs, axis=0)
    aux_lb = E * jnp.sum(frac * mean_prob)
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    aux_z = jnp.mean(z * z)
    return weights, ids, {"load_balance": aux_lb, "router_z": aux_z}


def _dispatch_indices(ids: jax.Array, E: int, C: int):
    """Slot assignment: for each (token, choice) its rank within the expert.

    Sort-based (megablocks-style): stable-sort choices by expert id; within
    the sorted array, rank = position − first-occurrence-of-my-expert
    (a vectorized searchsorted), then scatter ranks back.  O(n log n) in both
    time and cost-model bytes — the previous one-hot cumsum formulation was
    cost-modeled as an O(n²) reduce-window and dominated the *entire* MoE
    training byte budget (see EXPERIMENTS §Perf, deepseek-v3 iteration 1).
    Ranking prefers earlier tokens on overflow, same as the cumsum form.
    """
    T, k = ids.shape
    n = T * k
    flat = ids.reshape(-1)                                       # [T*k]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")    # run starts
    rank_sorted = jnp.arange(n) - first
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32)
    )
    keep = rank < C
    slot = flat * C + jnp.minimum(rank, C - 1)                   # [T*k]
    return slot, keep


def _dispatch(x: jax.Array, slot: jax.Array, keep: jax.Array, E: int, C: int):
    """Scatter token copies into [E*C, d] capacity buffers."""
    T = x.shape[0]
    k = slot.shape[0] // T
    src = jnp.repeat(x, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * C, x.shape[1]), x.dtype)
    return buf.at[slot].add(src, mode="drop")


def _combine(buf_out: jax.Array, slot: jax.Array, keep: jax.Array,
             weights: jax.Array, T: int):
    """Gather expert outputs back to tokens, weighted by router weights."""
    k = weights.shape[1]
    gathered = buf_out[slot]                                     # [T*k, d]
    gathered = gathered * (keep[:, None] * weights.reshape(-1, 1)).astype(
        gathered.dtype
    )
    return jnp.sum(gathered.reshape(T, k, -1), axis=1)


def _expert_ffn(xin: jax.Array, experts: Params) -> jax.Array:
    """Batched SwiGLU over local experts: xin [E_loc, C', d]."""
    g = jnp.einsum("ecd,edf->ecf", xin, experts["wg"])
    g = g * jax.nn.sigmoid(g.astype(jnp.float32)).astype(g.dtype)
    u = jnp.einsum("ecd,edf->ecf", xin, experts["wu"])
    return jnp.einsum("ecf,efd->ecd", g * u, experts["wd"])


# ---------------------------------------------------------------------------
# execution modes
# ---------------------------------------------------------------------------


def _capacity(T: int, m: MoEConfig, dropless: bool) -> int:
    """Tokens-per-expert buffer depth.

    ``dropless=True`` (decode/serving): C = T·k guarantees no token is ever
    dropped — mandatory when T is small (a single decode step routes only a
    handful of tokens and capacity-dropping would corrupt generations).
    Training uses the GShard capacity factor.
    """
    if dropless:
        return T * m.experts_per_token
    return max(1, int(np.ceil(T * m.experts_per_token * m.capacity_factor
                              / m.num_experts)))


def _moe_local(x: jax.Array, p: Params, m: MoEConfig,
               dropless: bool = False) -> tuple[jax.Array, dict]:
    """Single-device path (smoke tests, CPU examples)."""
    T, d = x.shape
    E = m.num_experts
    C = _capacity(T, m, dropless)
    weights, ids, aux = _route(x, p["router"], m)
    slot, keep = _dispatch_indices(ids, E, C)
    buf = _dispatch(x, slot, keep, E, C)
    out = _expert_ffn(buf.reshape(E, C, d), p["experts"]).reshape(E * C, d)
    y = _combine(out, slot, keep, weights, T)
    aux["dropped_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, aux


def _moe_ep_all_to_all(
    x: jax.Array, p: Params, m: MoEConfig, ep_axes: tuple[str, ...],
    dropless: bool = False, mesh_axes: tuple[str, ...] = (),
) -> tuple[jax.Array, dict]:
    """shard_map body: tokens and experts both sharded over ep_axes."""
    T_loc, d = x.shape
    E = m.num_experts
    P_ep = int(np.prod([jax.lax.axis_size(a) for a in ep_axes]))
    E_loc = E // P_ep
    C = _capacity(T_loc, m, dropless)

    weights, ids, aux = _route(x, p["router"], m)
    slot, keep = _dispatch_indices(ids, E, C)
    buf = _dispatch(x, slot, keep, E, C)                          # [E*C, d]
    buf = buf.reshape(P_ep, E_loc * C, d)
    recv = jax.lax.all_to_all(buf, ep_axes, split_axis=0, concat_axis=0,
                              tiled=False)                        # [P, E_loc*C, d]
    xin = recv.reshape(P_ep, E_loc, C, d).transpose(1, 0, 2, 3).reshape(
        E_loc, P_ep * C, d
    )
    out = _expert_ffn(xin, p["experts"])                          # [E_loc, P*C, d]
    out = out.reshape(E_loc, P_ep, C, d).transpose(1, 0, 2, 3)    # [P, E_loc, C, d]
    back = jax.lax.all_to_all(out.reshape(P_ep, E_loc * C, d), ep_axes,
                              split_axis=0, concat_axis=0, tiled=False)
    y = _combine(back.reshape(E * C, d), slot, keep, weights, T_loc)
    aux["dropped_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {k: jax.lax.pmean(v, mesh_axes or ep_axes) for k, v in aux.items()}
    return y, aux


def _moe_ep_tp(
    x: jax.Array, p: Params, m: MoEConfig, ep_axes: tuple[str, ...],
    dropless: bool = False, mesh_axes: tuple[str, ...] = (),
    psum_axes: tuple[str, ...] | None = None,
) -> tuple[jax.Array, dict]:
    """shard_map body: tokens replicated over ep_axes, experts sharded.

    Each chip dispatches only to its local experts and a psum combines.
    ``psum_axes`` may exceed ``ep_axes`` when the expert FFN dim is
    additionally sharded (serving mode: partial-f contributions also sum).
    """
    T, d = x.shape
    E = m.num_experts
    P_ep = int(np.prod([jax.lax.axis_size(a) for a in ep_axes]))
    E_loc = E // P_ep
    my = jax.lax.axis_index(ep_axes[0]) if len(ep_axes) == 1 else (
        jax.lax.axis_index(ep_axes[0]) * jax.lax.axis_size(ep_axes[1])
        + jax.lax.axis_index(ep_axes[1])
    )
    e_lo = my * E_loc

    weights, ids, aux = _route(x, p["router"], m)
    C = _capacity(T, m, dropless)
    slot, keep = _dispatch_indices(ids, E, C)
    # keep only slots belonging to my experts, re-based to local ids
    flat_e = ids.reshape(-1)
    mine = (flat_e >= e_lo) & (flat_e < e_lo + E_loc)
    keep_loc = keep & mine
    slot_loc = jnp.where(mine, slot - e_lo * C, 0)
    buf = _dispatch(x, slot_loc, keep_loc, E_loc, C)              # [E_loc*C, d]
    out = _expert_ffn(buf.reshape(E_loc, C, d), p["experts"]).reshape(E_loc * C, d)
    y_part = _combine(out, slot_loc, keep_loc, weights, T)
    y = jax.lax.psum(y_part, psum_axes or ep_axes)
    aux["dropped_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    if mesh_axes:
        aux = {k: jax.lax.pmean(v, mesh_axes) for k, v in aux.items()}
    return y, aux


def apply_moe(
    p: Params,
    x: jax.Array,                      # [B, S, d]
    cfg: ArchConfig,
    *,
    mesh_info: "MoeMeshInfo | None" = None,
    dropless: bool = False,
) -> tuple[jax.Array, dict]:
    """Routed experts (+ shared experts added on top)."""
    m = cfg.moe
    assert m is not None
    B, S, d = x.shape

    if mesh_info is None:
        y, aux = _moe_local(x.reshape(B * S, d), p, m, dropless)
        y = y.reshape(B, S, d)
    else:
        # [B, S, d] enters the shard_map directly (B over dp, S over model for
        # EP-all): the token flatten happens per-device, avoiding the global
        # reshape+reshard XLA cannot partition efficiently.
        y, aux = mesh_info.run(p, x, m, dropless)
    y = y.astype(x.dtype)                    # residual-stream dtype stability

    if "shared" in p:
        y = y + layers.apply_mlp(p["shared"], x)
    return y, aux


@dataclasses.dataclass(frozen=True)
class MoeMeshInfo:
    """How to execute MoE under the active mesh (built by the step builder)."""

    mesh: Any
    ep_axes: tuple[str, ...]
    mode: str                          # "all" | "tp"
    token_spec: Any                    # P spec for [B, S, d] tokens in shard_map
    expert_spec_tree: Any              # P specs for the MoE param subtree
    psum_axes: tuple[str, ...] | None = None   # tp mode: combine axes if wider

    def run(self, p: Params, x: jax.Array, m: MoEConfig, dropless: bool = False):
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        mesh_axes = tuple(self.mesh.axis_names)

        if self.mode == "all":
            def body(xt, params):
                return _moe_ep_all_to_all(xt, params, m, self.ep_axes,
                                          dropless, mesh_axes)
        else:
            def body(xt, params):
                return _moe_ep_tp(xt, params, m, self.ep_axes, dropless,
                                  mesh_axes, self.psum_axes)

        def fn(params, xb):
            bl, sl, d = xb.shape
            y, aux = body(xb.reshape(bl * sl, d), params)
            return y.reshape(bl, sl, d), aux

        routed = {"router": p["router"], "experts": p["experts"]}
        aux_spec = {k: P() for k in ("load_balance", "router_z", "dropped_frac")}
        y, aux = shard_map(
            fn,
            mesh=self.mesh,
            in_specs=(self.expert_spec_tree, self.token_spec),
            out_specs=(self.token_spec, aux_spec),
            check_rep=False,
        )(routed, x)
        return y, aux
