"""Model zoo: 10 assigned architectures from shared, dispatch-routed blocks."""

from repro.models.model import DecoderLM, EncDecLM, build_model, plan_segments

__all__ = ["DecoderLM", "EncDecLM", "build_model", "plan_segments"]
