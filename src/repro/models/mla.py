"""Multi-head Latent Attention (DeepSeek-V3).

KV is compressed to a per-token latent ``c_kv`` of kv_lora_rank floats plus a
shared rotary key of qk_rope_dim floats — the decode cache is 576 B/token
instead of 2·128·128 = 32 KiB/token.  Train/prefill expand the latents to full
keys/values and run flash attention (qk dim 192, v dim 128); decode uses the
**absorbed-weight** formulation (W_UK folded into the query, W_UV applied to
the attended latent), never materializing per-head keys.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import dispatch
from repro.dist.act import shard_act
from repro.models import layers
from repro.models.params import ParamSpec

Params = Any


def mla_specs(cfg: ArchConfig) -> Params:
    m = cfg.mla
    assert m is not None
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    s = 1.0 / np.sqrt(d)
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora"), scale=s),
        "q_norm": layers.norm_spec(m.q_lora_rank),
        "wq_b": ParamSpec((m.q_lora_rank, H * qk), ("q_lora", "heads"),
                          scale=1.0 / np.sqrt(m.q_lora_rank)),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", None),
                           scale=s),
        "kv_norm": layers.norm_spec(m.kv_lora_rank),
        "wkv_b": ParamSpec(
            (m.kv_lora_rank, H * (m.qk_nope_dim + m.v_head_dim)),
            ("q_lora", "heads"), scale=1.0 / np.sqrt(m.kv_lora_rank),
        ),
        "wo": ParamSpec((H * m.v_head_dim, d), ("heads", "embed"),
                        scale=1.0 / np.sqrt(H * m.v_head_dim)),
    }


def _queries(p: Params, x: jax.Array, cfg: ArchConfig):
    """x [B,S,d] -> q_nope [B,S,H,nope], q_rope [B,S,H,rope] (pre-rotation)."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    cq = layers.apply_norm(p["q_norm"], dispatch.op("matmul", x, p["wq_a"]),
                           cfg.norm_eps)
    q = dispatch.op("matmul", cq, p["wq_b"]).reshape(
        B, S, H, m.qk_nope_dim + m.qk_rope_dim
    )
    q = shard_act(q, "batch", None, "heads", None)
    return q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]


def _latents(p: Params, x: jax.Array, cfg: ArchConfig):
    """x -> (c_kv [B,S,r], k_rope [B,S,rope]) with c_kv normalized."""
    m = cfg.mla
    ckv = dispatch.op("matmul", x, p["wkv_a"])
    c_kv, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    return layers.apply_norm(p["kv_norm"], c_kv, cfg.norm_eps), k_rope


def mla_full(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Train/prefill. Returns (y, c_kv, k_rope[rotated]) for the cache."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope = _queries(p, x, cfg)
    c_kv, k_rope = _latents(p, x, cfg)

    cos, sin = layers.rope_table(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)   # [B,S,1,rope]

    kv = dispatch.op("matmul", c_kv, p["wkv_b"]).reshape(
        B, S, H, m.qk_nope_dim + m.v_head_dim
    )
    kv = shard_act(kv, "batch", None, "heads", None)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_dim))], axis=-1
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = dispatch.op(
        "flash_attention",
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=True,
        scale=1.0 / float(np.sqrt(m.qk_nope_dim + m.qk_rope_dim)),
    ).swapaxes(1, 2)
    y = dispatch.op("matmul", out.reshape(B, S, -1), p["wo"])
    return y, c_kv, k_rope[:, :, 0, :]


def mla_decode_attention(
    q_nope: jax.Array,                 # [B, H, nope]
    q_rope: jax.Array,                 # [B, H, rope] (rotated)
    c_kv: jax.Array,                   # [B, T, r] latent cache
    k_rope: jax.Array,                 # [B, T, rope] rotated shared keys
    w_uk: jax.Array,                   # [r, H, nope]
    w_uv: jax.Array,                   # [r, H, v]
    length: jax.Array,
    *,
    scale: float,
) -> jax.Array:
    """Absorbed MLA decode: O(T·r) per head-group, no key expansion.

    The latent cache is read in its storage dtype with f32 accumulation
    (upcasting it first doubled per-token cache traffic — §Perf iteration 2).
    """
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    logits = jnp.einsum("bhr,btr->bht", q_abs.astype(c_kv.dtype), c_kv,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bhp,btp->bht", q_rope.astype(k_rope.dtype), k_rope,
                         preferred_element_type=jnp.float32)
    logits *= scale
    T = c_kv.shape[1]
    length = jnp.asarray(length)
    if length.ndim == 1:                            # per-sequence lengths [B]
        length = length[:, None, None]
    valid = jnp.arange(T)[None, None, :] < length
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", probs.astype(c_kv.dtype), c_kv,
                     preferred_element_type=jnp.float32)
    return jnp.einsum("bhr,rhv->bhv", ctx.astype(w_uv.dtype), w_uv,
                      preferred_element_type=jnp.float32)


def mla_decode(
    p: Params,
    x: jax.Array,                      # [B, 1, d]
    cache_ckv: jax.Array,              # [B, T, r]
    cache_krope: jax.Array,            # [B, T, rope]
    pos: jax.Array,
    cfg: ArchConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    q_nope, q_rope = _queries(p, x, cfg)
    c_kv, k_rope = _latents(p, x, cfg)

    cos, sin = layers.rope_table(layers.decode_positions(pos), m.qk_rope_dim,
                                 cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)[:, 0]            # [B,H,rope]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, 0, 0]  # [B,rope]

    if pos.ndim == 0:
        cache_ckv = jax.lax.dynamic_update_slice(
            cache_ckv, c_kv.astype(cache_ckv.dtype), (0, pos, 0)
        )
        cache_krope = jax.lax.dynamic_update_slice(
            cache_krope, k_rope[:, None, :].astype(cache_krope.dtype), (0, pos, 0)
        )
    else:                                            # per-sequence positions
        idx = jnp.arange(B)
        cache_ckv = cache_ckv.at[idx, pos].set(c_kv[:, 0].astype(cache_ckv.dtype))
        cache_krope = cache_krope.at[idx, pos].set(k_rope.astype(cache_krope.dtype))

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_dim + m.v_head_dim)
    w_uk, w_uv = wkv_b[..., : m.qk_nope_dim], wkv_b[..., m.qk_nope_dim:]
    out = dispatch.op(
        "mla_decode_attention",
        q_nope[:, 0], q_rope, cache_ckv, cache_krope, w_uk, w_uv, pos + 1,
        scale=1.0 / float(np.sqrt(m.qk_nope_dim + m.qk_rope_dim)),
    )
    y = dispatch.op("matmul", out.reshape(B, -1), p["wo"])
    return y[:, None, :].astype(x.dtype), cache_ckv, cache_krope


# register the absorbed decode as a dispatchable op
from repro.core.registry import GLOBAL_REGISTRY, KernelImpl  # noqa: E402

for _src in ("reference", "xla"):
    GLOBAL_REGISTRY.register(
        KernelImpl(op="mla_decode_attention", device_kind="any", source=_src,
                   fn=mla_decode_attention),
        allow_override=True,
    )
