"""Parameter descriptor system.

Models declare their parameters as trees of :class:`ParamSpec` (shape + dtype +
*logical* sharding axes + init law).  From one descriptor tree we derive:

  - ``init_params``      → concrete arrays (smoke tests, examples),
  - ``abstract_params``  → ShapeDtypeStructs (dry-run: zero allocation),
  - ``pspec_tree``       → PartitionSpecs via the sharding rules (dist/).

This is what lets the 671B config lower on a CPU container: nothing is ever
materialized for the production shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]          # logical axis name per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                     # normal | zeros | ones | ssm_a | uniform
    scale: float = 0.02

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(
            spec.dtype
        )
    if spec.init == "ssm_a":                 # log of -a in (log 1, log 16): a in (-16,-1)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return -u.astype(spec.dtype)         # stored as a_log (negative)
    if spec.init == "uniform":
        return jax.random.uniform(
            key, spec.shape, jnp.float32, -spec.scale, spec.scale
        ).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(tree: Any, rng: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=is_spec
    )


def stack_specs(tree: Any, n: int, logical: str | None = None) -> Any:
    """Prepend a stacking (scan) dimension to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec(
            shape=(n, *s.shape),
            logical=(logical, *s.logical),
            dtype=s.dtype,
            init=s.init,
            scale=s.scale,
        ),
        tree,
        is_leaf=is_spec,
    )


def count_params(tree: Any) -> int:
    return sum(
        int(np.prod(s.shape)) for s in jax.tree.leaves(tree, is_leaf=is_spec)
    )


def bytes_params(tree: Any) -> int:
    return sum(
        int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(tree, is_leaf=is_spec)
    )
