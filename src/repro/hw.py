"""Target-hardware constants (TPU v5e) — single source of truth.

Used by the roofline analysis, the agent descriptors, and kernel BlockSpec
sizing.  This container executes on CPU; these constants describe the TARGET.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float        # FLOP/s per chip
    peak_int8_ops: float          # OP/s per chip
    hbm_bytes: int                # capacity
    hbm_bw: float                 # bytes/s
    vmem_bytes: int               # on-chip vector memory
    ici_bw_per_link: float        # bytes/s per ICI link
    ici_links: int                # links per chip (2D torus -> 4)
    mxu_dim: int = 128            # systolic array edge
    clock_hz: float = 0.94e9      # derived: 197e12 / (8 * 128*128*2) ~ 0.94 GHz equiv

    @property
    def flops_per_cycle(self) -> float:
        return self.peak_bf16_flops / self.clock_hz


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    peak_int8_ops=394e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    ici_bw_per_link=50e9,
    ici_links=4,
)

# The evaluation host of the paper (Ultra96: ARM Cortex-A53) — kept only for
# benchmark narration; OP/cycle comparisons in benchmarks/table3 are measured
# on this container's host CPU instead.
DEFAULT_CHIP = TPU_V5E
