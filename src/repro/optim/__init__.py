from repro.optim.adamw import OptConfig, opt_init, opt_state_specs, opt_update, lr_schedule
