"""Optimizers: AdamW and Adafactor, pure-JAX, sharding-aware.

State layout mirrors the parameter tree leaf-for-leaf, so the parameter
PartitionSpecs apply verbatim to optimizer state (ZeRO for free: FSDP-sharded
params → FSDP-sharded moments).  Adafactor factors the second moment of rank-2
(+) tensors into row/col statistics — the memory trade that lets 671B-param
training fit 16 GB/chip at 256 chips (see DESIGN §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"                # "adamw" | "adafactor"
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    accum_dtype: str = "float32"       # microbatch grad accumulator precision
    # adafactor
    factored_min_dim: int = 128
    decay_adafactor: float = 0.99      # b1=0.0 -> classic momentum-free Adafactor


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.decay_steps - cfg.warmup_steps), 0, 1
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 *
                    (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Params, max_norm: float) -> tuple[Params, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, params: Params, grads: Params, state: dict):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, momentum in bf16)
# ---------------------------------------------------------------------------


def _factored(shape: tuple[int, ...], min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(cfg: OptConfig, params: Params) -> dict:
    def init_v(p):
        if _factored(p.shape, cfg.factored_min_dim):
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"full": jnp.zeros(p.shape, jnp.float32)}

    if cfg.b1 > 0:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    else:   # classic momentum-free Adafactor: the >300B memory budget choice
        m = jax.tree.map(lambda p: jnp.zeros((), jnp.bfloat16), params)
    return {
        "m": m,
        "v": jax.tree.map(init_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, params: Params, grads: Params, state: dict):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    beta = cfg.decay_adafactor

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if "full" in v:
            vf = beta * v["full"] + (1 - beta) * (g * g + 1e-30)
            precond = g * jax.lax.rsqrt(vf + 1e-30)
            new_v = {"full": vf}
        else:
            row = beta * v["row"] + (1 - beta) * jnp.mean(g * g + 1e-30, axis=-1)
            col = beta * v["col"] + (1 - beta) * jnp.mean(g * g + 1e-30, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            r = (row / (row_mean + 1e-30))[..., None]
            c = col[..., None, :]
            precond = g * jax.lax.rsqrt(r * c + 1e-30)
            new_v = {"row": row, "col": col}
        # update clipping (Adafactor's RMS trick)
        rms = jnp.sqrt(jnp.mean(precond * precond) + 1e-30)
        precond = precond / jnp.maximum(1.0, rms)
        if cfg.b1 > 0:
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * precond
            new_m = mf.astype(jnp.bfloat16)
        else:
            mf = precond
            new_m = m                       # dummy scalar, untouched
        delta = mf + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_m, new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([r[0] for r in res])
    new_m = tdef.unflatten([r[1] for r in res])
    new_v = tdef.unflatten([r[2] for r in res])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }


# ---------------------------------------------------------------------------
# unified interface
# ---------------------------------------------------------------------------


def opt_init(cfg: OptConfig, params: Params) -> dict:
    if cfg.kind == "adamw":
        return adamw_init(params)
    if cfg.kind == "adafactor":
        return adafactor_init(cfg, params)
    raise ValueError(cfg.kind)


def opt_update(cfg: OptConfig, params: Params, grads: Params, state: dict):
    if cfg.kind == "adamw":
        return adamw_update(cfg, params, grads, state)
    return adafactor_update(cfg, params, grads, state)


def opt_state_specs(cfg: OptConfig, param_specs: Any, pspec_of) -> Any:
    """PartitionSpec tree for the optimizer state, mirroring the params.

    ``pspec_of`` maps a ParamSpec leaf to its PartitionSpec; factored Adafactor
    stats inherit the spec with the reduced axis dropped.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.params import is_spec

    def for_leaf(s):
        ps = pspec_of(s)
        m_spec = ps if (cfg.kind == "adamw" or cfg.b1 > 0) else P()
        if cfg.kind == "adamw":
            return {"m": ps, "v": ps}
        if _factored(s.shape, cfg.factored_min_dim):
            return {
                "m": m_spec,
                "v": {"row": P(*ps[:-1]), "col": P(*(list(ps[:-2]) + [ps[-1]]))},
            }
        return {"m": m_spec, "v": {"full": ps}}

    tree = jax.tree.map(for_leaf, param_specs, is_leaf=is_spec)
    m = jax.tree.map(lambda t: t["m"], tree, is_leaf=lambda x: isinstance(x, dict) and "m" in x)
    v = jax.tree.map(lambda t: t["v"], tree, is_leaf=lambda x: isinstance(x, dict) and "m" in x)
    return {"m": m, "v": v, "step": P()}
