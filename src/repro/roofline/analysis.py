"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), all in seconds-per-step at the TPU v5e
target:

  compute    = FLOPs_per_device / peak_bf16_FLOP/s
  memory     = bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_link_bw

``cost_analysis()`` is per-device (the partitioned module), so dividing by
per-chip peaks directly gives the per-step time bound; multiplying numerator
and denominator by `chips` recovers the brief's global formulation exactly.

collective_bytes is not in cost_analysis: we parse the compiled HLO and sum
the **operand** bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from typing import Any

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.hw import TPU_V5E

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  "bf16[16,1024,128]{2,1,0}"  — capture dtype + dims
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# "%x = <shape(s)> all-reduce(%a, %b), ..." — LHS shape(s), op name
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)

# "replica_groups=[128,2]<=..."  (iota form: G groups × M members)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# "replica_groups={{0,16,32},{...}}" (explicit form: count first group)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 2


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, Any]:
    """Per-device link traffic of every collective, ring-cost model.

    Uses the instruction's **output** shape B and replica-group size M:

      all-gather          B·(M−1)/M      (receive all shards but your own)
      reduce-scatter      B·(M−1)        (input is M·B; send (M−1)/M of it)
      all-reduce          2·B·(M−1)/M    (ring = reduce-scatter + all-gather)
      all-to-all          B·(M−1)/M
      collective-permute  B

    ``-done`` halves of async pairs are skipped.
    """
    by_kind: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if m.group(3) == "-start":
            b = b // 2                       # start tuples carry (in, out)
        msize = _group_size(line)
        if kind == "all-gather":
            traffic = b * (msize - 1) / msize
        elif kind == "reduce-scatter":
            traffic = b * (msize - 1)
        elif kind == "all-reduce":
            traffic = 2 * b * (msize - 1) / msize
        elif kind == "all-to-all":
            traffic = b * (msize - 1) / msize
        else:                                # collective-permute
            traffic = b
        by_kind[kind] += traffic
        counts[kind] += 1
    total = sum(by_kind.values())
    return {
        "total": float(total),
        "by_kind": {k: float(v) for k, v in by_kind.items() if v},
        "counts": {k: v for k, v in counts.items() if v},
    }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D; D = tokens this step."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0                      # forward only
    else:
        tokens = shape.global_batch     # one token per sequence
        mult = 2.0
    n = cfg.active_params()
    return mult * n * tokens


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, cfg: ArchConfig,
                   shape: ShapeConfig, chips: int) -> dict:
    chip = TPU_V5E
    compute_s = flops_per_device / chip.peak_bf16_flops
    memory_s = bytes_per_device / chip.hbm_bw
    collective_s = collective_bytes_per_device / chip.ici_bw_per_link
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_per_device * chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (mf / hlo_flops_global) if hlo_flops_global else 0.0,
        "step_bound_s": max(terms.values()),
        "roofline_fraction": (
            compute_s / max(terms.values()) if max(terms.values()) > 0 else 0.0
        ),
    }
