"""Cost-mode unrolling flag.

XLA's ``cost_analysis`` counts while-loop bodies **once** regardless of trip
count, so any ``lax.scan``/``lax.map`` in the model hides work from the
roofline.  During cost-extrapolation lowering this context makes the inner
loops (attention q-block map, chunked-CE scan) unroll into straight-line HLO
so every FLOP is counted.  Never enabled for the real compile-proof artifacts.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Iterator

_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_unroll_inner", default=False
)


@contextlib.contextmanager
def unroll_inner_loops() -> Iterator[None]:
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def inner_loops_unrolled() -> bool:
    return _UNROLL.get()
