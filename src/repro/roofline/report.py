"""Render EXPERIMENTS.md tables from dry-run result JSONs."""

from __future__ import annotations

import json
from typing import Any


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def _fmt_ms(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s*1e3:.1f}ms"


def dryrun_table(results: list[dict[str, Any]]) -> str:
    rows = ["| arch | shape | mesh | status | compile | args GiB/dev | temp GiB/dev | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            m = r["memory"]
            colls = r.get("collectives", {})
            cstr = " ".join(f"{k.split('-')[1] if '-' in k else k}:{v/2**30:.1f}G"
                            for k, v in sorted(colls.items())) or "-"
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"| {r['compile_s']:.0f}s | {_fmt_bytes(m['argument_bytes'])} "
                f"| {_fmt_bytes(m['temp_bytes'])} | {cstr} |"
            )
        elif r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP "
                        f"| - | - | - | {r['reason'][:60]} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL "
                        f"| - | - | - | {r.get('error','')[:60]} |")
    return "\n".join(rows)


def roofline_table(results: list[dict[str, Any]]) -> str:
    rows = ["| arch | shape | compute | memory | collective | bottleneck "
            "| roofline frac | useful FLOPs |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(rf['compute_s'])} "
            f"| {_fmt_ms(rf['memory_s'])} | {_fmt_ms(rf['collective_s'])} "
            f"| {rf['bottleneck']} | {rf['roofline_fraction']:.3f} "
            f"| {rf['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(rows)


def summarize(path_single: str, path_multi: str | None = None) -> str:
    results = json.load(open(path_single))
    if path_multi:
        results += json.load(open(path_multi))
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    fail = sum(1 for r in results if r["status"] == "fail")
    out = [f"Cells: {ok} ok, {skip} skip (documented), {fail} fail.",
           "", "### Dry-run table", "", dryrun_table(results),
           "", "### Roofline (single-pod)", "", roofline_table(results)]
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    print(summarize(*sys.argv[1:]))
