"""Per-layer cost extrapolation.

``cost_analysis`` on a scanned model counts each scan body once.  We recover
true costs by lowering small **unrolled** variants with varied segment counts
and solving the affine system

    measured_j = outside + Σ_i counts_{ji} · segment_i

then evaluating at the real segment counts.  Variants: all-ones baseline plus
one count incremented per segment (k+1 lowers for k segment types; k ≤ 2 for
every assigned arch).  Inner loops (attention block-map, chunked CE) unroll
under the same context so their FLOPs are counted too.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import ShardingRules
from repro.launch.specs import abstract_opt_state, batch_specs, decode_specs, pick_opt
from repro.models import build_model
from repro.models.model import Segment, plan_segments
from repro.models.params import abstract_params
from repro.roofline.analysis import collective_bytes_from_hlo
from repro.roofline.unrolling import unroll_inner_loops

METRICS = ("flops", "bytes", "coll")


def _measure(cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules,
             plan: list[Segment] | None, enc_dec_counts=None) -> dict[str, float]:
    """Lower+compile one unrolled variant; return per-device cost metrics."""
    vcfg = cfg
    if enc_dec_counts is not None:
        vcfg = dataclasses.replace(cfg, encoder_layers=enc_dec_counts[0],
                                   num_layers=enc_dec_counts[1])
    model = build_model(vcfg, plan=plan, unroll=True)
    p_abs = abstract_params(model.param_specs())

    with jax.set_mesh(rules.mesh), unroll_inner_loops():
        if shape.kind == "train":
            from repro.train.step import make_train_step

            opt_cfg = pick_opt(cfg)
            step, *_ = make_train_step(model, opt_cfg, rules,
                                       global_batch=shape.global_batch,
                                       donate=False)
            o_abs = abstract_opt_state(opt_cfg, p_abs)
            lowered = step.lower(p_abs, o_abs, batch_specs(vcfg, shape))
        elif shape.kind == "prefill":
            from repro.serve.engine import make_prefill_step

            step, *_ = make_prefill_step(model, rules,
                                         global_batch=shape.global_batch)
            lowered = step.lower(p_abs, batch_specs(vcfg, shape))
        else:
            from repro.serve.engine import make_decode_step

            step, *_ = make_decode_step(model, rules,
                                        global_batch=shape.global_batch,
                                        cache_len=shape.seq_len,
                                        donate_cache=False)
            tokens, cache = decode_specs(vcfg, shape, model)
            lowered = step.lower(p_abs, tokens, cache)
        compiled = lowered.compile()

    ca = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll["total"],
    }


def extrapolated_costs(cfg: ArchConfig, shape: ShapeConfig,
                       rules: ShardingRules) -> dict[str, Any]:
    """True per-device (flops, bytes, collective_bytes) for the full depth."""
    if cfg.family == "audio":
        # two stacks: encoder, decoder — vary each
        variants = [(1, 1), (2, 1), (1, 2)]
        true_counts = np.array([1.0, cfg.encoder_layers, cfg.num_layers])
        rows = []
        meas = []
        for enc, dec in variants:
            rows.append([1.0, enc, dec])
            meas.append(_measure(cfg, shape, rules, None, (enc, dec)))
    else:
        plan = plan_segments(cfg)
        k = len(plan)
        count_vecs = [[1] * k]
        for i in range(k):
            v = [1] * k
            v[i] = 2
            count_vecs.append(v)
        true_counts = np.array([1.0] + [float(s.count) for s in plan])
        rows, meas = [], []
        for counts in count_vecs:
            vplan = [Segment(s.kinds, c) for s, c in zip(plan, counts)]
            rows.append([1.0] + [float(c) for c in counts])
            meas.append(_measure(cfg, shape, rules, vplan))

    A = np.array(rows)
    out: dict[str, Any] = {"variants": len(rows)}
    for key in METRICS:
        b = np.array([m[key] for m in meas])
        x, *_ = np.linalg.lstsq(A, b, rcond=None)
        x = np.maximum(x, 0.0)                 # clamp solver noise
        out[key] = float(true_counts @ x)
        out[f"{key}_outside"] = float(x[0])
        out[f"{key}_per_segment"] = [float(v) for v in x[1:]]
    return out
