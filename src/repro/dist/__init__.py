"""Distribution layer: sharding rules, activation constraints, collectives."""

from repro.dist import act
from repro.dist.sharding import ShardingRules

__all__ = ["act", "ShardingRules"]
