"""Logical-axis sharding rules.

Models annotate parameters and activations with *logical* axis names
("batch", "heads", "mlp", "expert", ...).  :class:`ShardingRules` owns the
single mapping from those names to physical mesh axes and derives every
PartitionSpec in the system from it:

  - ``pspec(spec)``        → parameter PartitionSpec (via ParamSpec.logical),
  - ``sharding_tree(tree)``→ NamedSharding tree for a ParamSpec tree,
  - ``act_pspec(...)``     → activation constraint specs (dist.act.shard_act),
  - ``batch_pspec(...)``   → data-parallel batch specs for inputs/logits.

Divisibility is checked per-dimension: an axis that does not evenly divide a
dimension is dropped (replicated) rather than erroring, so reduced smoke
configs and production configs share one rule set.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _axes_size(mesh: Any, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping of logical axis names to physical mesh axes."""

    mesh: Any
    logical_to_physical: Mapping[str, tuple[str, ...]]
    serving: bool = False

    # -- construction ---------------------------------------------------------

    @classmethod
    def for_arch(cls, cfg: Any, mesh: Any, *, serving: bool = False) -> "ShardingRules":
        """Standard layout: batch over (pod, data), tensor axes over model.

        MoE expert placement: training with experts_per_token >= 4 selects the
        EP-all layout (experts over data x model, tokens all_to_all'd); smaller
        top-k keeps experts on the model axis and replicates tokens (TP mode).
        Serving prefers expert-FFN sharding over data when the expert count
        cannot cover the full mesh.
        """
        axes = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in axes)
        model = ("model",) if "model" in axes else ()

        ep: tuple[str, ...] = model
        ff: tuple[str, ...] = ()
        moe = getattr(cfg, "moe", None)
        if moe is not None:
            full = tuple(a for a in ("data", "model") if a in axes)
            if not serving and moe.experts_per_token >= 4 and len(full) > 1:
                ep = full                                  # EP-all layout
            elif serving and model:
                total = _axes_size(mesh, full)
                if moe.num_experts % max(total, 1) != 0 and "data" in axes:
                    # can't cover the mesh with experts: E over model, f over data
                    ff = ("data",)

        l2p: dict[str, tuple[str, ...]] = {
            "batch": dp,
            "embed": (),
            "layers": (),
            "vocab": model,
            "heads": model,
            "kv_heads": model,
            "mlp": model,
            "ssm_inner": model,
            "ssm_heads": model,
            "q_lora": (),
            "kv_lora": (),
            "expert": ep,
            "expert_embed": (),
            "expert_ff": ff,
        }
        return cls(mesh=mesh, logical_to_physical=l2p, serving=serving)

    # -- core mapping ---------------------------------------------------------

    def ep_axes(self) -> tuple[str, ...]:
        return tuple(self.logical_to_physical.get("expert", ()))

    def _entries(
        self, shape: Sequence[int], logical: Sequence[str | None]
    ) -> list[Any]:
        """Per-dim physical entries with divisibility + duplicate-axis checks."""
        used: set[str] = set()
        entries: list[Any] = []
        for dim, name in zip(shape, logical):
            axes = tuple(self.logical_to_physical.get(name, ())) if name else ()
            axes = tuple(a for a in axes if a in self.mesh.axis_names and a not in used)
            size = _axes_size(self.mesh, axes)
            if not axes or size <= 1 or dim % size != 0:
                entries.append(None)
                continue
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        return entries

    def pspec(self, spec: Any) -> P:
        """ParamSpec -> PartitionSpec (the ``pspec_of`` hook of the optimizer)."""
        return P(*self._entries(spec.shape, spec.logical))

    def act_pspec(self, shape: Sequence[int], logical: Sequence[str | None]) -> list[Any]:
        return self._entries(shape, logical)

    def sharding_tree(self, spec_tree: Any) -> Any:
        import jax

        from repro.models.params import is_spec

        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, self.pspec(s)),
            spec_tree,
            is_leaf=is_spec,
        )

    def batch_pspec(self, global_batch: int, extra_dims: int) -> P:
        """P(dp_entry, None * extra_dims); dp dropped when batch not divisible."""
        dp = tuple(self.logical_to_physical.get("batch", ()))
        entry: Any = None
        if dp and global_batch % _axes_size(self.mesh, dp) == 0:
            entry = dp if len(dp) > 1 else dp[0]
        return P(entry, *([None] * extra_dims))
