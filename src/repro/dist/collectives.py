"""Compressed collectives: block-wise int8 quantization for gradient traffic.

``compressed_psum`` is the shard_map building block: quantize the local
shard, mean-reduce the dequantized payload, and return the quantization
residual so callers can apply error feedback (the residual is carried into
the next step's gradients, keeping the *accumulated* update unbiased).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jax.Array, block: int = 64):
    """Block-wise symmetric int8 quantization.

    Returns ``(q, scale, shape)``: int8 blocks [nb, block], per-block f32
    scales [nb, 1], and the original shape for :func:`dequantize_int8`.
    Per-element error is bounded by scale/2 = max|x_block| / 254.
    """
    x = jnp.asarray(x)
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, shape


def dequantize_int8(q: jax.Array, scale: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    n = int(np.prod(shape)) if shape else 1
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:n].reshape(shape)


def compression_ratio(shape: Sequence[int], block: int = 64) -> float:
    """f32 bytes vs (int8 payload + f32 per-block scales)."""
    n = int(np.prod(list(shape)))
    nb = -(-n // block)
    return (n * 4) / (n * 1 + nb * 4)


def compressed_psum(x: jax.Array, axes: Sequence[str], *, block: int = 64):
    """Mean-reduce ``x`` over mesh ``axes`` through the int8 wire format.

    Returns ``(mean, residual)`` where residual = x - dequant(quant(x)) is the
    local error-feedback term.  Must run inside shard_map/jit with the axes
    bound.
    """
    q, scale, shape = quantize_int8(x, block)
    sent = dequantize_int8(q, scale, shape).astype(jnp.float32)
    y = jax.lax.pmean(sent, tuple(axes) if len(tuple(axes)) > 1 else tuple(axes)[0])
    return y.astype(x.dtype), (x.astype(jnp.float32) - sent).astype(x.dtype)
