"""Activation sharding constraints.

``shard_act(x, *logical)`` applies ``with_sharding_constraint`` using the
active :class:`~repro.dist.sharding.ShardingRules` (scoped via ``use_rules``).
Outside any rules scope — single-device tests, examples — it is an exact
no-op, so model code carries its production sharding annotations everywhere
without penalizing small-scale runs.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Iterator

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[Any] = contextvars.ContextVar(
    "repro_act_rules", default=None
)


def current() -> Any:
    return _RULES.get()


@contextlib.contextmanager
def use_rules(rules: Any) -> Iterator[Any]:
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def shard_act(x: jax.Array, *logical: str | None) -> jax.Array:
    rules = _RULES.get()
    if rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} logical axes for rank-{x.ndim} array")
    entries = rules.act_pspec(x.shape, logical)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*entries))
    )
