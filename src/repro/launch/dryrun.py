import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 TPU pods; ``jax.jit(step).lower(...).compile()``
must succeed with the production shardings, and the compiled artifact yields
``memory_analysis()`` (fits?) + ``cost_analysis()`` (FLOPs/bytes) +
collective traffic (parsed from HLO) for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, cell_supported, get_arch, get_shape
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_opt_state, batch_specs, decode_specs, pick_opt
from repro.models import build_model
from repro.models.params import abstract_params
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               verbose: bool = True, with_cost: bool = True) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    # inference cells deploy with serving rules: TP-resident weights, no FSDP
    rules = ShardingRules.for_arch(cfg, mesh, serving=shape.kind != "train")
    model = build_model(cfg)
    p_abs = abstract_params(model.param_specs())

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            from repro.train.step import auto_microbatches, make_train_step

            opt_cfg = pick_opt(cfg)
            mb = auto_microbatches(shape.global_batch, shape.seq_len, rules,
                                   cfg=cfg)
            step, p_sh, o_sh, b_sh = make_train_step(
                model, opt_cfg, rules, global_batch=shape.global_batch,
                microbatches=mb, donate=True,
            )
            o_abs = abstract_opt_state(opt_cfg, p_abs)
            lowered = step.lower(p_abs, o_abs, batch_specs(cfg, shape))
        elif shape.kind == "prefill":
            from repro.serve.engine import make_prefill_step

            step, p_sh, b_sh = make_prefill_step(
                model, rules, global_batch=shape.global_batch,
            )
            lowered = step.lower(p_abs, batch_specs(cfg, shape))
        else:  # decode
            from repro.serve.engine import make_decode_step

            step, p_sh, c_sh, cache_tree = make_decode_step(
                model, rules, global_batch=shape.global_batch,
                cache_len=shape.seq_len,
            )
            tokens, cache = decode_specs(cfg, shape, model)
            lowered = step.lower(p_abs, tokens, cache)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    # scan bodies are cost-counted once; recover true per-step costs by
    # extrapolating from small unrolled variants (single-pod roofline only)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    coll_dev = coll["total"]
    extrap = None
    if with_cost and not multi_pod:
        from repro.roofline.extrapolate import extrapolated_costs

        extrap = extrapolated_costs(cfg, shape, rules)
        flops_dev, bytes_dev, coll_dev = extrap["flops"], extrap["bytes"], extrap["coll"]

    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "scan_measured": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total"],
        },
        "extrapolation": extrap,
        "collectives": coll["by_kind"],
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "roofline": roofline_terms(
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            collective_bytes_per_device=coll_dev,
            cfg=cfg,
            shape=shape,
            chips=n_chips,
        ),
    }
    if verbose:
        r = record["roofline"]
        print(f"[dryrun] {arch_name} × {shape_name} × {record['mesh']}: "
              f"compile {t_compile:.0f}s, "
              f"compute {r['compute_s']*1e3:.2f}ms mem {r['memory_s']*1e3:.2f}ms "
              f"coll {r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}"
              f" (args {ma.argument_size_in_bytes/2**30:.2f} GiB/dev)")
        print(f"[dryrun] memory_analysis: {ma}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    results = []
    out_path = args.out
    if out_path and os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    failures = 0
    for arch, shape, mp in cells:
        key = (arch, shape, "2x16x16" if mp else "16x16")
        if key in done:
            continue
        try:
            rec = lower_cell(arch, shape, multi_pod=mp)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": key[2], "status": "fail", "error": str(e)[-2000:]}
            failures += 1
        results.append(rec)
        if out_path:
            tmp = out_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(results, f, indent=1)
            os.replace(tmp, out_path)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skip")
    print(f"[dryrun] ok={n_ok} skip={n_skip} fail={failures}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
