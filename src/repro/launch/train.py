"""Training launcher.

Production entry point: picks the mesh (or a reduced one for local runs),
builds the model + sharded train step, runs the fault-tolerant loop.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
      --steps 100 --global-batch 8 --seq 256 --reduced

``--reduced`` swaps in the smoke-scale config of the same family so the
launcher is exercisable on one CPU; on a pod, omit it and pass
``--mesh 16x16``/``--mesh 2x16x16``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch, reduced
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import pick_opt
from repro.models import build_model
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import (
    auto_microbatches,
    init_train_state,
    make_train_step,
)


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        return make_mesh(dims, ("pod", "data", "model"))
    return make_mesh(dims, ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family (CPU runs)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (activation-budget heuristic)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = parse_mesh(args.mesh)
    rules = ShardingRules.for_arch(cfg, mesh)
    model = build_model(cfg)
    opt = dataclasses.replace(pick_opt(cfg), lr=args.lr,
                              decay_steps=max(args.steps, 10))
    mb = args.microbatches or auto_microbatches(
        args.global_batch, args.seq, rules, cfg=cfg
    )
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch,
    ))

    with jax.set_mesh(mesh):
        step, *_ = make_train_step(model, opt, rules,
                                   global_batch=args.global_batch,
                                   microbatches=mb)
        params, opt_state = init_train_state(model, opt, rules,
                                             jax.random.key(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n/1e6:.1f}M params, mesh={args.mesh}, "
              f"microbatches={mb}, opt={opt.kind}")

        def batch_at(s: int):
            return {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}

        loop = TrainLoop(step, batch_at, LoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, log_every=10,
        ))
        _, _, report = loop.run(params, opt_state)
        print(f"[train] done: {report.steps_run} steps, "
              f"loss={report.last_metrics.get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
