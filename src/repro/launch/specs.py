"""Abstract input specs for every (arch × shape) cell.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, zero device
allocation.  ``step_kind`` decides which program the cell lowers:
train_* → train_step, prefill_* → prefill_step, decode_*/long_* → decode_step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.layers import COMPUTE_DTYPE


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Inputs for train/prefill programs."""
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), COMPUTE_DTYPE
        )
    if cfg.frontend == "audio_frames":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), COMPUTE_DTYPE
        )
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig, model) -> tuple[dict, dict]:
    """(tokens, cache) for decode programs: 1 new token, seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = model.cache_specs(B, S)
    return tokens, cache


def abstract_opt_state(opt_cfg, abstract_params):
    from repro.optim.adamw import opt_init

    return jax.eval_shape(lambda p: opt_init(opt_cfg, p), abstract_params)


def pick_opt(cfg: ArchConfig):
    """Optimizer memory ladder for a 16 GB/chip budget:

    <20B: AdamW (f32 moments).  20–300B: Adafactor (bf16 momentum, factored
    second moment).  >300B: classic momentum-free Adafactor + bf16 microbatch
    gradient accumulation — the DeepSeek-scale configuration.
    """
    from repro.optim.adamw import OptConfig

    total = cfg.total_params()
    if total > 300e9:
        return OptConfig(kind="adafactor", b1=0.0, accum_dtype="bfloat16")
    if total > 20e9:
        return OptConfig(kind="adafactor")
    return OptConfig(kind="adamw")
