"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, small-scale examples)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
