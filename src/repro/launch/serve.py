"""Serving launcher: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import ARCHS, get_arch, reduced
from repro.models import build_model
from repro.models.params import init_params
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_len=args.max_len, temperature=args.temperature)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(2, 10))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        engine.submit(prompt, max_new_tokens=args.max_new)

    done = engine.run_to_completion()
    for req in sorted(done, key=lambda r: r.uid):
        print(f"req {req.uid}: prompt[{len(req.prompt)}] -> {req.generated}")
    print(f"[serve] completed {len(done)}/{args.requests} requests")


if __name__ == "__main__":
    main()
