"""repro — Transparent accelerator dispatch for JAX at multi-pod scale.

A production-grade reproduction and TPU-native extension of
"Transparent FPGA Acceleration with TensorFlow" (Pfenning, Holzinger,
Reichenbach; 2021).
"""

__version__ = "1.0.0"
