"""repro — Transparent accelerator dispatch for JAX at multi-pod scale.

A production-grade reproduction and TPU-native extension of
"Transparent FPGA Acceleration with TensorFlow" (Pfenning, Holzinger,
Reichenbach; 2021).
"""

from repro import _compat  # noqa: F401  (jax forward-compat aliases)

__version__ = "1.0.0"
