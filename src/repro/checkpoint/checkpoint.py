"""Checkpointing: atomic, sharded, elastic-restorable.

Fault-tolerance contract (the 1000-node posture):

  - **Atomicity**: writes go to ``step_N.tmp/`` and are renamed into place
    only after every array + the manifest are fsynced — a preempted writer
    never corrupts the latest checkpoint.
  - **Self-describing**: the manifest records step, mesh shape, and the flat
    key → file mapping.
  - **Elastic restore**: arrays are stored logically (full tensors, one .npy
    per leaf).  On restore they are ``device_put`` against the *live* mesh's
    shardings — a job restarted at a different chip count reshards
    transparently (checkpoint layout is decoupled from device layout).
    At real scale the .npy store is swapped for a tensorstore/OCDBT driver
    with per-shard writes; the manifest/atomicity/restore logic is unchanged.
  - **Retention**: keep the newest ``keep`` checkpoints, delete older ones
    only after a newer one is durable.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _keys(tree: Any) -> list[str]:
    paths = jax.tree_util.tree_leaves_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in paths]


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    mesh_shape: tuple[int, ...] = (),
    keep: int = 3,
    extra_meta: dict | None = None,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    keys = _keys(tree)
    entries = []
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"key": key, "file": fname, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "time": time.time(),
        "mesh_shape": list(mesh_shape),
        "entries": entries,
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish

    # retention
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old), ignore_errors=True)
    return final


def latest_checkpoint(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for cand in reversed(ckpts):                  # newest valid wins
        path = os.path.join(ckpt_dir, cand)
        if os.path.exists(os.path.join(path, MANIFEST)):
            return path
    return None


def restore_checkpoint(
    path: str,
    tree_like: Any,
    *,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of NamedShardings — arrays are
    device_put to the live mesh (elastic resharding happens here).
    """
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(tree_like)
    if len(leaves) != len(manifest["entries"]):
        raise ValueError(
            f"checkpoint has {len(manifest['entries'])} leaves, "
            f"model expects {len(leaves)}"
        )
    shard_leaves = (
        _flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    )
    out = []
    for entry, like, shard in zip(manifest["entries"], leaves, shard_leaves):
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{entry['key']}: shape {arr.shape} != {like.shape}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr).astype(like.dtype))
    return treedef.unflatten(out), manifest
