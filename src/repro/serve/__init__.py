from repro.serve.engine import (
    Request,
    ServeEngine,
    ServeTruncated,
    make_decode_step,
    make_prefill_step,
)
