"""Serving: cache sharding, jitted prefill/decode steps, batched engine.

``decode_step`` is the program the decode_* dry-run shapes lower: one new
token against a seq_len KV cache, fully sharded (batch over DP, heads/state
over TP).  The :class:`ServeEngine` implements continuous-batching-lite over
fixed slots — requests join free slots, finished slots are recycled — and can
route its launches through the HSA queue so serving shares the accelerator
with other producers (the paper's multi-tenancy story).

**Fused multi-token decode** (``decode_fusion=K``): one launch runs a jitted
``lax.scan`` of K decode steps with on-device sampling, so the per-launch
packet round trip (submit -> doorbell -> grant -> completion wait — Table
II's invocation row) is paid once per K tokens instead of per token.
Sampling is position-indexed per request (``fold_in(fold_in(seed_key, uid),
token_index)``), so token streams are bitwise-identical across fusion depths
— a finished slot is masked out mid-scan, never resampled, and host-side
splicing takes exactly each request's remaining budget.
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import ledger as ledger_mod
from repro.core.hsa.clock import WallClock
from repro.core.hsa.faults import CorruptPayload, FaultError, SilentCorruption
from repro.core.policy import (
    RESUME_REPREFILL,
    RESUME_SNAPSHOT,
    AdmissionPolicy,
    ChunkPolicy,
    FusionPolicy,
    IntegrityPolicy,
    PreemptionCandidate,
    PreemptionPolicy,
    PrefixPolicy,
    RetryPolicy,
    SpillCandidate,
    SpillPolicy,
)
from repro.core.reconfig import TransferEngine
from repro.dist import act
from repro.dist.sharding import ShardingRules
from repro.serve import paged as paged_mod
from repro.train.step import batch_shardings, moe_mesh_info


# ---------------------------------------------------------------------------
# cache sharding
# ---------------------------------------------------------------------------


def cache_pspecs(cfg: ArchConfig, rules: ShardingRules, cache_tree: Any,
                 global_batch: int) -> Any:
    """PartitionSpec per cache leaf, by name + divisibility.

    Layout [L, B, H, T, hd] (kv), [L, B, T, r] (latent), [L, B, H, P, N]
    (ssm), [L, B, K-1, C] (conv).  B shards over DP when divisible.  For the
    TP axis, the **first** non-batch dim divisible by the model-axis size is
    sharded: heads when they divide, otherwise the cache time axis
    (sequence-parallel KV — a kv=8 GQA cache at TP=16 must shard over T or a
    32k cache replicates 16× and decode stops fitting).  Softmax statistics
    over a T-sharded cache reduce with small [B, H] collectives — the standard
    trade.
    """
    import jax.tree_util as jtu

    mesh = rules.mesh
    model_size = mesh.shape.get("model", 1)
    dp_spec = rules.batch_pspec(global_batch, 0)[0]   # axis entry or None

    def tp_first_divisible(shape, start: int) -> list:
        parts: list = [None] * len(shape)
        if model_size <= 1:
            return parts
        for i in range(start, len(shape)):
            if shape[i] % model_size == 0 and shape[i] >= model_size:
                parts[i] = "model"
                break
        return parts

    def spec_for(path, leaf) -> P:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key == "pos":
            return P()
        shape = leaf.shape
        if key in ("k", "v", "mem_k", "mem_v", "ssm_state", "ckv", "krope",
                   "conv_tail"):
            parts = tp_first_divisible(shape, 2)
            parts[0] = None                       # layer-stack dim
            parts[1] = dp_spec                    # batch dim
            return P(*parts)
        return P(*([None] * len(shape)))

    return jtu.tree_map_with_path(spec_for, cache_tree)


def cache_shardings(cfg, rules, cache_tree, global_batch):
    pspecs = cache_pspecs(cfg, rules, cache_tree, global_batch)
    return jax.tree.map(lambda ps: NamedSharding(rules.mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# jitted steps
# ---------------------------------------------------------------------------


def make_prefill_step(model, rules: ShardingRules, *, global_batch: int,
                      cache_len: int | None = None):
    cfg = model.cfg
    mesh = rules.mesh
    p_shard = rules.sharding_tree(model.param_specs())
    b_shard = batch_shardings(cfg, rules, global_batch)
    minfo = moe_mesh_info(cfg, rules)

    def prefill(params, batch):
        with act.use_rules(rules):
            return model.prefill(params, batch, moe_info=minfo, cache_len=cache_len)

    logits_shard = NamedSharding(mesh, rules.batch_pspec(global_batch, 1))
    return jax.jit(
        prefill,
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, None),     # cache sharding propagated
    ), p_shard, b_shard


def make_decode_step(model, rules: ShardingRules, *, global_batch: int,
                     cache_len: int, donate_cache: bool = True):
    cfg = model.cfg
    mesh = rules.mesh
    p_shard = rules.sharding_tree(model.param_specs())
    cache_tree = model.cache_specs(global_batch, cache_len)
    c_shard = cache_shardings(cfg, rules, cache_tree, global_batch)
    tok_shard = NamedSharding(mesh, rules.batch_pspec(global_batch, 1))
    logits_shard = NamedSharding(mesh, rules.batch_pspec(global_batch, 1))
    minfo = moe_mesh_info(cfg, rules, for_decode=True)

    def decode(params, tokens, cache):
        with act.use_rules(rules):
            return model.decode_step(params, tokens, cache, moe_info=minfo)

    step = jax.jit(
        decode,
        in_shardings=(p_shard, tok_shard, c_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(2,) if donate_cache else (),
    )
    return step, p_shard, c_shard, cache_tree


# ---------------------------------------------------------------------------
# batched serving engine (continuous-batching-lite)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    parked: bool = False               # preempted, awaiting resume
    preemptions: int = 0               # times this request was parked
    fault_recoveries: int = 0          # fault-triggered park/requeue cycles
    # the fault that permanently killed this request (recovery budget spent)
    failed: BaseException | None = None
    # committed tokens a re-prefill resume is replaying; the engine asserts
    # regenerated tokens match this prefix bitwise, then drops it
    replay: list[int] | None = None
    # engine-clock timestamps (None until the event happens): arrival at
    # submit, first generated token, completion — the TTFT/TPOT feed
    arrival_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None


@dataclasses.dataclass
class _Parked:
    """A preempted request's host-side state between park and resume."""

    req: Request
    pos: int                           # cache rows at park (prompt + gen - 1)
    mode: str                          # RESUME_SNAPSHOT | RESUME_REPREFILL
    # snapshot-mode KV lives in the engine's HostArena (keyed by uid), not
    # here — the arena's budget/free-list is the single accounting point;
    # this field stays None and exists only for introspection symmetry
    snapshot: Any | None
    # engine-clock time the fault that parked this request fired (None for
    # pool-pressure parks); resume - fault_t is the request's MTTR sample
    fault_t: float | None = None
    # in-flight H2D refill handle (a reconfig.Transfer) issued by the
    # ahead-of-need pump; the resume waits on it instead of a cold DMA
    refill: Any | None = None
    # shared-prefix pages the slot held at park time: the snapshot excludes
    # them (their bytes stay resident under other readers' refcounts) and
    # the resume re-attaches them via the prefix index — or demotes to
    # replay if the prefix evaporated while parked (a CoW copy)
    shared_pages: int = 0


@dataclasses.dataclass
class _Prefilling:
    """A request mid chunked-prefill: holds a slot and a staging cache.

    ``tokens`` is the prompt padded to its bucket length — chunking runs
    over the *same* padded token array the whole-prompt path prefills, so
    every cache row (pads included) and the first-token fixup are bitwise
    identical to the unchunked engine.
    """

    req: Request
    tokens: np.ndarray                 # [b] prompt padded to bucket length
    n: int                             # real prompt length
    chunk: int                         # chunk rows per step, fixed at admit
    cache: Any                         # staging {"pos", "segments"} tree
    filled: int = 0                    # rows prefilled so far
    stalled: bool = False              # paged: last chunk unfundable


class ServeTruncated(RuntimeError):
    """``run_to_completion`` exhausted ``max_steps`` with work still pending.

    Carries the partial result so callers can't mistake truncation for
    completion — and distinguishes *why* each unfinished request is
    unfinished:

    - ``pending`` — active slots and admissible queued requests: transient,
      more steps would finish them;
    - ``parked`` — preempted mid-flight by pool pressure (generated-so-far
      tokens intact): transient, they resume when pages free up;
    - ``rejected`` — queued *or parked* requests whose worst-case page
      footprint can never fit the pool under the current admission policy:
      permanent, no number of steps completes them.  (``submit`` refuses
      these up front; they appear here only if the policy was tightened
      after submission.)
    - ``failed`` — requests killed by a hardware fault after the engine's
      recovery budget (``RetryPolicy.max_request_recoveries``) was spent:
      permanent, and raised as soon as everything else drains — the step
      loop never spins retrying them.  Each carries the fatal error on
      ``req.failed``.
    """

    def __init__(self, done: list[Request], pending: list[Request], *,
                 parked: list[Request] | tuple = (),
                 rejected: list[Request] | tuple = (),
                 failed: list[Request] | tuple = ()) -> None:
        self.done = done
        self.pending = pending
        self.parked = list(parked)
        self.rejected = list(rejected)
        self.failed = list(failed)
        super().__init__(
            f"serving truncated at max_steps: {len(done)} requests done, "
            f"{len(pending)} pending, {len(self.parked)} parked, "
            f"{len(self.rejected)} permanently rejected, "
            f"{len(self.failed)} failed to faults"
        )


class ServeEngine:
    """Fixed-slot batched decoder with slot recycling.

    Small-scale/CPU engine used by examples and tests: prompts are prefilled
    one slot at a time into the shared batch cache, all live slots decode in
    lock-step, finished slots free up for queued requests.  Sampling is greedy
    or temperature-softmax.

    **Paged KV cache** (``paged=True``): instead of a dense ``[slots,
    max_len]`` reservation per slot, KV lives in a global page pool
    (:mod:`repro.serve.paged`) addressed through per-slot block tables.
    Prefill scatters into freshly mapped pages, the fused decode scan
    carries the table and grows a sequence by one page exactly when it
    crosses a page boundary, and a finished request's pages return to the
    pool immediately.  Admission moves from "free slot?" to an
    :class:`AdmissionPolicy` over free pages and the projected growth of
    the requests already running — the concurrency ceiling becomes a
    function of *actual* sequence lengths, not the worst case.  Token
    streams are bitwise-identical to the dense engine for the same
    requests (the paged attention op gathers pages into the dense layout
    and runs the same math).
    """

    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0, seed: int = 0,
                 decode_fusion: "int | FusionPolicy" = 1,
                 hsa_queue=None, hsa_scheduler=None, producer: str = "tf-serving",
                 bucket_prompts: bool = True, min_bucket: int = 8,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: int | None = None,
                 admission: AdmissionPolicy | None = None,
                 preemption: PreemptionPolicy | None = None,
                 ledger: "ledger_mod.OverheadLedger | None" = None,
                 prefill_chunk: "int | ChunkPolicy | None" = None,
                 clock=None,
                 step_time_model: "Callable[[int, int], float] | None" = None,
                 retry: "RetryPolicy | int | None" = None,
                 host_budget_bytes: int | None = None,
                 spill: "SpillPolicy | None" = None,
                 faults=None,
                 transfer_bandwidth_bytes_s: float = 8e9,
                 integrity: "IntegrityPolicy | bool | None" = None,
                 prefix: "PrefixPolicy | bool | None" = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}      # slot -> request
        self._uid = 0
        self._cache = None
        self._pos = np.zeros(batch_slots, np.int64)
        # fused multi-token decode: K tokens per launch (int) or a FusionPolicy
        # choosing K per launch from contention and remaining request length
        self.decode_fusion = decode_fusion
        self._fused_cache: dict[int, Callable[..., Any]] = {}
        # sampling is position-indexed per request: token t of request uid is
        # drawn with fold_in(fold_in(base_key, uid), t), never from a shared
        # sequential stream — so the token sequence of a request depends only
        # on (seed, uid, logits), not on admission order or fusion depth
        self._base_key = jax.random.PRNGKey(seed)
        self._slot_key = np.zeros(
            (batch_slots,) + np.shape(self._base_key), np.uint32
        )
        self._slot_tok = np.zeros(batch_slots, np.int32)
        # optional HSA routing: prefill/decode launches become queue packets so
        # serving shares the agent with other producers (paper multi-tenancy)
        if (hsa_queue is None) != (hsa_scheduler is None):
            raise ValueError("hsa_queue and hsa_scheduler must be given together")
        self._hsa_queue = hsa_queue
        self._hsa_scheduler = hsa_scheduler
        self._producer = producer
        # prompt bucketing: pad prompts to power-of-two lengths so repeated
        # serving hits the jitted prefill's trace cache instead of retracing
        # per distinct prompt length (a distinct length = a distinct role
        # signature = a re-synthesis, in paper terms).  Only safe for
        # position-indexed caches: recurrent state (SSM/conv) folds pad
        # tokens in with no pos mask to ignore them, so bucketing is forced
        # off when the model carries any.
        self.bucket_prompts = bucket_prompts and self._bucketing_safe()
        self.min_bucket = min_bucket
        self.prefill_traces = 0        # bumped at *trace* time only: the counter
        #                                the bucketing example reads before/after
        # explicit ledger for memory accounting (falls back to the queue's)
        self.ledger = ledger if ledger is not None else (
            hsa_queue.ledger if hsa_queue is not None else None
        )
        # -- paged KV cache state ------------------------------------------
        self.paged = paged
        self.page_size = page_size
        self.admission = admission if admission is not None else AdmissionPolicy()
        self.preemption = preemption if preemption is not None else PreemptionPolicy()
        # preempted requests awaiting resume, kept oldest-uid-first: parked
        # requests were admitted before anything still queued, so they also
        # resume before anything still queued (strict seniority, no starvation)
        self._parked: list[_Parked] = []
        # fault recovery: with a RetryPolicy, a launch dying to a FaultError
        # (after the scheduler's own retries) parks its requests for
        # re-prefill replay instead of raising; a request whose recovery
        # budget is spent lands in _failed and surfaces via ServeTruncated.
        # Any non-FaultError still propagates — bugs are not retried.
        self.retry = RetryPolicy.of(retry)
        self._failed: list[Request] = []
        # overcommit counters (mirrored into the ledger when one is attached)
        self.preemptions = 0
        self.resumes = 0
        self.pages_reclaimed = 0
        self.recompute_tokens = 0
        # tiered-pool counters (host arena spill/refill/demotion)
        self.spills = 0
        self.refills = 0
        self.demotions = 0
        self.replay_fallback_tokens = 0
        self.transfer_faults = 0
        if paged:
            if not self._paged_safe():
                raise ValueError(
                    "paged=True requires plain position-indexed GQA KV caches "
                    "(no MLA latent, recurrent, windowed, or cross-attn leaves)"
                )
            if page_size < 1 or max_len % page_size:
                raise ValueError(
                    f"max_len={max_len} must be a multiple of page_size={page_size}"
                )
            if pool_pages is None:
                # match the dense engine's footprint (+ the scratch page)
                pool_pages = batch_slots * (max_len // page_size) + 1
            self.allocator = paged_mod.PageAllocator(pool_pages)
            self.pool_pages = pool_pages
            self.table_pages = max_len // page_size          # table width NP
            # per-slot block tables; unmapped entries point at the scratch
            # page so masked dummy writes never touch a live page
            self._table = np.full((batch_slots, self.table_pages),
                                  paged_mod.TRASH_PAGE, np.int32)
            self._mapped = np.zeros(batch_slots, np.int64)   # pages mapped/slot
            self._projected: dict[int, int] = {}             # slot -> pages
        else:
            self.allocator = None
        # -- prefix sharing (refcounted pages + CoW block tables) ----------
        # the paper's Table II `if_not_configured` hit applied to KV state:
        # a request whose prompt prefix is already paged in attaches to the
        # resident pages at +1 refcount and prefills only its suffix
        self.prefix = PrefixPolicy.of(prefix)
        if self.prefix is not None:
            if not paged:
                raise ValueError("prefix sharing requires paged=True "
                                 "(shared pages live in the page pool)")
            if not self._chunk_safe():
                raise ValueError(
                    "prefix sharing requires chunk-exact models (plain "
                    "dense-attention GQA layers): the unshared suffix is "
                    "prefilled as one chunk over the resident prefix rows"
                )
        self._prefix_index = (
            paged_mod.PrefixIndex() if self.prefix is not None else None
        )
        self._slot_shared = np.zeros(batch_slots, np.int64)  # shared pages/slot
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_pages_saved = 0
        self.cow_copies = 0
        self._token_bytes = 0                                # set at cache build
        # concurrency trace: sustained (mean over decode steps with work
        # pending) and peak live requests — benchmarks/table7 reads these
        self._concurrency_sum = 0
        self._concurrency_n = 0
        self.peak_concurrency = 0
        # feedback staleness: producer -> (last sample count, silent rounds)
        self._wait_freshness: dict[str, tuple[int, int]] = {}
        # -- chunked prefill (continuous batching) -------------------------
        # split each prompt into prefill_chunk-row chunks that interleave
        # with fused decode in the same step(): new requests join mid-stream
        # instead of monopolizing a launch with a whole-prompt prefill
        self.chunk_policy = ChunkPolicy.of(prefill_chunk)
        if self.chunk_policy is not None and not self._chunk_safe():
            raise ValueError(
                "prefill_chunk requires plain dense-attention layers with "
                "GQA k/v caches (MoE routing and recurrent state are not "
                "row-local across chunk boundaries)"
            )
        self._prefilling: dict[int, _Prefilling] = {}
        self._staging: dict[int, Any] = {}    # slot -> reusable segments tree
        self.chunk_traces = 0                 # bumped at chunk *trace* time
        self._last_fusion_k = 1               # feeds ChunkPolicy.choose_chunk
        self._first_this_step: list[Request] = []
        # engine clock: arrival/first-token/completion timestamps ride on it;
        # a VirtualClock plus step_time_model makes latency deterministic
        # (step_time_model(prefill_tokens, decode_tokens) -> seconds, applied
        # after every step when the clock is virtual)
        self.clock = clock if clock is not None else WallClock()
        self.step_time_model = step_time_model
        # -- tiered KV pool (host arena, tier 1) ---------------------------
        # parked snapshots spill D2H into a budgeted HostArena and stream
        # back H2D ahead of need on the TransferEngine timeline; past the
        # budget, SpillPolicy demotes victims to re-prefill replay.  With
        # host_budget_bytes=None the arena is unbounded (the PR 5
        # behavior), but the accounting and refill pipeline run either way.
        self.spill = SpillPolicy.of(spill)
        self.host_budget_bytes = host_budget_bytes
        self.faults = faults
        # -- integrity layer (silent-corruption detection) ------------------
        # digests stamped at write boundaries, verified at read/transfer/
        # region boundaries, budget-scrubbed in the background.  None keeps
        # the hot path bit-for-bit free of hashing.
        self.integrity = IntegrityPolicy.of(integrity)
        if self.integrity is not None and not paged:
            raise ValueError("integrity requires paged=True "
                             "(digests are page-granular)")
        self._page_digests: dict[int, bytes] = {}   # sealed page -> digest
        # unified scrub rotation cursor: the last-scanned target, keyed as
        # (tier, id) with tier 0 = device page, tier 1 = arena uid.  Keyed
        # on *identity*, not list position: membership churn between steps
        # (pages stamped/freed, blocks parked/resumed) can delay a
        # surviving target by at most the inserted ones, never skip it.
        self._scrub_cursor: tuple[int, int] = (-1, -1)
        # injected-but-undetected corruption, the escape-accounting ground
        # truth: device pages (page -> owner uid), tainted arena entries,
        # and slots restored from tainted/corrupted payloads
        self._live_corrupt_pages: dict[int, int] = {}
        self._tainted_uids: set[int] = set()
        self._tainted_slots: set[int] = set()
        self.corruptions_injected = 0
        self.corruptions_detected = 0
        self.pages_quarantined = 0
        self.escaped_corruptions = 0
        self.scrubbed_targets = 0
        if paged:
            self.arena = paged_mod.HostArena(host_budget_bytes)
            self._xfer = TransferEngine(
                bandwidth_bytes_s=transfer_bandwidth_bytes_s,
                clock=self.clock,
                ledger=(self.ledger if self.ledger is not None
                        else ledger_mod.GLOBAL_LEDGER),
                faults=faults,
                integrity=self.integrity,
            )
            if hsa_scheduler is not None and hasattr(
                    hsa_scheduler, "register_refill_source"):
                # refills ride the scheduler's prefetch pass too: a parked
                # request nearing resume is a lookahead-window role one
                # memory tier down (non-blocking — the engine also pumps
                # itself every step, and pumping is idempotent)
                hsa_scheduler.register_refill_source(
                    self._pump_refills_external
                )
        else:
            if host_budget_bytes is not None:
                raise ValueError("host_budget_bytes requires paged=True")
            self.arena = None
            self._xfer = None
        # submit() may run on feeder threads while step() is mid-flight:
        # the queue, uid counter, and truncation classification share a lock
        self._lock = threading.RLock()

        def _traced_chunk(params, tokens, cache, start):
            self.chunk_traces += 1    # side effect runs once per new shape
            return self.model.prefill_chunk(params, tokens, cache, start=start)

        _traced_chunk.__name__ = "prefill_chunk"
        self._chunk_fn = jax.jit(_traced_chunk, static_argnames="start")
        # the bucket-pad fixup decode (one token at the true position):
        # jitted once so repeated prefills hit the trace cache instead of
        # re-lowering an eager scan per request
        self._fixup_fn = jax.jit(self.model.decode_step)
        self._fixup_fn.__name__ = "prefill_fixup"

        def _traced_prefill(params, tokens):
            self.prefill_traces += 1   # side effect runs once per new shape
            return self.model.prefill(
                params, {"tokens": tokens}, cache_len=self.max_len
            )

        _traced_prefill.__name__ = "prefill"
        self._prefill_fn = jax.jit(_traced_prefill)

    def _launch(self, fn, *args, **kwargs):
        """Run a model step directly, or as an AQL packet through the HSA queue."""
        if self._hsa_queue is None:
            return fn(*args, **kwargs)
        if kwargs:
            def call(*a):
                return fn(*a, **kwargs)
            call.__name__ = getattr(fn, "__name__", "serve_step")
        else:
            call = fn
        pkt = self._hsa_queue.call(call, *args, producer=self._producer)
        t0 = time.perf_counter_ns()
        if getattr(self._hsa_scheduler, "running", False):
            # the scheduler's worker thread owns the consume side: never run
            # the cooperative loop concurrently, just wait for completion
            pkt.completion.wait_eq(0)
        else:
            # drain only our queue: another tenant's dep-blocked packet must
            # not wedge (or deadlock) a decode step
            self._hsa_scheduler.drain(self._hsa_queue)
        if self._hsa_queue.ledger is not None:
            # the producer-blocked leg of the packet round trip (overlaps the
            # device execution it waits on; subtract EXEC for pure overhead)
            self._hsa_queue.ledger.record(
                ledger_mod.DISPATCH_WAIT, (time.perf_counter_ns() - t0) * 1e-9,
                queue=self._hsa_queue.name, producer=self._producer,
                what=getattr(call, "__name__", "serve_step"),
            )
        if pkt.out.error is not None:
            raise pkt.out.error
        return pkt.out.value

    def submit(self, prompt: list[int], max_new_tokens: int = 32, *,
               arrival_t: float | None = None) -> int:
        """Queue a request; its uid.  ``arrival_t`` backdates the arrival
        timestamp (a trace replayer delivers arrivals at step boundaries,
        but the request arrived — and its TTFT clock started — earlier)."""
        with self._lock:
            return self._submit_locked(prompt, max_new_tokens, arrival_t)

    def _submit_locked(self, prompt: list[int], max_new_tokens: int,
                       arrival_t: float | None = None) -> int:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens)
        req.arrival_t = arrival_t if arrival_t is not None else self.clock.now()
        if self.paged:
            if len(req.prompt) + max_new_tokens > self.max_len:
                # the block table maps exactly max_len rows: past it, decode
                # writes would clamp onto the last page and corrupt live KV
                raise ValueError(
                    f"prompt ({len(req.prompt)}) + max_new_tokens "
                    f"({max_new_tokens}) exceeds max_len={self.max_len}"
                )
            # permanent rejection happens here, at submit: a request whose
            # *worst-case* footprint (growth_reserve-independent — under
            # overcommit it may map far more than its admission projection)
            # exceeds the pool can never complete, even with every other
            # tenant preempted.  Transient exhaustion mid-flight is handled
            # by preemption, never by an error.
            if self._never_fits(req):
                worst = self.admission.worst_case_pages(
                    len(req.prompt), max_new_tokens, self.page_size
                )
                cap = (self.allocator.total_pages
                       - self.admission.watermark_pages)
                raise ValueError(
                    f"request needs up to {worst} pages but the pool can ever "
                    f"admit at most {cap} — it would block the queue forever"
                )
        self._queue.append(req)
        return self._uid

    # -- internals ------------------------------------------------------------

    _RECURRENT_CACHE_KEYS = frozenset({"ssm_state", "conv_tail"})

    def _cache_leaf_keys(self) -> set[str] | None:
        """Leaf-key set of the model's cache tree (None if unknowable)."""
        import jax.tree_util as jtu

        try:
            specs = self.model.cache_specs(1, 8)
        except Exception:
            return None
        keys: set[str] = set()

        def visit(path, leaf):
            last = path[-1]
            keys.add(last.key if hasattr(last, "key") else str(last))

        jtu.tree_map_with_path(visit, specs["segments"])
        return keys

    def _bucketing_safe(self) -> bool:
        """True iff every cache leaf is position-indexed (decode masks by
        ``pos``, so end-padding is causally inert).  Recurrent leaves have no
        such mask, and sliding-window (ring) KV caches clip to the *last*
        window positions at prefill — which would be the pads.  Unknown cache
        layouts also decline, conservatively."""
        if getattr(self.cfg, "attn_window", None):
            return False
        keys = self._cache_leaf_keys()
        return keys is not None and not (keys & self._RECURRENT_CACHE_KEYS)

    def _bucket_len(self, n: int) -> int:
        """Next power-of-two at least ``min_bucket``, capped at ``max_len``."""
        b = self.min_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    # -- paged KV cache internals ---------------------------------------------

    def _paged_safe(self) -> bool:
        """True iff every cache leaf is a plain GQA k/v tensor (the layouts
        :func:`repro.models.layers.attention_decode_paged` can page)."""
        if getattr(self.cfg, "attn_window", None) or self.cfg.mla is not None:
            return False
        keys = self._cache_leaf_keys()
        return keys is not None and keys <= {"k", "v"}

    def _chunk_safe(self) -> bool:
        """True iff chunked prefill is *exact* for this model: every layer a
        plain dense-attention block over GQA k/v caches.  Attention over the
        causal prefix is row-local given the cache, so chunk boundaries are
        invisible; MoE capacity routing and recurrent scans are not row-local
        and would change values across a chunk boundary."""
        if not self._paged_safe():
            return False
        return all(seg.kinds == ("dense",) for seg in self.model.segments)

    def _projected_pages(self, req: Request) -> int:
        return self.admission.projected_pages(
            len(req.prompt), req.max_new_tokens, self.page_size
        )

    def _never_fits(self, req: Request) -> bool:
        """Permanently inadmissible: the request's worst-case footprint
        exceeds what the pool can ever fund under the current admission
        policy — no amount of preemption or waiting completes it.  The one
        predicate behind submit-time rejection and truncation-time
        classification (queued and parked alike: a parked victim's restore
        floor never exceeds its worst case)."""
        worst = self.admission.worst_case_pages(
            len(req.prompt), req.max_new_tokens, self.page_size
        )
        return worst > self.allocator.total_pages - self.admission.watermark_pages

    def _projected_growth(self) -> int:
        """Pages the already-admitted requests are still projected to map.

        Chunk-prefilling slots count too: their remaining prompt rows are
        committed growth just like an active slot's remaining decode."""
        live = list(self._active) + list(self._prefilling)
        return sum(
            max(0, self._projected[slot] - int(self._mapped[slot]))
            for slot in live
        )

    def _admit_paged(self, req: Request) -> bool:
        # admission charges only the unshared pages: a resident prefix
        # costs nothing to attach (the Table II `if_not_configured` hit)
        shared = (len(self._lookup_prefix(req.prompt, req.uid))
                  if self.prefix is not None else 0)
        return self.admission.admit(
            free_pages=self.allocator.free_pages,
            projected_growth_pages=self._projected_growth(),
            request_pages=max(0, self._projected_pages(req) - shared),
        )

    def _launch_pages(self, slot: int, req: Request, k: int) -> int:
        """Mapped-page target for ``slot`` to absorb a depth-``k`` launch
        (through the last position the launch can write).  The one formula
        behind both growth *funding* (`_fund_growth`) and growth *mapping*
        (`_grow_to`) — keeping them a single computation is what makes
        mid-launch ``PagePoolExhausted`` unreachable by construction."""
        rem = req.max_new_tokens - len(req.generated)
        if rem <= 0:
            return int(self._mapped[slot])
        last_write = int(self._pos[slot]) + min(k, rem) - 1
        return min(last_write // self.page_size + 1, self.table_pages)

    def _grow_to(self, slot: int, need: int) -> None:
        """Map pages up to the ``need`` target — the on-demand growth step:
        a sequence gets its next page exactly when a launch will carry it
        across a page boundary."""
        have = int(self._mapped[slot])
        if need <= have:
            return
        pages = self.allocator.allocate(self._active[slot].uid, need - have)
        self._table[slot, have:need] = pages
        self._mapped[slot] = need

    def _release_slot(self, slot: int, req: Request) -> None:
        """Finished/cancelled request: its page *references* drop now.

        A page returns to the pool only when its last reader lets go — the
        digest stamp, the live-corruption record, and the prefix-index
        entry keyed on a physical page must all survive exactly as long as
        some block table still maps it, so they are dropped only for the
        pages the allocator actually released."""
        pages = [int(p) for p in self._table[slot, : int(self._mapped[slot])]]
        rehome = False
        if pages:
            released = self.allocator.free(req.uid, pages)
            for p in released:
                # a freed page's digest dies with its contents (the next
                # owner re-stamps); an undetected corruption on it never
                # influenced a token — latent, not escaped
                self._page_digests.pop(p, None)
                self._live_corrupt_pages.pop(p, None)
                if self._prefix_index is not None:
                    rehome = rehome or p in self._prefix_index.pages()
                    self._prefix_index.drop_page(p)
        self._tainted_slots.discard(slot)
        self._table[slot] = paged_mod.TRASH_PAGE
        self._mapped[slot] = 0
        self._projected.pop(slot, None)
        if self.prefix is not None:
            self._slot_shared[slot] = 0
            self._record_prefix_gauge()
            if rehome:
                # the released pages backed index entries, but other slots
                # may hold bitwise-identical private copies (first-wins
                # losers) — re-home the keys onto a surviving copy so a
                # prefix stays discoverable as long as *any* reader lives
                for s, r in self._active.items():
                    if s != slot:
                        self._publish_prefix(s, r)

    # -- prefix sharing: lookup / attach / publish ----------------------------

    def _lookup_prefix(self, prompt: np.ndarray, uid: int) -> list[int]:
        """Longest resident, attachable page run covering a prefix of
        ``prompt`` — the admission-time "is my prefix already configured?"
        probe.  Capped at ``(len(prompt) - 1) // page_size`` so the suffix
        prefill always computes at least the last real row (whose logits
        sample token 0); the walk stops at the first miss, ref-capped page,
        or page ``uid`` already holds."""
        if self._prefix_index is None or self._cache is None:
            return []
        cap = (len(prompt) - 1) // self.page_size
        if cap < 1:
            return []
        keys = paged_mod.prefix_page_keys(prompt, self.page_size,
                                          max_pages=cap)
        pages: list[int] = []
        for key in keys:
            p = self._prefix_index.get(key)
            if p is None:
                break
            refs = self.allocator.refcount(p)
            if refs == 0:                     # stale entry (page released)
                self._prefix_index.drop_page(p)
                break
            if refs >= self.prefix.max_refs:
                break
            if uid in self.allocator.owners_of(p):
                break
            pages.append(p)
        if len(pages) < self.prefix.min_prefix_pages:
            return []
        return pages

    def _count_prefix_lookup(self, shared: list[int]) -> None:
        self.prefix_lookups += 1
        if shared:
            self.prefix_hits += 1
            self.prefix_pages_saved += len(shared)
        if self.ledger is not None:
            self.ledger.record_prefix_lookup(
                hit=bool(shared), pages_saved=len(shared)
            )

    def _attach_prefix(self, slot: int, uid: int, pages: list[int]) -> None:
        """Map ``pages`` (a resident shared prefix) into ``slot``'s block
        table at +1 refcount each.  The caller has already reset the row."""
        for p in pages:
            self.allocator.share(p, uid)
        s = len(pages)
        self._table[slot, :s] = pages
        self._mapped[slot] = s
        self._slot_shared[slot] = s
        self._record_prefix_gauge()

    def _publish_prefix(self, slot: int, req: Request) -> None:
        """Register ``slot``'s full prompt pages in the prefix index so
        later requests with the same prefix attach instead of prefilling.
        First-wins: pages already published under a key stay published."""
        if self._prefix_index is None:
            return
        full = len(req.prompt) // self.page_size
        if full < 1:
            return
        keys = paged_mod.prefix_page_keys(req.prompt, self.page_size,
                                          max_pages=full)
        for i, key in enumerate(keys):
            self._prefix_index.publish(key, int(self._table[slot, i]))

    def _record_prefix_gauge(self) -> None:
        if self.ledger is not None and self.prefix is not None:
            self.ledger.record_prefix_sharing(
                shared_pages=self.allocator.shared_pages
            )

    # -- integrity: digests, scrubbing, corruption injection ------------------

    def _sealed_pages(self, slot: int, rows: int) -> list[int]:
        """Pages of ``slot`` whose every row is final at ``rows`` written
        rows.  The trailing partial page is still being appended to by
        decode, so it is never digested (its hash would be stale one step
        later) and never an injection target (corrupting rows that a later
        write overwrites anyway proves nothing)."""
        full = rows // self.page_size
        return [int(p) for p in self._table[slot, :full]]

    def _seal_slot_pages(self, slot: int, rows: int) -> None:
        """Stamp content digests on every sealed page of ``slot``.

        Called at each write boundary — prefill scatter, chunk scatter,
        decode commit, snapshot restore — so `_page_digests` always reflects
        the bytes a correct execution would hold."""
        if self.integrity is None:
            return
        segments = self._cache["segments"]
        for p in self._sealed_pages(slot, rows):
            # a sealed page's rows are final until the page is freed (and
            # the digest dies with it in _release_slot), so an existing
            # stamp is already correct — re-hashing would only launder an
            # injected flip into a "clean" digest
            if p not in self._page_digests:
                self._page_digests[p] = paged_mod.page_digest(segments, p)

    def _inject_corruption(self) -> None:
        """Seeded in-place bit flips on cold state, drawn once per step.

        Device-page flips target sealed pages of live slots; arena-block
        flips target parked snapshots.  Both record the page/uid so the
        engine itself can account an *escape* if undetected bytes ever
        reach a sampled token — the zero-escape assertion is honest, not
        tautological.  Injection runs regardless of ``integrity`` (that is
        how a verification-off run proves escapes actually happen)."""
        if self.faults is None or not self.paged or self._cache is None:
            return
        # device pages: sealed pages across active + fully-scattered chunk
        # rows.  Pages already corrupt are excluded — a second XOR flip would
        # restore the original bytes, silently un-corrupting the target and
        # leaving the escape accounting pointing at clean state.
        targets: list[tuple[int, int]] = []  # (slot, page)
        for slot in self._active:
            for p in self._sealed_pages(slot, int(self._pos[slot])):
                if p not in self._live_corrupt_pages:
                    targets.append((slot, p))
        for slot, entry in self._prefilling.items():
            for p in self._sealed_pages(slot, min(entry.filled, entry.n)):
                if p not in self._live_corrupt_pages:
                    targets.append((slot, p))
        if targets:
            i = self.faults.draw_corruption(
                "flip_page", [f"page[{p}]" for _, p in targets]
            )
            if i is not None:
                _, page = targets[i]
                self._cache["segments"] = paged_mod.flip_page(
                    self._cache["segments"], page
                )
                uid = self.allocator.owner_of(page)
                self._live_corrupt_pages[page] = uid if uid is not None else -1
                self.corruptions_injected += 1
                if self.ledger is not None:
                    self.ledger.record_corruption(kind="flip_page")
        # arena blocks: parked snapshots spilled to host
        uids = [u for u in self.arena.entries()
                if self.arena.load(u) is not None
                and u not in self._tainted_uids]
        if uids:
            i = self.faults.draw_corruption(
                "flip_block", [f"block[uid={u}]" for u in uids]
            )
            if i is not None:
                uid = uids[i]
                self.arena.corrupt(uid)
                self._tainted_uids.add(uid)
                self.corruptions_injected += 1
                if self.ledger is not None:
                    self.ledger.record_corruption(kind="flip_block")

    def _scrub_step(self) -> None:
        """Budgeted background audit: re-hash up to ``scrub_pages_per_step``
        cold targets against their stamped digests.  A mismatch quarantines
        the page and forces every reader through RESUME_REPREFILL — the
        same recovery lane as a PR 7 engine fault, so completed streams
        stay bitwise-identical to corruption-free runs.

        Stamped device pages and stamped arena blocks form *one* rotation,
        resumed at the first target strictly greater than the last-scanned
        (tier, id) cursor, wrapping — so under a budget smaller than the
        target count, every stamped target is audited within
        ``ceil(targets / budget)`` steps regardless of where it sits in the
        rotation, and membership churn (pages stamped/freed, blocks
        parked/resumed between steps) can never skip or double-scan a
        surviving target within a rotation.  Only *stamped* targets count:
        an unstamped arena entry (integrity off at store time) is neither
        scanned nor part of the coverage denominator."""
        if self.integrity is None or self.integrity.scrub_pages_per_step <= 0:
            return
        budget = self.integrity.scrub_pages_per_step
        t0 = self.clock.now()
        segments = self._cache["segments"] if self._cache is not None else None
        targets: list[tuple[int, int]] = []
        if segments is not None:
            targets += [(0, p) for p in sorted(self._page_digests)]
        targets += [
            (1, u) for u in sorted(self.arena.entries())
            if self.arena.digest_of(u) is not None
        ]
        scanned_pages = scanned_blocks = 0
        bad: list[int] = []
        bad_uids: list[int] = []
        if targets:
            k = min(budget, len(targets))
            idx = bisect.bisect_right(targets, self._scrub_cursor)
            scan = [targets[(idx + j) % len(targets)] for j in range(k)]
            self._scrub_cursor = scan[-1]
            for tier, tid in scan:
                if tier == 0:
                    scanned_pages += 1
                    if (paged_mod.page_digest(segments, tid)
                            != self._page_digests[tid]):
                        bad.append(tid)
                else:
                    scanned_blocks += 1
                    if not self.arena.verify(tid):
                        bad_uids.append(tid)
        self.scrubbed_targets += scanned_pages + scanned_blocks
        if self.ledger is not None:
            self.ledger.record_scrub(
                pages=scanned_pages, blocks=scanned_blocks,
                targets=len(targets),
            )
            self.ledger.record("scrub", max(0.0, self.clock.now() - t0))
        if bad:
            self._handle_corrupt_pages(bad, via="scrub")
        for uid in bad_uids:
            self.corruptions_detected += 1
            self._tainted_uids.discard(uid)
            if self.ledger is not None:
                self.ledger.record_integrity_detection(
                    via="scrub", recovered=True
                )
            entry = next(
                (e for e in self._parked if e.req.uid == uid), None
            )
            if entry is not None:
                self._demote_entry(entry)
            elif self.arena.holds(uid):
                self.arena.discard(uid)

    def _handle_corrupt_pages(self, pages: list[int], *, via: str) -> None:
        """Quarantine ``pages`` and re-prefill *every* reader from its
        prompt.

        A shared page can sit in several block tables at once, so recovery
        discovers the full reader set itself: every active reader parks
        through ``RESUME_REPREFILL`` (the PR 7 fault lane — position-
        indexed sampling replays the committed tokens bitwise-identically)
        and every mid-prefill reader aborts back to the queue.  Order
        matters: park/release first drops every reference (pages go back to
        the free list only at refcount zero), *then* quarantine pulls them
        out of circulation — the allocator only quarantines free pages,
        keeping the tiling invariant checkable.  Readers beyond the first
        of a shared page are the copy-on-write cost of sharing and are
        counted as CoW copies."""
        err = SilentCorruption(
            f"digest mismatch on page(s) {pages} (via {via})"
        )
        if self.prefix is not None:
            extra = sum(
                max(0, self.allocator.refcount(p) - 1) for p in pages
            )
            if extra:
                self.cow_copies += extra
                if self.ledger is not None:
                    self.ledger.record_prefix_cow(extra)
        for p in pages:
            self._live_corrupt_pages.pop(p, None)
            self._page_digests.pop(p, None)
        bad = set(pages)

        def reads_bad(slot: int) -> bool:
            mapped = {int(q) for q in
                      self._table[slot, : int(self._mapped[slot])]}
            return bool(bad & mapped)

        for slot in sorted(s for s in self._active if reads_bad(s)):
            req = self._active[slot]
            req.fault_recoveries += 1
            if (self.retry is not None
                    and req.fault_recoveries
                    > self.retry.max_request_recoveries):
                self._active.pop(slot)
                self._release_slot(slot, req)
                self._fail_request(req, err)
            else:
                self._park_slot(slot, mode=RESUME_REPREFILL,
                                fault_t=self.clock.now())
        for slot in sorted(s for s in self._prefilling if reads_bad(s)):
            if self.retry is not None:
                self._abort_prefill_to_queue(slot, err)
            else:
                entry = self._prefilling.pop(slot)
                self._release_slot(slot, entry.req)
                entry.req.fault_recoveries += 1
                idx = next(
                    (i for i, r in enumerate(self._queue)
                     if r.uid > entry.req.uid),
                    len(self._queue),
                )
                self._queue.insert(idx, entry.req)
        if self.prefix is not None:
            # after the parks: releasing a reader re-homes index entries
            # onto surviving copies, which may re-insert a bad page — drop
            # them last, just before they leave circulation
            for p in pages:
                self._prefix_index.drop_page(p)
        for p in pages:
            self.corruptions_detected += 1
            if self.ledger is not None:
                self.ledger.record_integrity_detection(
                    via=via, recovered=True
                )
            try:
                self.allocator.quarantine(p)
            except ValueError:
                continue  # freed page already re-allocated this step
            self.pages_quarantined += 1
            if self.ledger is not None:
                self.ledger.record_page_quarantine()

    def _record_escape(self, n: int = 1) -> None:
        self.escaped_corruptions += n
        if self.ledger is not None:
            for _ in range(n):
                self.ledger.record_escape()

    # -- preemption: park / resume lifecycle ----------------------------------

    @property
    def parked_requests(self) -> list[Request]:
        return [e.req for e in self._parked]

    def preempt(self, uid: int | None = None) -> int:
        """Park one active request, returning its pages to the pool *now*.

        With ``uid=None`` the engine's :class:`PreemptionPolicy` picks the
        victim (youngest-first by default).  This is the external-pressure
        entry point — the paper's fabric is shared "simultaneously from
        other sources", and this is how another source takes serving's
        memory back mid-flight.  The request keeps its generated-so-far
        tokens and resumes automatically once pages free up.
        """
        if not self.paged:
            raise RuntimeError("preemption requires paged=True")
        with self._lock:
            if uid is None:
                victims = self.preemption.victims(self._candidates(), 1)
                if not victims:
                    raise ValueError("no active request to preempt")
                uid = victims[0]
            slot = next(
                (s for s, r in self._active.items() if r.uid == uid), None
            )
            if slot is None:
                raise ValueError(f"request {uid} is not active")
            self._park_slot(slot)
            return uid

    def resume(self, uid: int) -> bool:
        """Force a resume attempt for a parked request.

        Returns False when the pool still cannot fund it (the request stays
        parked — re-park, never spin).  Raises ``ValueError`` if ``uid`` is
        not parked: resuming a request twice (or one that is active, done,
        or unknown) is a caller bug, not a transient condition.
        """
        with self._lock:
            entry = next(
                (e for e in self._parked if e.req.uid == uid), None
            )
            if entry is None:
                raise ValueError(
                    f"request {uid} is not parked (double resume?)"
                )
            slot = next(
                (s for s in range(self.slots)
                 if s not in self._active and s not in self._prefilling),
                None,
            )
            if slot is None:
                return False
            return self._try_resume(entry, slot)

    def _candidates(self) -> list[PreemptionCandidate]:
        return [
            PreemptionCandidate(
                uid=req.uid,
                mapped_pages=int(self._mapped[slot]),
                tokens_done=int(self._pos[slot]),
            )
            for slot, req in self._active.items()
        ]

    def _park_slot(self, slot: int, *, mode: str | None = None,
                   fault_t: float | None = None) -> None:
        """Reclaim one active request's pages; keep its progress on the host.

        ``mode`` overrides the policy's resume-mode choice (fault recovery
        forces re-prefill: device-side cache state after a failed launch is
        untrusted, so nothing is snapshotted from it); ``fault_t`` stamps the
        park as fault-triggered for MTTR accounting at resume."""
        req = self._active.pop(slot)
        t0 = time.perf_counter_ns()
        pos = int(self._pos[slot])
        if mode is None:
            mode = self.preemption.resume_mode(tokens_done=pos)
        snapshot = None
        snap_bytes = 0
        reclaimed = int(self._mapped[slot])
        shared = int(self._slot_shared[slot]) if self.prefix is not None else 0
        if mode == RESUME_SNAPSHOT:
            # only the pages holding written rows (0..pos-1) matter; pages
            # mapped ahead for a launch that never ran hold nothing.  The
            # shared-prefix pages are excluded: their bytes stay resident
            # under other readers' refcounts and the resume re-attaches
            # them through the prefix index — this is the copy-on-write
            # discipline (park copies only the private tail).
            keep = paged_mod.pages_for(pos, self.page_size)
            snapshot = paged_mod.gather_pages(
                self._cache["segments"], self._table[slot, shared:keep]
            )
            snap_bytes = paged_mod.snapshot_bytes(snapshot)
            # the snapshot spills D2H into the budgeted host arena; if the
            # store cannot be funded (budget, or a faulted transfer) the
            # park gracefully degrades to re-prefill replay — the request
            # keeps only its committed prefix and recomputes the rest
            if not self._spill_snapshot(req.uid, snapshot, snap_bytes, pos):
                mode = RESUME_REPREFILL
                snap_bytes = 0
                shared = 0
            snapshot = None                 # the arena is authoritative
        if mode == RESUME_REPREFILL:
            shared = 0                      # replay re-looks-up from scratch
        self._release_slot(slot, req)
        req.parked = True
        req.preemptions += 1
        self._parked.append(_Parked(req=req, pos=pos, mode=mode,
                                    snapshot=snapshot, fault_t=fault_t,
                                    shared_pages=shared))
        self._parked.sort(key=lambda e: e.req.uid)
        self.preemptions += 1
        self.pages_reclaimed += reclaimed
        if self.ledger is not None:
            self.ledger.record(
                ledger_mod.PREEMPT_PARK, (time.perf_counter_ns() - t0) * 1e-9,
                producer=self._producer, what=mode, uid=req.uid,
            )
            self.ledger.record_preemption(
                pages_reclaimed=reclaimed, snapshot_bytes=snap_bytes
            )

    def _try_resume(self, entry: _Parked, slot: int) -> bool:
        """Bring a parked request back into ``slot`` if the pool can fund it.

        The admission test mirrors fresh admission (projected lifetime pages
        against free minus in-flight growth), floored by what the resume
        needs *immediately* — a snapshot restore maps every written row's
        page up front, which late in a request's life can exceed the
        reserve-scaled projection.
        """
        req = entry.req
        attach: list[int] = []
        if self.prefix is not None:
            attach = self._lookup_prefix(req.prompt, req.uid)
        if entry.mode == RESUME_SNAPSHOT and entry.shared_pages > len(attach):
            # the shared prefix this snapshot leaned on evaporated (last
            # reader gone, ref-capped, or quarantined) while parked: the
            # snapshot lacks those rows, so this is the CoW moment — demote
            # to replay, which rebuilds the prefix privately (or re-shares
            # whatever the fresh lookup still finds)
            self.cow_copies += 1
            if self.ledger is not None:
                self.ledger.record_prefix_cow()
            self._demote_entry(entry)
        if entry.mode == RESUME_SNAPSHOT:
            attach = attach[: entry.shared_pages]
            need_now = (paged_mod.pages_for(entry.pos, self.page_size)
                        - len(attach))
        else:
            need_now = max(0, paged_mod.pages_for(
                len(req.prompt), self.page_size) - len(attach))
        request_pages = max(
            need_now, self._projected_pages(req) - len(attach)
        )
        if not self.admission.admit(
            free_pages=self.allocator.free_pages,
            projected_growth_pages=self._projected_growth(),
            request_pages=request_pages,
        ):
            return False                      # still full: stays parked
        t0 = time.perf_counter_ns()
        self._parked.remove(entry)
        recompute = 0
        if entry.mode == RESUME_SNAPSHOT:
            # wait on the ahead-of-need refill (only its exposed residue
            # stalls the resume); a cold resume issues the DMA on demand —
            # fully exposed, which is what the lookahead pump exists to
            # avoid.  A faulted refill retires through the transfer
            # engine's abort/backoff and demotes this entry to replay.
            x = entry.refill
            if x is None:
                x = self._issue_refill(req.uid)
            if x.error is not None:
                self.transfer_faults += 1
                self._demote_entry(entry)       # falls through to replay
            else:
                try:
                    self._xfer.wait(x)
                except CorruptPayload:
                    # the refill delivered wrong bytes (arena rot or DMA
                    # corruption caught by the payload digest): the host
                    # copy is untrusted — demote to replay, stream unharmed
                    self.transfer_faults += 1
                    self.corruptions_detected += 1
                    self._tainted_uids.discard(req.uid)
                    self._demote_entry(entry)
                else:
                    entry.refill = None
                    if x.payload is not None:
                        # the DMA's delivered bytes (corrupted or not, when
                        # verification is off) are what lands on device
                        snapshot = x.payload
                        self.arena.discard(req.uid)
                    else:
                        snapshot = self.arena.take(req.uid)
                    self.refills += 1
                    n = paged_mod.pages_for(entry.pos, self.page_size)
                    s = len(attach)
                    self._table[slot] = paged_mod.TRASH_PAGE
                    if s:
                        # the prefix rows never left the device: re-attach
                        # them at +1 refcount; only the private tail pages
                        # are allocated and DMA-restored
                        self._attach_prefix(slot, req.uid, attach)
                    pages = self.allocator.allocate(req.uid, n - s)
                    self._table[slot, s:n] = pages
                    self._mapped[slot] = n
                    self._cache["segments"] = paged_mod.restore_pages(
                        self._cache["segments"], snapshot, np.asarray(pages)
                    )
                    self._pos[slot] = entry.pos
                    self._projected[slot] = self._projected_pages(req)
                    self._slot_key[slot] = np.asarray(
                        jax.random.fold_in(self._base_key, req.uid)
                    )
                    self._seal_slot_pages(slot, entry.pos)
                    if x.corrupted or req.uid in self._tainted_uids:
                        # verification off: garbage was restored — remember
                        # it so the commit path can count the escape
                        self._tainted_uids.discard(req.uid)
                        self._tainted_slots.add(slot)
        if entry.mode == RESUME_REPREFILL:
            # re-prefill + replay: recompute the prompt cache (bitwise equal
            # to the original prefill — same fn, same inputs), rewind the
            # request, and let the normal decode path regenerate the
            # committed tokens.  Sampling is position-indexed, so the replay
            # emits the same stream bit for bit — asserted in step() against
            # ``req.replay`` as it goes.
            committed = req.replay if req.replay is not None else req.generated
            recompute = len(req.prompt) + len(committed) - 1
            req.replay = committed
            req.generated = []
            try:
                self._prefill_slot(slot, req)
            except FaultError as e:
                # the recovery prefill itself died to hardware: re-park (the
                # committed tokens live on in req.replay) or give up once the
                # recovery budget is spent — never leave it half-resumed
                if self.retry is None:
                    raise
                self._repark_faulted(entry, e)
                return False
            if req.generated[0] != committed[0]:
                raise RuntimeError(
                    f"preemption replay diverged at request {req.uid} token 0: "
                    f"re-prefill sampled {req.generated[0]}, committed "
                    f"{committed[0]}"
                )
        req.parked = False
        self._active[slot] = req
        self.resumes += 1
        self.recompute_tokens += recompute
        if self.ledger is not None:
            self.ledger.record(
                ledger_mod.PREEMPT_RESUME,
                (time.perf_counter_ns() - t0) * 1e-9,
                producer=self._producer, what=entry.mode, uid=req.uid,
            )
            self.ledger.record_resume(
                mode=entry.mode, recompute_tokens=recompute
            )
        if entry.fault_t is not None:
            # fault-triggered park now healed: park-to-resume on the engine
            # clock is this request's repair time (the MTTR feed), and the
            # replayed tokens are recovery recompute, not overcommit churn
            mttr = max(0.0, self.clock.now() - entry.fault_t)
            if self.ledger is not None:
                self.ledger.record(
                    ledger_mod.RECOVER, mttr, producer=self._producer,
                    what=entry.mode, uid=req.uid,
                )
                self.ledger.record_recovery(
                    mttr_s=mttr, recompute_tokens=recompute
                )
        return True

    # -- tiered pool: spill / refill / demotion -------------------------------

    def _spill_snapshot(self, uid: int, snapshot: Any, nbytes: int,
                        pos: int) -> bool:
        """Spill a fresh park's snapshot D2H into the host arena.

        Returns False when the park must degrade to re-prefill replay
        instead: the snapshot can never fit the budget, the D2H transfer
        faulted, or every demotable victim was already demoted and the
        store still does not fit.  With ``SpillPolicy.allow_replay=False``
        those cases raise (:class:`~repro.serve.paged.HostArenaExhausted`
        or the transfer's :class:`FaultError`) — the only configuration in
        which tiering rejects work.
        """
        arena = self.arena
        arena.configure(self.page_size * self._token_bytes)
        if not arena.can_ever_fit(nbytes):
            if not self.spill.allow_replay:
                raise paged_mod.HostArenaExhausted(
                    f"snapshot of {nbytes} B cannot ever fit host budget "
                    f"{arena.budget_bytes} B and replay is disabled"
                )
            self._count_demotion(bytes_freed=0, replay_tokens=pos)
            return False
        digest = None
        payload = None
        if self.integrity is not None:
            # stamp the content digest at the write boundary (before the
            # DMA), so corruption in the transfer *or* in the arena is
            # caught by any later check against this digest
            digest = paged_mod.tree_digest(snapshot)
            payload = snapshot
        x = self._xfer.issue("d2h", f"kv[uid={uid}]", nbytes,
                             payload=payload, digest=digest)
        if x.error is not None:
            self.transfer_faults += 1
            if isinstance(x.error, CorruptPayload):
                # the spill's payload digest failed at issue: the host copy
                # is wrong — degrading to replay keeps only trusted state
                self.corruptions_detected += 1
            if not self.spill.allow_replay:
                raise x.error
            self._count_demotion(bytes_freed=0, replay_tokens=pos)
            return False
        while not arena.fits(nbytes):
            if not self.spill.allow_replay:
                raise paged_mod.HostArenaExhausted(
                    f"store of {nbytes} B over host budget "
                    f"{arena.budget_bytes} B ({arena.used_bytes} B used) "
                    "and replay is disabled"
                )
            cands = [
                SpillCandidate(
                    uid=e.req.uid,
                    arena_bytes=arena.bytes_of(e.req.uid),
                    tokens_done=e.pos,
                )
                for e in self._parked
                if e.mode == RESUME_SNAPSHOT and arena.holds(e.req.uid)
            ]
            if not cands:
                # nothing left to demote: the incoming snapshot itself
                # degrades to replay (its d2h timeline slot is sunk cost)
                self._count_demotion(bytes_freed=0, replay_tokens=pos)
                return False
            short = arena.blocks_for(nbytes) - arena.free_blocks
            need_bytes = short * arena.block_bytes
            for v_uid in self.spill.victims(cands, need_bytes):
                self._demote_entry(
                    next(e for e in self._parked if e.req.uid == v_uid)
                )
        # store what the DMA *delivered* (a corrupt_transfer draw with
        # verification off hands back flipped bytes) under the pre-transfer
        # digest — exactly the mismatch a scrub or refill check catches
        stored = x.payload if x.payload is not None else snapshot
        arena.store(uid, stored, nbytes, digest=digest)
        if x.corrupted:
            self._tainted_uids.add(uid)
        self.spills += 1
        return True

    def _issue_refill(self, uid: int):
        """Issue the H2D refill for ``uid``'s arena entry, threading the
        stored payload + its store-time digest through the transfer so the
        DMA completion can verify what it delivered."""
        payload = digest = None
        if self.integrity is not None:
            payload = self.arena.load(uid)
            digest = self.arena.digest_of(uid)
        return self._xfer.issue(
            "h2d", f"kv[uid={uid}]", self.arena.bytes_of(uid),
            payload=payload, digest=digest,
        )

    def _demote_entry(self, entry: _Parked) -> None:
        """Demote one parked snapshot to re-prefill replay: its arena bytes
        go back to the budget, its in-flight refill (if any) is cancelled,
        and the eventual resume recomputes ``entry.pos`` rows instead of
        restoring them."""
        uid = entry.req.uid
        freed = self.arena.discard(uid) if self.arena.holds(uid) else 0
        # a tainted (corrupted-in-arena) entry demoted to replay never
        # restores its bytes: the corruption is gone with the blocks
        self._tainted_uids.discard(uid)
        if entry.refill is not None:
            self._xfer.cancel(entry.refill)
            entry.refill = None
        entry.mode = RESUME_REPREFILL
        entry.snapshot = None
        self._count_demotion(bytes_freed=freed, replay_tokens=entry.pos)

    def _count_demotion(self, *, bytes_freed: int,
                        replay_tokens: int) -> None:
        self.demotions += 1
        self.replay_fallback_tokens += replay_tokens
        if self.ledger is not None:
            self.ledger.record_demotion(
                bytes_freed=bytes_freed, replay_tokens=replay_tokens
            )

    def _pump_refills(self) -> None:
        """Issue H2D refills for the parked snapshots nearest resume.

        The ahead-of-need half of the tier: the first
        ``SpillPolicy.refill_lookahead`` parked entries (seniority order —
        exactly the order ``_step_locked`` resumes them) get their arena
        bytes queued on the transfer engine now, so by the time the resume
        runs, most of the DMA has hidden behind decode steps.  A refill
        that faults here demotes its entry to replay immediately (the
        abort/backoff already happened inside the transfer engine).
        Idempotent: entries with an in-flight refill are skipped.
        """
        if not self.paged or self._xfer is None:
            return
        for entry in list(self._parked[: self.spill.refill_lookahead]):
            if entry.mode != RESUME_SNAPSHOT or entry.refill is not None:
                continue
            uid = entry.req.uid
            if not self.arena.holds(uid):
                continue
            x = self._issue_refill(uid)
            if x.error is not None:
                self.transfer_faults += 1
                self._demote_entry(entry)
                continue
            entry.refill = x

    def _pump_refills_external(self) -> None:
        """Scheduler-driven pump (registered via
        ``Scheduler.register_refill_source``).  Never blocks: if the engine
        lock is held (a step is mid-flight on another thread), skip — the
        engine pumps itself at the end of every step anyway."""
        if not self._lock.acquire(blocking=False):
            return
        try:
            self._pump_refills()
        finally:
            self._lock.release()

    # -- fault recovery -------------------------------------------------------

    @property
    def failed_requests(self) -> list[Request]:
        """Requests permanently killed by faults (recovery budget spent)."""
        return list(self._failed)

    def _fail_request(self, req: Request, err: BaseException) -> None:
        """Recovery budget spent: the request is dead.  It moves to the
        ``failed`` bucket (surfaced by ``run_to_completion`` via
        :class:`ServeTruncated`) instead of being retried forever."""
        req.failed = err
        req.parked = False
        self._failed.append(req)
        if self.ledger is not None:
            self.ledger.record_recovery(failed=True)

    def _repark_faulted(self, entry: _Parked, err: FaultError) -> None:
        """A fault-interrupted resume goes back to the parked list (budget
        permitting) with its fault timestamp set so the eventual successful
        resume reports the full outage as MTTR."""
        req = entry.req
        req.fault_recoveries += 1
        if req.fault_recoveries > self.retry.max_request_recoveries:
            self._fail_request(req, err)
            return
        if entry.fault_t is None:
            entry.fault_t = self.clock.now()
        self._parked.append(entry)
        self._parked.sort(key=lambda e: e.req.uid)

    def _recover_decode_fault(self, err: FaultError) -> None:
        """A decode launch died to hardware after the scheduler's own
        retries: park every live slot for re-prefill replay.

        The failed launch's carry was never committed (cache, positions and
        tokens are as they were before the launch), but the device-side KV
        behind them is untrusted after a fault — so recovery forces
        ``RESUME_REPREFILL``: recompute the prompt cache from scratch and
        replay the committed tokens, which position-indexed sampling makes
        bitwise-identical to the fault-free stream.  Requests whose recovery
        budget is spent fail instead of parking.  Recovery needs the paged
        park/resume machinery and an engine RetryPolicy; otherwise the fault
        propagates unchanged (the legacy fail-loud behavior).
        """
        if self.retry is None or not self.paged:
            raise err
        now = self.clock.now()
        for slot in sorted(self._active):
            req = self._active[slot]
            req.fault_recoveries += 1
            if req.fault_recoveries > self.retry.max_request_recoveries:
                self._active.pop(slot)
                self._release_slot(slot, req)
                self._fail_request(req, err)
                continue
            self._park_slot(slot, mode=RESUME_REPREFILL, fault_t=now)

    def _abort_prefill_to_queue(self, slot: int, err: FaultError) -> None:
        """A chunked prefill's launch faulted: drop the partial staging work,
        return the request to the queue in uid order (budget permitting) —
        the re-admitted prefill recomputes every chunk from row 0, so the
        eventual stream is untouched by the fault."""
        entry = self._prefilling.pop(slot)
        req = entry.req
        if self.paged:
            self._release_slot(slot, req)
        req.fault_recoveries += 1
        if req.fault_recoveries > self.retry.max_request_recoveries:
            self._fail_request(req, err)
            return
        idx = next(
            (i for i, r in enumerate(self._queue) if r.uid > req.uid),
            len(self._queue),
        )
        self._queue.insert(idx, req)

    def _fund_growth(self, k: int) -> int:
        """Make this launch's page growth allocatable; the funded depth.

        Plans every live slot's mapping need for a depth-``k`` launch.  On a
        shortfall the cheap lever comes first: **shrink the launch** (halve
        ``k``) — a shallower scan needs fewer pages ahead and costs nothing
        but amortization, while preempting costs a victim its pages and
        possibly a full re-prefill for a launch depth that might then be
        abandoned anyway.  Only when even ``k=1`` cannot be funded does the
        engine park policy-chosen victims, one at a time with a re-plan
        between (a parked victim both frees its pages and drops its own
        need).  A lone request can always fund itself at any depth —
        ``submit`` rejected anything whose worst case exceeds the pool — so
        the loop terminates with the launch funded and
        ``PagePoolExhausted`` stays unreachable.
        """
        while True:
            needed = sum(
                max(0, self._launch_pages(slot, req, k)
                    - int(self._mapped[slot]))
                for slot, req in self._active.items()
            )
            shortfall = needed - self.allocator.free_pages
            if shortfall <= 0:
                return k
            if k > 1:
                k = (k + 1) // 2
                continue
            victims = self.preemption.victims(self._candidates(), shortfall)
            if not victims:
                return k                   # nothing to reclaim (empty batch)
            slot = next(
                s for s, r in self._active.items() if r.uid == victims[0]
            )
            self._park_slot(slot)

    def _record_memory(self) -> None:
        if self.ledger is None or self._token_bytes == 0:
            return
        used = sum(int(self._pos[s]) for s in self._active) * self._token_bytes
        if self.paged:
            reserved = (
                int(self._mapped.sum()) * self.page_size * self._token_bytes
            )
        else:
            reserved = len(self._active) * self.max_len * self._token_bytes
        self.ledger.record_memory(reserved_bytes=reserved, used_bytes=used)
        if self.arena is not None:
            self.ledger.record_host_memory(
                used_bytes=self.arena.used_bytes,
                budget_bytes=self.arena.budget_bytes,
            )

    def concurrency_stats(self) -> dict[str, float]:
        """Sustained (mean over steps with live work) and peak concurrency."""
        sustained = (
            self._concurrency_sum / self._concurrency_n
            if self._concurrency_n else 0.0
        )
        return {"sustained": sustained, "peak": float(self.peak_concurrency)}

    def _prefill_slot(self, slot: int, req: Request) -> None:
        if self.prefix is not None and self.paged:
            shared = self._lookup_prefix(req.prompt, req.uid)
            self._count_prefix_lookup(shared)
            if shared:
                self._prefill_shared(slot, req, shared)
                return
        n = len(req.prompt)
        pad = max(0, self._bucket_len(n) - n) if self.bucket_prompts else 0
        tokens = np.pad(req.prompt, (0, pad)) if pad else req.prompt
        logits, cache = self._launch(
            self._prefill_fn, self.params, jnp.asarray(tokens[None, :])
        )
        if pad:
            # end-padding is causally inert for the cached prompt positions
            # (decode masks by pos), but prefill's returned logits sit at a
            # pad position.  Re-derive the first token's logits with one
            # decode step of the last prompt token at its true position; keep
            # the *prefill* cache verbatim (the decode's KV rewrite of pos
            # n-1 is the same value only up to low-precision rounding).
            fix_cache = {
                "pos": jnp.asarray([n - 1], jnp.int32),
                "segments": cache["segments"],
            }
            logits, _ = self._launch(
                self._fixup_fn, self.params,
                jnp.asarray(req.prompt[-1:][None, :]), fix_cache,
            )
        req_key = np.asarray(jax.random.fold_in(self._base_key, req.uid))
        tok = self._sample_token(np.asarray(logits, np.float32)[0], req_key, 0)
        req.generated.append(int(tok))
        self._slot_key[slot] = req_key
        self._slot_tok[slot] = tok
        if self.paged:
            if self._cache is None:
                self._cache = {
                    "segments": paged_mod.build_pool(
                        cache["segments"], self.allocator.num_pages,
                        self.page_size,
                    )
                }
                self._token_bytes = paged_mod.pool_token_bytes(
                    self._cache["segments"]
                )
            # map pages covering the prompt and scatter the prefill KV in;
            # the page for the first decode write arrives via _grow_to
            n_store = paged_mod.pages_for(len(req.prompt), self.page_size)
            pages = self.allocator.allocate(req.uid, n_store)
            self._table[slot] = paged_mod.TRASH_PAGE
            self._table[slot, :n_store] = pages
            self._mapped[slot] = n_store
            self._projected[slot] = self._projected_pages(req)
            self._cache["segments"] = paged_mod.scatter_prefill(
                self._cache["segments"], cache["segments"],
                jnp.asarray(pages, jnp.int32), self.page_size,
            )
            self._pos[slot] = len(req.prompt)
            self._seal_slot_pages(slot, len(req.prompt))
            if self.prefix is not None:
                self._publish_prefix(slot, req)
            return
        if self._cache is None:
            # allocate the batched cache (batch axis 1 under the layer stack)
            self._cache = {
                "segments": jax.tree.map(
                    lambda x: jnp.repeat(jnp.zeros_like(x), self.slots, axis=1),
                    cache["segments"],
                )
            }
            self._token_bytes = paged_mod.pool_token_bytes(
                self._cache["segments"]
            )
        # splice the slot cache into the batch cache
        def splice(full, one):
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)

        self._cache["segments"] = jax.tree.map(
            splice, self._cache["segments"], cache["segments"]
        )
        self._pos[slot] = len(req.prompt)

    def _prefill_shared(self, slot: int, req: Request,
                        shared: list[int]) -> None:
        """Prefill only the unshared suffix of ``req.prompt``.

        The shared pages hold exactly the KV a private prefill would have
        computed for those rows (KV row t depends only on tokens <= t, and
        page keys chain over the full token prefix), so seeding the staging
        cache from the pool and running one chunk with ``start=srows`` is
        row-for-row bitwise-identical to prefilling the whole prompt.  The
        suffix chunk always covers row n-1 (shared pages are capped at
        ``(n-1)//page_size``), so the first token's logits — chunked or
        pad-fixed — match the private path exactly.
        """
        n = len(req.prompt)
        s = len(shared)
        srows = s * self.page_size
        b = self._bucket_len(n) if self.bucket_prompts else n
        tokens = np.pad(req.prompt, (0, b - n)) if b > n else req.prompt
        staging = self._staging.get(slot)
        if staging is None:
            specs = self.model.cache_specs(1, self.max_len)["segments"]
            staging = jax.tree.map(
                lambda sp: jnp.zeros(sp.shape, sp.dtype), specs
            )
        prefix_kv = paged_mod.gather_pages(
            self._cache["segments"], np.asarray(shared, np.int64)
        )
        staging = paged_mod.scatter_rows(staging, prefix_kv, 0, self.page_size)
        cache = {"pos": jnp.asarray(srows, jnp.int32), "segments": staging}
        logits, cache = self._launch(
            self._chunk_fn, self.params,
            jnp.asarray(tokens[None, srows:b]), cache, start=srows,
        )
        if b > n:
            fix_cache = {
                "pos": jnp.asarray([n - 1], jnp.int32),
                "segments": cache["segments"],
            }
            logits, _ = self._launch(
                self._fixup_fn, self.params,
                jnp.asarray(req.prompt[-1:][None, :]), fix_cache,
            )
        req_key = np.asarray(jax.random.fold_in(self._base_key, req.uid))
        tok = self._sample_token(np.asarray(logits, np.float32)[0], req_key, 0)
        req.generated.append(int(tok))
        self._slot_key[slot] = req_key
        self._slot_tok[slot] = tok
        # all launches done — now mutate allocator/table state (FaultError
        # above this line leaves the engine untouched)
        n_store = paged_mod.pages_for(n, self.page_size)
        self._table[slot] = paged_mod.TRASH_PAGE
        self._attach_prefix(slot, req.uid, shared)
        priv = self.allocator.allocate(req.uid, n_store - s)
        self._table[slot, s:n_store] = priv
        self._mapped[slot] = n_store
        self._projected[slot] = self._projected_pages(req)
        self._cache["segments"] = paged_mod.scatter_chunk(
            self._cache["segments"], cache["segments"],
            jnp.asarray(self._table[slot], jnp.int32), srows, n - srows,
            self.page_size,
        )
        self._staging[slot] = cache["segments"]
        self._pos[slot] = n
        self._seal_slot_pages(slot, n)
        self._publish_prefix(slot, req)

    # -- chunked prefill (continuous batching) --------------------------------

    def _chunk_for_new(self, req: Request) -> int:
        """Chunk size a newly admitted request will prefill at (fixed for the
        request's whole prefill, so its trace set is independent of traffic)."""
        return self.chunk_policy.choose_chunk(
            live_decode=len(self._active), fusion_k=self._last_fusion_k
        )

    def _admit_chunked(self, req: Request) -> bool:
        """Paged admission for a chunked prefill: charge the *first chunk's*
        pages, not the whole prompt — the rest of the prompt is projected
        growth, reserve-scaled like decode growth.  This is what lets a new
        request join while long prompts are still streaming in."""
        chunk = self._chunk_for_new(req)
        shared = (len(self._lookup_prefix(req.prompt, req.uid))
                  if self.prefix is not None else 0)
        first = max(0, paged_mod.pages_for(
            min(len(req.prompt), shared * self.page_size + chunk),
            self.page_size,
        ) - shared)
        return self.admission.admit(
            free_pages=self.allocator.free_pages,
            projected_growth_pages=self._projected_growth(),
            request_pages=first,
        )

    def _start_chunked(self, slot: int, req: Request) -> None:
        """Admit ``req`` into ``slot`` as a chunked prefill."""
        n = len(req.prompt)
        b = self._bucket_len(n) if self.bucket_prompts else n
        tokens = np.pad(req.prompt, (0, b - n)) if b > n else req.prompt
        staging = self._staging.get(slot)
        if staging is None:
            specs = self.model.cache_specs(1, self.max_len)["segments"]
            staging = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), specs
            )
        # the staging tree is reused across occupants without re-zeroing:
        # chunk c only attends rows [0, end) written by chunks before it,
        # and decode masks rows >= pos — stale rows are never read with
        # nonzero weight, so they cannot perturb a single bit
        if self.paged and self._cache is None:
            self._cache = {
                "segments": paged_mod.build_pool(
                    staging, self.allocator.num_pages, self.page_size
                )
            }
            self._token_bytes = paged_mod.pool_token_bytes(
                self._cache["segments"]
            )
        shared: list[int] = []
        if self.prefix is not None and self.paged:
            shared = self._lookup_prefix(req.prompt, req.uid)
            self._count_prefix_lookup(shared)
        srows = len(shared) * self.page_size
        if shared:
            # seed the staging rows the shared pages cover; chunking then
            # starts at srows and never recomputes them — same bytes, fewer
            # launches (see _prefill_shared for why this is bit-exact)
            prefix_kv = paged_mod.gather_pages(
                self._cache["segments"], np.asarray(shared, np.int64)
            )
            staging = paged_mod.scatter_rows(
                staging, prefix_kv, 0, self.page_size
            )
        self._prefilling[slot] = _Prefilling(
            req=req, tokens=tokens, n=n, chunk=self._chunk_for_new(req),
            cache={"pos": jnp.asarray(srows, jnp.int32), "segments": staging},
            filled=srows,
        )
        if self.paged:
            self._table[slot] = paged_mod.TRASH_PAGE
            self._mapped[slot] = 0
            if shared:
                self._attach_prefix(slot, req.uid, shared)
            self._projected[slot] = self._projected_pages(req)

    def _chunk_step(self, slot: int, entry: _Prefilling) -> int:
        """Run one prefill chunk for ``slot``; rows processed (0 = stalled)."""
        req = entry.req
        b = len(entry.tokens)
        start = entry.filled
        size = min(entry.chunk, b - start)
        if self.paged:
            # fund this chunk's pages: only rows < n are ever scattered, so
            # the mapping target is the pages covering the new *real* rows.
            # A shortfall stalls the chunk — decode keeps running and frees
            # pages; total deadlock (nothing running at all) aborts the
            # youngest prefill back to the queue in the step loop.
            need = paged_mod.pages_for(min(start + size, entry.n),
                                       self.page_size)
            have = int(self._mapped[slot])
            if need > have:
                if self.allocator.free_pages < need - have:
                    entry.stalled = True
                    return 0
                pages = self.allocator.allocate(req.uid, need - have)
                self._table[slot, have:need] = pages
                self._mapped[slot] = need
        entry.stalled = False
        toks = jnp.asarray(entry.tokens[None, start:start + size])
        logits, entry.cache = self._launch(
            self._chunk_fn, self.params, toks, entry.cache, start=start
        )
        if self.paged and start < entry.n:
            # scatter only the chunk's real rows into their pages; pad rows
            # stay in staging (decode masks them, like the unchunked path)
            count = min(start + size, entry.n) - start
            self._cache["segments"] = paged_mod.scatter_chunk(
                self._cache["segments"], entry.cache["segments"],
                jnp.asarray(self._table[slot], jnp.int32), start, count,
                self.page_size,
            )
            self._seal_slot_pages(slot, min(start + size, entry.n))
        entry.filled += size
        if entry.filled >= b:
            self._finish_chunked(slot, entry, logits)
        return size

    def _finish_chunked(self, slot: int, entry: _Prefilling, logits) -> None:
        """Prompt fully prefilled: derive token 0 exactly as the unchunked
        path would, then move the request into the decode batch."""
        req, n = entry.req, entry.n
        pad = len(entry.tokens) - n
        if pad:
            # the last chunk's logits sit at a pad position — same fixup as
            # the unchunked path: one decode step of the last prompt token
            # at its true position, keeping the prefill cache verbatim
            fix_cache = {
                "pos": jnp.asarray([n - 1], jnp.int32),
                "segments": entry.cache["segments"],
            }
            logits, _ = self._launch(
                self._fixup_fn, self.params,
                jnp.asarray(req.prompt[-1:][None, :]), fix_cache,
            )
        req_key = np.asarray(jax.random.fold_in(self._base_key, req.uid))
        tok = self._sample_token(np.asarray(logits, np.float32)[0], req_key, 0)
        req.generated.append(int(tok))
        self._slot_key[slot] = req_key
        self._slot_tok[slot] = tok
        if not self.paged:
            if self._cache is None:
                self._cache = {
                    "segments": jax.tree.map(
                        lambda x: jnp.repeat(
                            jnp.zeros_like(x), self.slots, axis=1
                        ),
                        entry.cache["segments"],
                    )
                }
                self._token_bytes = paged_mod.pool_token_bytes(
                    self._cache["segments"]
                )

            def splice(full, one):
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one, slot, axis=1
                )

            self._cache["segments"] = jax.tree.map(
                splice, self._cache["segments"], entry.cache["segments"]
            )
        self._staging[slot] = entry.cache["segments"]
        self._pos[slot] = n
        if self.paged and self.prefix is not None:
            self._publish_prefix(slot, req)
        del self._prefilling[slot]
        self._active[slot] = req
        if req.first_token_t is None:
            self._first_this_step.append(req)

    def _sample_token(self, logits: np.ndarray, req_key: np.ndarray,
                      t: int) -> int:
        """Sample token ``t`` of one request from its position-indexed key.

        The same formula the fused scan applies on-device — greedy argmax, or
        ``categorical(fold_in(req_key, t), logits / T)`` — so host-sampled
        tokens (the prefill's first token) and scan-sampled tokens come from
        one deterministic stream.
        """
        if self.temperature <= 0:
            return int(np.argmax(logits))
        sub = jax.random.fold_in(jnp.asarray(req_key), t)
        return int(jax.random.categorical(
            sub, jnp.asarray(logits) / self.temperature
        ))

    # -- fused multi-token decode -------------------------------------------------

    def _fused_decode_fn(self, k: int):
        """Jitted ``lax.scan`` of ``k`` decode steps with on-device sampling.

        Carry is ``(segments, pos, tok, counts, active, remaining)`` —
        everything per-slot.  A slot whose budget runs out mid-scan is masked:
        its position freezes, its token holds, and the emitted-validity mask
        goes False, so the host splices exactly each request's remaining
        tokens.  (The masked slot's cache rows keep absorbing dummy writes at
        its frozen position; harmless, since a recycled slot's cache is
        replaced wholesale at the next prefill.)
        """
        fn = self._fused_cache.get(k)
        if fn is not None:
            return fn
        model, temp, paged = self.model, self.temperature, self.paged

        def sample(logits, keys, counts):
            if temp > 0:
                sub = jax.vmap(jax.random.fold_in)(keys, counts)
                return jax.vmap(
                    lambda row, s: jax.random.categorical(s, row / temp)
                )(logits, sub)
            return jnp.argmax(logits, axis=-1)

        def fused(params, segments, table, pos, tok, keys, counts, active,
                  remaining):
            def body(carry, _):
                segments, pos, tok, counts, active, remaining = carry
                cache = {"pos": pos, "segments": segments}
                if paged:
                    # the block table rides the whole scan unchanged: page
                    # growth happens on the host *between* launches (a
                    # launch is sized so it never outruns its mapped pages)
                    cache["block_table"] = table
                logits, new_cache = model.decode_step(
                    params, tok[:, None], cache
                )
                nxt = jnp.where(active, sample(logits, keys, counts).astype(jnp.int32), tok)
                emitted = active
                pos = jnp.where(active, pos + 1, pos)
                counts = jnp.where(active, counts + 1, counts)
                remaining = jnp.where(active, remaining - 1, remaining)
                active = active & (remaining > 0)
                carry = (new_cache["segments"], pos, nxt, counts, active, remaining)
                return carry, (nxt, emitted)

            carry0 = (segments, pos, tok, counts, active, remaining)
            carry, (toks, valid) = jax.lax.scan(body, carry0, None, length=k)
            segments, pos, tok, counts, _, _ = carry
            return segments, pos, tok, toks, valid

        fused.__name__ = f"decode_fused_k{k}" + ("_paged" if paged else "")
        fn = jax.jit(fused)
        fn.__name__ = fused.__name__
        self._fused_cache[k] = fn
        return fn

    #: launches without a new foreign sample before that producer's stale
    #: p99 stops throttling K (a tenant that left must not pin fusion low)
    FEEDBACK_STALE_LAUNCHES = 8

    def _contention_ledger(self):
        """Where foreign ``dispatch_wait`` samples actually land: the shared
        queue's ledger when routed through HSA (an explicit ``ledger=`` only
        carries this engine's memory accounting), else the explicit one."""
        if self._hsa_queue is not None and self._hsa_queue.ledger is not None:
            return self._hsa_queue.ledger
        return self.ledger

    def _observed_foreign_wait(self) -> float | None:
        """Worst recent p99 ``dispatch_wait`` among *other* producers on the
        shared ledger — the feedback FusionPolicy's contention signal.

        A producer whose sample count has not moved for
        ``FEEDBACK_STALE_LAUNCHES`` consecutive launches is ignored: the
        quantile window is count-bounded, so a tenant that burst during
        warmup and then went silent would otherwise hold K down forever.
        """
        led = self._contention_ledger()
        if led is None:
            return None
        worst = None
        for prod, cats in led.producer_breakdown().items():
            if prod == self._producer:
                continue
            stat = cats.get(ledger_mod.DISPATCH_WAIT)
            if stat is None or stat.count == 0:
                continue
            last, stale = self._wait_freshness.get(prod, (-1, 0))
            stale = stale + 1 if stat.count == last else 0
            self._wait_freshness[prod] = (stat.count, stale)
            if stale >= self.FEEDBACK_STALE_LAUNCHES:
                continue
            q = led.quantile(ledger_mod.DISPATCH_WAIT, 0.99, producer=prod)
            if q is not None and (worst is None or q > worst):
                worst = q
        return worst

    def _choose_fusion(self) -> int:
        """Fusion depth for this launch: the static knob, or the policy fed
        with live contention (foreign packets pending on the shared device —
        or, in feedback mode, the observed foreign p99 dispatch_wait) and
        the mean remaining budget of the active slots."""
        remaining = [
            r.max_new_tokens - len(r.generated) for r in self._active.values()
        ]
        if isinstance(self.decode_fusion, FusionPolicy):
            depth = 0
            if self._hsa_scheduler is not None:
                depth = sum(
                    q.pending() for q in self._hsa_scheduler.queues
                    if q is not self._hsa_queue
                )
            observed = (
                self._observed_foreign_wait()
                if self.decode_fusion.feedback else None
            )
            k = self.decode_fusion.choose_k(
                queue_depth=depth,
                mean_request_len=sum(remaining) / max(1, len(remaining)),
                observed_wait_s=observed,
            )
        else:
            k = int(self.decode_fusion)
        # never scan past every live slot's budget: those steps are all-masked
        return max(1, min(k, max(remaining, default=1)))

    def step(self) -> list[Request]:
        """Admit queued requests, run one prefill chunk per chunk-prefilling
        slot, then decode up to ``decode_fusion`` tokens for all live slots
        in one fused launch.

        Returns requests completed this step.
        """
        with self._lock:
            return self._step_locked()

    def _step_locked(self) -> list[Request]:
        self._first_this_step = []
        # integrity: draw this step's corruption injections on the pre-step
        # state, then spend the scrub budget — detections park their owners
        # before any launch can read the bad bytes
        self._inject_corruption()
        self._scrub_step()
        chunked = self.chunk_policy is not None
        prefill_tokens = 0
        for slot in range(self.slots):
            if slot in self._active or slot in self._prefilling:
                continue
            if self.paged and self._parked:
                # parked requests were admitted before anything still queued
                # (admission is FIFO), so they also resume first — and an
                # unresumable head blocks younger work exactly like the
                # queue head does.  A failed attempt is a no-op: the entry
                # stays parked until pages free up, never spins.
                if not self._try_resume(self._parked[0], slot):
                    break
                continue
            if not self._queue:
                break
            if self.paged:
                head = self._queue[0]
                admitted = (self._admit_chunked(head) if chunked
                            else self._admit_paged(head))
                if not admitted:
                    # head-of-line blocking is deliberate: skipping ahead to
                    # smaller requests would starve large ones forever
                    break
            req = self._queue.pop(0)
            if chunked:
                self._start_chunked(slot, req)
            else:
                try:
                    self._prefill_slot(slot, req)
                except FaultError as e:
                    if self.retry is None:
                        raise
                    # the prefill launch faulted before any engine state was
                    # touched: the request simply goes back to the queue head
                    # (FIFO preserved) for the next step, or fails on budget
                    req.fault_recoveries += 1
                    if req.fault_recoveries > self.retry.max_request_recoveries:
                        self._fail_request(req, e)
                    else:
                        self._queue.insert(0, req)
                    continue
                prefill_tokens += (self._bucket_len(len(req.prompt))
                                   if self.bucket_prompts else len(req.prompt))
                self._active[slot] = req
                if req.first_token_t is None:
                    self._first_this_step.append(req)

        # -- chunk phase: one prefill chunk per prefilling slot, oldest
        # first (uid order), so under page pressure the senior prefill funds
        # before junior ones and always makes progress ----------------------
        if self._prefilling:
            order = sorted(
                self._prefilling,
                key=lambda s: self._prefilling[s].req.uid,
            )
            for slot in order:
                try:
                    prefill_tokens += self._chunk_step(
                        slot, self._prefilling[slot]
                    )
                except FaultError as e:
                    if self.retry is None:
                        raise
                    self._abort_prefill_to_queue(slot, e)
            if (self.paged and self._prefilling and prefill_tokens == 0
                    and not self._active):
                # every prefill stalled and nothing is decoding: no pages
                # will free on their own.  Abort the youngest prefill back
                # into the queue (uid order preserved) — its pages fund the
                # senior ones, which then always complete (a lone admitted
                # request can fund any of its chunks by construction).
                slot = max(
                    self._prefilling,
                    key=lambda s: self._prefilling[s].req.uid,
                )
                entry = self._prefilling.pop(slot)
                self._release_slot(slot, entry.req)
                idx = next(
                    (i for i, r in enumerate(self._queue)
                     if r.uid > entry.req.uid),
                    len(self._queue),
                )
                self._queue.insert(idx, entry.req)

        finished = self._decode_locked() if self._active else []

        # -- tiered pool: issue H2D refills for parked snapshots nearing
        # resume *before* the clock advances — the step's modeled time then
        # hides the DMA, which is the whole ahead-of-need point ------------
        self._pump_refills()

        # -- engine clock: advance virtual time by the step's modeled cost,
        # then stamp this step's latency events at the new now --------------
        decode_tokens = self._decode_tokens_last
        self._decode_tokens_last = 0
        if (self.step_time_model is not None
                and getattr(self.clock, "virtual", False)):
            self.clock.advance(
                self.step_time_model(prefill_tokens, decode_tokens)
            )
        now = self.clock.now()
        for req in self._first_this_step:
            req.first_token_t = now
            if self.ledger is not None and req.arrival_t is not None:
                self.ledger.record(
                    ledger_mod.TTFT, now - req.arrival_t,
                    producer=self._producer, uid=req.uid,
                )
        for req in finished:
            req.finish_t = now
            if self.ledger is not None and req.first_token_t is not None:
                self.ledger.record(
                    ledger_mod.TPOT,
                    (req.finish_t - req.first_token_t)
                    / max(1, len(req.generated) - 1),
                    producer=self._producer, uid=req.uid,
                )
        self._record_memory()
        return finished

    #: decode tokens of the last fused launch (k × live slots) — the decode
    #: half of the step_time_model charge, reset by the step loop
    _decode_tokens_last = 0

    def _decode_locked(self) -> list[Request]:
        k = self._choose_fusion()
        if self.paged:
            # fund this launch's on-demand growth first: under overcommit
            # (growth_reserve < 1) the pool can run dry mid-decode, and the
            # answer is a shallower launch, then preemption — never
            # PagePoolExhausted
            k = self._fund_growth(k)
            if not self._active:
                return []                   # every live slot became a victim
            # re-cap to the survivors: if the longest-remaining slot was
            # parked, a depth-k scan past every survivor's budget would run
            # all-masked decode steps (growth stays funded — it was budgeted
            # for the larger k)
            k = max(1, min(k, max(
                r.max_new_tokens - len(r.generated)
                for r in self._active.values()
            )))
        n_live = len(self._active)          # post-preemption: slots decoding
        self._last_fusion_k = k
        self._decode_tokens_last = k * n_live
        self._concurrency_sum += n_live
        self._concurrency_n += 1
        self.peak_concurrency = max(self.peak_concurrency, n_live)
        counts = np.zeros(self.slots, np.int32)
        remaining = np.zeros(self.slots, np.int32)
        active = np.zeros(self.slots, bool)
        for slot, req in self._active.items():
            self._slot_tok[slot] = req.generated[-1]
            counts[slot] = len(req.generated)
            remaining[slot] = req.max_new_tokens - len(req.generated)
            active[slot] = remaining[slot] > 0
            if self.paged and remaining[slot] > 0:
                # on-demand growth, launch-granular: map through the last
                # position this launch can write for the slot (funded above)
                self._grow_to(slot, self._launch_pages(slot, req, k))
        # integrity: the sealed pages this launch will read, captured at
        # pre-launch positions — decode writes only the unsealed tail, so
        # any post-launch digest mismatch on these is silent corruption
        sealed_before: dict[int, list[int]] = {}
        if self.paged:
            sealed_before = {
                slot: self._sealed_pages(slot, int(self._pos[slot]))
                for slot in self._active
            }
        tbl = self._table if self.paged else None
        if self.paged and self._prefilling:
            # a mid-prefill slot already has real pages mapped, but it is not
            # in this launch's active set — its masked dummy writes must land
            # on the scratch page (as an unmapped slot's would), not on the
            # chunk rows already scattered into the pool
            tbl = tbl.copy()
            for pslot in self._prefilling:
                tbl[pslot] = paged_mod.TRASH_PAGE
        table = jnp.asarray(tbl) if self.paged else None
        # per-slot positions: continuous batching — slots joined at different
        # times decode against their own sequence positions
        try:
            segments, pos, tok, toks, valid = self._launch(
                self._fused_decode_fn(k), self.params, self._cache["segments"],
                table, jnp.asarray(self._pos, jnp.int32),
                jnp.asarray(self._slot_tok),
                jnp.asarray(self._slot_key), jnp.asarray(counts),
                jnp.asarray(active), jnp.asarray(remaining),
            )
        except FaultError as e:
            self._recover_decode_fault(e)
            return []
        # -- pre-commit read verification: re-hash the sealed pages this
        # launch read against their stamped digests in the *new* segments.
        # A mismatch parks the owner at its pre-launch state before the
        # wholesale position/token commit below — corrupt bytes never
        # influence a committed token, which is what makes the zero-escape
        # assertion structural rather than probabilistic ---------------------
        if self.paged:
            verify = (self.integrity is not None
                      and self.integrity.verify_reads)
            corrupt_slots: dict[int, list[int]] = {}
            for slot in list(self._active):
                bad: list[int] = []
                for p in sealed_before.get(slot, ()):
                    if verify and p in self._page_digests:
                        if (paged_mod.page_digest(segments, p)
                                != self._page_digests[p]):
                            bad.append(p)
                    elif p in self._live_corrupt_pages:
                        # verification off: this launch consumed known-bad
                        # bytes — the token about to commit is divergent
                        self._live_corrupt_pages.pop(p)
                        self._record_escape()
                if bad:
                    corrupt_slots[slot] = bad
            if corrupt_slots:
                all_bad = sorted(
                    {p for b in corrupt_slots.values() for p in b}
                )
                self._handle_corrupt_pages(all_bad, via="read")
        self._cache = {"segments": segments}
        self._pos = np.asarray(pos, np.int64)
        self._slot_tok = np.asarray(tok, np.int32).copy()
        toks = np.asarray(toks)                      # [k, slots]
        valid = np.asarray(valid)                    # [k, slots]

        finished = []
        for slot, req in list(self._active.items()):
            if slot in self._tainted_slots:
                # restored from a corrupted payload with verification off:
                # the stream is divergent from the first post-restore commit
                self._tainted_slots.discard(slot)
                self._record_escape()
            req.generated.extend(int(t) for t in toks[valid[:, slot], slot])
            if req.replay is not None:
                # re-prefill resume in flight: the regenerated stream must
                # match the committed tokens bit for bit — this is the
                # bitwise-identity claim, checked live, every launch
                n = min(len(req.generated), len(req.replay))
                if req.generated[:n] != req.replay[:n]:
                    raise RuntimeError(
                        f"preemption replay diverged at request {req.uid}: "
                        f"regenerated {req.generated[:n]} != committed "
                        f"{req.replay[:n]}"
                    )
                if len(req.generated) >= len(req.replay):
                    req.replay = None          # fully replayed: normal decode
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                if self.paged:
                    self._release_slot(slot, req)
                del self._active[slot]
        # stamp digests on pages this launch filled (write boundary: decode
        # page-crossing commit) — survivors only; finished slots released
        if self.paged and self.integrity is not None:
            for slot in self._active:
                self._seal_slot_pages(slot, int(self._pos[slot]))
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        """Step until every submitted request finishes; the completed requests.

        Raises :class:`ServeTruncated` if ``max_steps`` launches were not
        enough — truncation is never silently returned as success.  The
        report splits the unfinished work by cause: ``pending`` (active +
        admissible queue — transient), ``parked`` (preempted, resumable —
        transient), ``rejected`` (worst case can never fit the pool under
        the *current* admission policy — permanent; ``submit`` refuses these
        up front, so they only appear when the policy was tightened after
        submission), ``failed`` (killed by a hardware fault after the
        recovery budget was spent — permanent, raised as soon as the live
        work drains instead of spinning out ``max_steps``).  Transient pool
        exhaustion itself never raises: the engine preempts and resumes
        through it — and with an engine :class:`RetryPolicy`, transient
        hardware faults likewise never raise.
        """
        done: list[Request] = []
        for _ in range(max_steps):
            done += self.step()
            # classification and the stop check hold the lock so a feeder
            # thread's submit() lands either fully before the check (and is
            # admitted at the next step boundary) or fully after it — a
            # half-appended queue can never be misread as empty or rejected
            with self._lock:
                if (not self._active and not self._prefilling
                        and not self._queue and not self._parked):
                    if self._failed:
                        # fault-killed requests are permanent: raise the
                        # classification as soon as the live work drains
                        # instead of burning the remaining steps on no-ops
                        break
                    return done
                if not self._active and not self._prefilling and self.paged:
                    # nothing is running, so nothing will ever free pages: if
                    # the seniority head (parked before queued) can never
                    # fit, every further step is a no-op — fail fast with the
                    # classification instead of spinning out max_steps
                    head = (self._parked[0].req if self._parked
                            else self._queue[0] if self._queue else None)
                    if head is not None and self._never_fits(head):
                        break
        with self._lock:
            if (self._active or self._prefilling or self._queue
                    or self._parked or self._failed):
                pending = list(self._active.values()) + [
                    e.req for e in self._prefilling.values()
                ]
                parked: list[Request] = []
                rejected: list[Request] = []
                for req in self._queue:
                    if self.paged and self._never_fits(req):
                        rejected.append(req)
                    else:
                        pending.append(req)
                for entry in self._parked:
                    # a parked victim the tightened policy can never
                    # re-admit is just as permanently dead as an
                    # inadmissible queued request
                    if self._never_fits(entry.req):
                        rejected.append(entry.req)
                    else:
                        parked.append(entry.req)
                raise ServeTruncated(done, pending, parked=parked,
                                     rejected=rejected,
                                     failed=list(self._failed))
        return done
