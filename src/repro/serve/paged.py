"""Paged KV cache: block pool, page allocator, block tables.

The dense serving cache reserves ``[slots, max_len]`` KV rows per slot at
admission — a short request strands almost its whole reservation, and the
engine's concurrency ceiling is ``pool_bytes / (max_len · bytes_per_token)``
regardless of how long requests actually run.  The paged cache instead
treats KV memory the way the paper treats compute: a pool of
runtime-(re)assignable regions.  Pages are the memory analogue of the
paper's partially-reconfigurable regions — a fixed-size physical resource
bound to a logical tenant at runtime and returned to the pool the moment
the tenant finishes — so admission is bounded by *actual* footprint, not by
the worst-case reservation.

Layout
------
Each KV cache leaf ``[L, B, Hkv, max_len, hd]`` of the dense engine becomes
a pool leaf ``[L, P, Hkv, page_size, hd]``: axis 1 indexes *pages* instead
of slots.  A per-slot block table ``[slots, max_len/page_size]`` maps
logical page indices to pool pages; one table is shared by every layer and
every leaf (all layers cache the same positions).  Page 0 is reserved as a
scratch ("trash") page: unmapped table entries point at it, so the fused
decode scan's masked dummy writes (finished slots keep absorbing writes at
their frozen position — see ``ServeEngine._fused_decode_fn``) land
somewhere harmless instead of corrupting a live page.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """A page allocation found the pool empty.

    Unreachable when admission runs with ``AdmissionPolicy.growth_reserve``
    = 1.0 (every admitted request's worst-case page count is accounted
    before admission); possible under optimistic overcommit (< 1.0), where
    the caller decided the projection risk was acceptable.
    """


@dataclasses.dataclass
class PageStats:
    total_pages: int                 # usable pages (scratch page excluded)
    free_pages: int
    allocated_pages: int
    high_water: int                  # max simultaneously allocated
    allocs: int
    frees: int
    quarantined: int = 0             # retired after a digest mismatch
    shared_pages: int = 0            # pages with refcount > 1 right now
    shares: int = 0                  # cumulative share() grants


class PageAllocator:
    """Free-list allocator over the global block pool, with refcounts.

    Page 0 is never handed out (the scratch page for masked writes).
    A page may be held by *several* owners at once (prefix sharing maps one
    physical page into many block tables): ``allocate`` mints a page with
    one owner, ``share`` adds an owner to an allocated page, and ``free``
    drops one owner's reference — the page returns to the free-list only
    when its last reference goes.  Double-free, foreign-free, and
    double-share are hard errors so serving bugs surface as exceptions,
    not silent corruption.
    """

    def __init__(self, num_pages: int) -> None:
        if num_pages < 2:
            raise ValueError(
                f"need >= 2 pages (1 scratch + 1 usable), got {num_pages}"
            )
        self.num_pages = num_pages
        self._free: list[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self._owners: dict[int, set[int]] = {}  # page -> owner uids
        self._quarantined: set[int] = set()     # retired (digest mismatch)
        self._refs_outstanding = 0
        self._high_water = 0
        self._allocs = 0
        self._frees = 0
        self._shares = 0

    @property
    def total_pages(self) -> int:
        # scratch page is not usable; quarantined pages left circulation
        return self.num_pages - 1 - len(self._quarantined)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return len(self._owners)

    @property
    def shared_pages(self) -> int:
        """Pages currently held by more than one owner."""
        return sum(1 for owners in self._owners.values() if len(owners) > 1)

    def allocate(self, owner: int, n: int = 1) -> list[int]:
        """Take ``n`` pages for ``owner`` (a request uid). All-or-nothing."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"({self.allocated_pages}/{self.total_pages} allocated) — "
                "admission overcommitted (growth_reserve < 1.0)?"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owners[p] = {owner}
        self._allocs += n
        self._refs_outstanding += n
        self._high_water = max(self._high_water, len(self._owners))
        return pages

    def share(self, page: int, owner: int) -> None:
        """Add ``owner`` as a reader of an already-allocated ``page``.

        The page must be live (allocated to at least one other owner) and
        ``owner`` must not already hold it — sharing a free, quarantined,
        or already-held page is a hard error.
        """
        if page == TRASH_PAGE:
            raise ValueError("cannot share the scratch page")
        owners = self._owners.get(page)
        if owners is None:
            state = "quarantined" if page in self._quarantined else "free"
            raise ValueError(f"cannot share {state} page {page}")
        if owner in owners:
            raise ValueError(f"request {owner} already holds page {page}")
        owners.add(owner)
        self._shares += 1
        self._refs_outstanding += 1

    def free(self, owner: int, pages: list[int]) -> list[int]:
        """Drop ``owner``'s reference on each of ``pages``; every page must
        be held by ``owner``.  Returns the pages whose *last* reference was
        dropped — i.e. the ones actually returned to the free-list (callers
        keyed on physical pages, like digest stamps, must only forget those).
        """
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("cannot free the scratch page")
            owners = self._owners.get(p)
            if owners is None:
                raise ValueError(f"double free of page {p}")
            if owner not in owners:
                raise ValueError(
                    f"page {p} belongs to request(s) {sorted(owners)}, "
                    f"not {owner}"
                )
        released = []
        for p in pages:
            owners = self._owners[p]
            owners.discard(owner)
            self._refs_outstanding -= 1
            if not owners:
                del self._owners[p]
                self._free.append(p)
                released.append(p)
        self._frees += len(pages)
        return released

    def pages_of(self, owner: int) -> list[int]:
        return [p for p, o in self._owners.items() if owner in o]

    def owner_of(self, page: int) -> int | None:
        """One holder uid of ``page`` (the smallest, for determinism), or
        None if free/quarantined.  Use :meth:`owners_of` for all readers."""
        owners = self._owners.get(page)
        return min(owners) if owners else None

    def owners_of(self, page: int) -> set[int]:
        """All holder uids of ``page`` (empty if free/quarantined)."""
        return set(self._owners.get(page, ()))

    def refcount(self, page: int) -> int:
        return len(self._owners.get(page, ()))

    def quarantine(self, page: int) -> None:
        """Retire ``page`` from circulation after a digest mismatch.

        The page must currently be free (detection paths park/release
        *every* reader first — a shared page only reaches refcount zero
        once all of them let go); it never returns to the free list, so the
        pool permanently shrinks by one page — the hardware-honest model of
        a block whose storage can no longer be trusted.
        """
        if page == TRASH_PAGE:
            raise ValueError("cannot quarantine the scratch page")
        owners = self._owners.get(page)
        if owners:
            raise ValueError(
                f"page {page} still belongs to request(s) {sorted(owners)}; "
                "release every reader before quarantining"
            )
        try:
            self._free.remove(page)
        except ValueError:
            raise ValueError(
                f"page {page} is not in the pool (already quarantined?)"
            ) from None
        self._quarantined.add(page)

    @property
    def quarantined_pages(self) -> int:
        return len(self._quarantined)

    def stats(self) -> PageStats:
        return PageStats(
            total_pages=self.total_pages,
            free_pages=self.free_pages,
            allocated_pages=self.allocated_pages,
            high_water=self._high_water,
            allocs=self._allocs,
            frees=self._frees,
            quarantined=len(self._quarantined),
            shared_pages=self.shared_pages,
            shares=self._shares,
        )

    def check_invariants(self) -> None:
        """free + allocated + quarantined must tile the pool, no aliasing,
        and references must conserve: every allocated page has >= 1 owner
        and the per-page owner sets sum to the outstanding-reference
        counter (allocate/share increments, free decrements)."""
        allocated = set(self._owners)
        free = set(self._free)
        assert not (allocated & free), f"aliased pages {allocated & free}"
        assert not (self._quarantined & allocated), \
            f"quarantined pages owned {self._quarantined & allocated}"
        assert not (self._quarantined & free), \
            f"quarantined pages free {self._quarantined & free}"
        assert TRASH_PAGE not in allocated and TRASH_PAGE not in free
        assert TRASH_PAGE not in self._quarantined
        union = allocated | free | self._quarantined
        expect = set(range(1, self.num_pages))
        assert union == expect, f"leaked pages {expect - union}"
        assert all(self._owners.values()), "allocated page with no owner"
        refs = sum(len(o) for o in self._owners.values())
        assert refs == self._refs_outstanding, (
            f"refcount leak: {refs} held vs {self._refs_outstanding} "
            "outstanding"
        )


# ---------------------------------------------------------------------------
# pool construction / prefill scatter (pure-jax helpers the engine jits)
# ---------------------------------------------------------------------------


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to store ``tokens`` KV rows."""
    return -(-tokens // page_size)


def build_pool(slot_cache_segments, num_pages: int, page_size: int):
    """Zeroed pool tree from one slot's prefill cache ``segments`` tree.

    Each leaf ``[L, 1, Hkv, T, hd]`` maps to ``[L, num_pages, Hkv,
    page_size, hd]``; non-KV leaves are rejected upstream by the engine's
    paged-support check.
    """
    def leaf(x):
        L, _, H, _, hd = x.shape
        return jnp.zeros((L, num_pages, H, page_size, hd), x.dtype)

    return jax.tree.map(leaf, slot_cache_segments)


def scatter_prefill(pool_segments, slot_segments, pages: jax.Array,
                    page_size: int):
    """Write one slot's prefill cache into its freshly mapped pages.

    ``slot_segments`` leaves are ``[L, 1, Hkv, T, hd]`` with T >= n·ps;
    ``pages`` is the [n] array of pool pages covering positions
    ``[0, n·ps)``.  Page tails beyond the prompt hold prefill values of pad
    positions — causally inert, masked by ``length`` at attention time.
    """
    n = pages.shape[0]

    def leaf(pool, one):
        L, _, H, T, hd = one.shape
        src = one[:, 0, :, : n * page_size]                   # [L,H,n*ps,hd]
        src = src.reshape(L, H, n, page_size, hd).transpose(0, 2, 1, 3, 4)
        return pool.at[:, pages].set(src.astype(pool.dtype))

    return jax.tree.map(leaf, pool_segments, slot_segments)


def scatter_chunk(pool_segments, slot_segments, table_row: jax.Array,
                  start: int, count: int, page_size: int):
    """Write one prefill chunk's rows ``[start, start+count)`` into the pool.

    Chunk-granular sibling of :func:`scatter_prefill`: the rows land in
    whatever pages ``table_row`` (the slot's full block-table row) maps
    their positions to, page-alignment-free — a chunk may straddle a page
    boundary or fill the middle of a page another chunk started.  Only real
    prompt rows are scattered; pad rows stay in staging (attention masks
    them by ``length``, exactly like the unchunked path's page tails).
    """
    pos = start + jnp.arange(count)
    pages = table_row[pos // page_size]                       # [count]
    offs = pos % page_size                                    # [count]

    def leaf(pool, one):
        src = one[:, 0, :, start:start + count]               # [L,H,count,hd]
        src = jnp.moveaxis(src, 2, 0)                         # [count,L,H,hd]
        return pool.at[:, pages, :, offs].set(src.astype(pool.dtype))

    return jax.tree.map(leaf, pool_segments, slot_segments)


def scatter_rows(slot_segments, saved, start_row: int, page_size: int):
    """Write a :func:`gather_pages` tree into a dense staging cache.

    Inverse of the page gather for the *staging* layout: ``saved`` leaves
    are ``[L, n, Hkv, page_size, hd]`` page stacks; they land as rows
    ``[start_row, start_row + n·page_size)`` of the ``[L, 1, Hkv, T, hd]``
    staging leaves.  This is how a shared prefix already resident in the
    pool seeds the suffix-only prefill: the chunked-prefill contract wants
    previous rows in the staging cache, and pool pages hold exactly the
    bytes those rows would contain.
    """
    def leaf(one, sv):
        L, n, H, ps, hd = sv.shape
        rows = jnp.asarray(sv, one.dtype).transpose(0, 2, 1, 3, 4)
        rows = rows.reshape(L, H, n * ps, hd)
        return one.at[:, 0, :, start_row:start_row + n * ps].set(rows)

    return jax.tree.map(leaf, slot_segments, saved)


# ---------------------------------------------------------------------------
# prefix sharing: page-granular prompt hashing + the shared-page index
# ---------------------------------------------------------------------------
#
# The paper's Table II `if_not_configured` hit is a tenant finding its
# kernel already resident and paying nothing for reconfiguration.  The KV
# analogue: a request finding its prompt prefix already paged in and paying
# nothing to prefill it.  Prefixes are hashed per *full* page of prompt
# tokens with a rolling digest, so equal keys mean equal token histories —
# and, because KV rows at position t depend only on tokens [0, t], equal
# token histories mean bitwise-equal page contents.


def prefix_page_keys(tokens, page_size: int,
                     max_pages: int | None = None) -> list[bytes]:
    """Rolling digest chain over full pages of ``tokens``.

    ``keys[i]`` commits to tokens ``[0, (i+1)·page_size)`` — key equality
    between two prompts implies their first ``i+1`` pages of KV are
    bitwise-identical.  Only *full* pages get keys: a trailing partial page
    is never shared (decode writes land there).
    """
    toks = np.asarray(tokens, np.int64)
    full = len(toks) // page_size
    if max_pages is not None:
        full = min(full, max_pages)
    keys: list[bytes] = []
    prev = b""
    for i in range(full):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(toks[i * page_size:(i + 1) * page_size].tobytes())
        prev = h.digest()
        keys.append(prev)
    return keys


class PrefixIndex:
    """Prefix-key → resident pool page map (the KV "hit-rate" table).

    Holds *no* references of its own: an entry is only valid while the
    page is allocated, and the engine drops entries the moment ``free``
    reports the page released (or it is quarantined).  ``publish`` is
    first-wins — once a key maps to a live page, later prefills of the
    same prefix attach to it rather than replacing it.
    """

    def __init__(self) -> None:
        self._by_key: dict[bytes, int] = {}
        self._by_page: dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def get(self, key: bytes) -> int | None:
        return self._by_key.get(key)

    def publish(self, key: bytes, page: int) -> bool:
        """Map ``key`` to ``page`` unless the key is already published.
        Returns True when the entry was added."""
        if key in self._by_key:
            return False
        old = self._by_page.get(page)
        if old is not None:            # page recycled under a new prefix
            del self._by_key[old]
        self._by_key[key] = page
        self._by_page[page] = key
        return True

    def drop_page(self, page: int) -> None:
        """Forget the entry backed by ``page`` (page released/quarantined)."""
        key = self._by_page.pop(page, None)
        if key is not None:
            del self._by_key[key]

    def pages(self) -> set[int]:
        return set(self._by_page)


# ---------------------------------------------------------------------------
# page snapshot save/restore (preemption's zero-recompute resume path)
# ---------------------------------------------------------------------------


def gather_pages(pool_segments, pages: np.ndarray):
    """Copy ``pages`` of every pool leaf to host memory.

    Returns a tree of numpy arrays ``[L, n, Hkv, page_size, hd]`` — the
    victim's KV exactly as it sits in the pool.  The copy is bit-preserving
    (device → host of the same dtype), which is what lets a snapshot resume
    keep the engine's bitwise-identity guarantee without recomputing
    anything.
    """
    idx = jnp.asarray(pages, jnp.int32)
    return jax.tree.map(lambda pool: np.asarray(pool[:, idx]), pool_segments)


def restore_pages(pool_segments, saved, pages: np.ndarray):
    """Scatter a :func:`gather_pages` snapshot back into freshly mapped
    ``pages`` (the *physical* page ids may differ from the ones saved —
    the block table indirection is what makes that invisible)."""
    idx = jnp.asarray(pages, jnp.int32)
    return jax.tree.map(
        lambda pool, sv: pool.at[:, idx].set(jnp.asarray(sv, pool.dtype)),
        pool_segments, saved,
    )


def snapshot_bytes(saved) -> int:
    """Host bytes a :func:`gather_pages` snapshot holds while parked."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(saved))


# ---------------------------------------------------------------------------
# content digests: the integrity layer's ground truth
# ---------------------------------------------------------------------------
#
# A digest is stamped at a write boundary (prefill scatter, chunk scatter,
# decode page seal, arena store) and re-checked wherever the bytes are
# trusted again (decode reads, DMA completion, scrub).  blake2b-128 — fast
# in pure python-stdlib, collision-safe far beyond any pool size here.


def tree_digest(tree) -> bytes:
    """Content digest of a whole KV tree (a :func:`gather_pages` snapshot
    or any array pytree), leaf-order dependent like the tree itself."""
    h = hashlib.blake2b(digest_size=16)
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.digest()


def page_digest(pool_segments, page: int) -> bytes:
    """Content digest of one physical ``page`` across every pool leaf."""
    h = hashlib.blake2b(digest_size=16)
    for leaf in jax.tree.leaves(pool_segments):
        h.update(np.asarray(leaf[:, page]).tobytes())
    return h.digest()


def flip_page(pool_segments, page: int):
    """Pool tree with one byte of ``page`` flipped in the first leaf —
    the fault injector's model of a silent device-memory bit flip."""
    flipped = False

    def leaf(x):
        nonlocal flipped
        if flipped:
            return x
        flipped = True
        host = np.asarray(x[:, page]).copy()
        host.view(np.uint8).reshape(-1)[0] ^= 0xFF
        return x.at[:, page].set(jnp.asarray(host, x.dtype))

    return jax.tree.map(leaf, pool_segments)


def flip_tree(tree):
    """Copy of ``tree`` with one byte flipped in the first leaf — the
    injector's model of a DMA that completes but delivers wrong bytes."""
    flipped = False

    def leaf(x):
        nonlocal flipped
        host = np.asarray(x).copy()
        if not flipped:
            flipped = True
            host.view(np.uint8).reshape(-1)[0] ^= 0xFF
        return host

    return jax.tree.map(leaf, tree)


# ---------------------------------------------------------------------------
# host arena: the budgeted second tier of the page pool
# ---------------------------------------------------------------------------


class HostArenaExhausted(RuntimeError):
    """A snapshot store found the host arena past its byte budget.

    Only reachable when degradation is disabled (``SpillPolicy.allow_replay
    = False``): with replay allowed the engine demotes parked snapshots to
    re-prefill replay until the store fits, so the budget is a ceiling the
    arena never crosses rather than an error the caller sees.
    """


class HostArena:
    """Budgeted host-side tier for cold KV pages.

    The device pool (tier 0) holds hot pages; parked-request snapshots —
    and, by design, any future cold-page class (shared-prefix tails,
    beyond-window history) — spill D2H into this arena (tier 1).  Like the
    device :class:`PageAllocator` it is an explicit free-list over
    fixed-size blocks with tracked owners, so conservation is an assertable
    invariant rather than an accounting convention.  One block holds the
    bytes of one device page (``configure`` is called lazily once the
    engine knows its per-page byte size), which keeps the two tiers'
    accounting commensurable: N device pages spill into N host blocks.

    ``budget_bytes=None`` means unbounded (the pre-tiering behavior):
    blocks are minted on demand and the free-list stays exact, so the
    conservation invariants hold either way.  With a budget, a store that
    does not fit raises :class:`HostArenaExhausted`; callers degrade by
    demoting victims (see ``SpillPolicy``) before retrying.

    Entries are keyed by owner uid.  ``eviction_order()`` is store order,
    oldest first — the default victim scan for policies that do not rank
    by resume cost.
    """

    def __init__(self, budget_bytes: int | None = None) -> None:
        if budget_bytes is not None and budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self.block_bytes: int | None = None      # set lazily by configure()
        self._total_blocks = 0
        self._free: list[int] = []
        self._owner: dict[int, int] = {}         # block -> owner uid
        self._blocks: dict[int, list[int]] = {}  # uid -> its blocks
        self._data: dict[int, Any] = {}          # uid -> snapshot tree
        self._nbytes: dict[int, int] = {}        # uid -> actual bytes stored
        self._digest: dict[int, bytes] = {}      # uid -> store-time digest
        self._order: list[int] = []              # uids in store order
        self.peak_bytes = 0
        self.stores = 0
        self.discards = 0

    # -- sizing ------------------------------------------------------------

    def configure(self, block_bytes: int) -> None:
        """Fix the block size (bytes of one device page).  Idempotent; a
        conflicting re-configure is a hard error — resizing live blocks
        would silently break the free-list ↔ budget correspondence."""
        if self.block_bytes is not None:
            if block_bytes != self.block_bytes:
                raise ValueError(
                    f"arena already configured with block_bytes="
                    f"{self.block_bytes}, got {block_bytes}"
                )
            return
        if block_bytes < 1:
            raise ValueError(f"block_bytes must be >= 1, got {block_bytes}")
        self.block_bytes = block_bytes
        if self.budget_bytes is not None:
            self._total_blocks = self.budget_bytes // block_bytes
            self._free = list(range(self._total_blocks - 1, -1, -1))

    def blocks_for(self, nbytes: int) -> int:
        """Blocks needed to hold ``nbytes`` (at least one)."""
        if self.block_bytes is None:
            raise RuntimeError("arena not configured (block_bytes unset)")
        return max(1, -(-nbytes // self.block_bytes))

    # -- accounting --------------------------------------------------------

    @property
    def total_blocks(self) -> int:
        return self._total_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._owner)

    @property
    def used_bytes(self) -> int:
        """Actual snapshot bytes resident (<= used_blocks · block_bytes)."""
        return sum(self._nbytes.values())

    def fits(self, nbytes: int) -> bool:
        """Would a store of ``nbytes`` succeed right now?"""
        if self.budget_bytes is None:
            return True
        return self.blocks_for(nbytes) <= len(self._free)

    def can_ever_fit(self, nbytes: int) -> bool:
        """Would ``nbytes`` fit into an *empty* arena?  False means no
        amount of demotion helps — the entry must go straight to replay."""
        if self.budget_bytes is None:
            return True
        return self.blocks_for(nbytes) <= self._total_blocks

    # -- store / load / discard --------------------------------------------

    def holds(self, uid: int) -> bool:
        return uid in self._data

    def bytes_of(self, uid: int) -> int:
        return self._nbytes[uid]

    def entries(self) -> list[int]:
        """Resident uids in eviction order (oldest store first)."""
        return list(self._order)

    def store(self, uid: int, data: Any, nbytes: int,
              digest: bytes | None = None) -> None:
        """Park ``data`` (a :func:`gather_pages` tree) under ``uid``.

        ``digest`` stamps the block's content at its write boundary — the
        engine passes the *pre-transfer* digest, so corruption anywhere
        downstream (the D2H DMA, the arena's own storage) is caught by
        :meth:`verify` or by the refill-wait payload check.  ``None`` skips
        stamping (the integrity layer is off); ``verify`` then always
        passes."""
        if uid in self._data:
            raise ValueError(f"uid {uid} already holds an arena entry")
        need = self.blocks_for(nbytes)
        if self.budget_bytes is None:
            while len(self._free) < need:       # unbounded: mint blocks
                self._free.append(self._total_blocks)
                self._total_blocks += 1
        elif need > len(self._free):
            raise HostArenaExhausted(
                f"store of {nbytes} B ({need} blocks) over budget: "
                f"{len(self._free)}/{self._total_blocks} blocks free, "
                f"budget {self.budget_bytes} B"
            )
        blocks = [self._free.pop() for _ in range(need)]
        for b in blocks:
            self._owner[b] = uid
        self._blocks[uid] = blocks
        self._data[uid] = data
        self._nbytes[uid] = nbytes
        if digest is not None:
            self._digest[uid] = digest
        self._order.append(uid)
        self.stores += 1
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def load(self, uid: int) -> Any:
        """Peek the stored snapshot without freeing its blocks."""
        return self._data[uid]

    def digest_of(self, uid: int) -> bytes | None:
        """Store-time content digest of ``uid``'s block (None if the
        integrity layer never stamped one)."""
        return self._digest.get(uid)

    def verify(self, uid: int) -> bool:
        """Re-hash ``uid``'s stored tree against its store-time digest —
        the scrubber's arena probe.  True when unstamped or dataless."""
        expect = self._digest.get(uid)
        data = self._data.get(uid)
        if expect is None or data is None:
            return True
        return tree_digest(data) == expect

    def corrupt(self, uid: int) -> None:
        """Flip one byte of ``uid``'s stored snapshot (fault injection:
        host memory rotting under a parked block).  The store-time digest
        is untouched, so :meth:`verify` and the refill payload check both
        see the mismatch.  Device-backed leaves are immutable, so the
        flipped leaf is rebuilt as a host copy — byte-identical except for
        the one flipped bit."""
        data = self._data[uid]
        if data is None:
            raise ValueError(f"uid {uid} holds no payload to corrupt")
        leaves, treedef = jax.tree.flatten(data)
        host = np.array(leaves[0])
        host.view(np.uint8).reshape(-1)[0] ^= 0xFF
        leaves[0] = host
        self._data[uid] = jax.tree.unflatten(treedef, leaves)

    def discard(self, uid: int) -> int:
        """Drop ``uid``'s entry, return its blocks to the free-list.

        Returns the bytes freed — what a demotion gives back to the
        budget, and what the ledger prices the demotion at.
        """
        if uid not in self._data:
            raise ValueError(f"uid {uid} holds no arena entry")
        for b in self._blocks.pop(uid):
            del self._owner[b]
            self._free.append(b)
        del self._data[uid]
        self._digest.pop(uid, None)
        self._order.remove(uid)
        self.discards += 1
        return self._nbytes.pop(uid)

    def take(self, uid: int) -> Any:
        """Load + discard in one step (the refill-complete path)."""
        data = self._data[uid]
        self.discard(uid)
        return data

    # -- invariants --------------------------------------------------------

    def check_invariants(self) -> None:
        """free + owned must tile [0, total_blocks) exactly; per-entry
        block counts must match the byte accounting; budget never crossed."""
        owned = set(self._owner)
        free = set(self._free)
        assert not (owned & free), f"aliased blocks {owned & free}"
        union = owned | free
        expect = set(range(self._total_blocks))
        assert union == expect, (
            f"leaked blocks {expect - union} / phantom {union - expect}"
        )
        assert set(self._data) == set(self._blocks) == set(self._nbytes)
        assert set(self._digest) <= set(self._data), "orphaned digests"
        assert set(self._order) == set(self._data)
        assert len(self._order) == len(self._data)
        for uid, blocks in self._blocks.items():
            assert len(blocks) == self.blocks_for(self._nbytes[uid])
            assert all(self._owner[b] == uid for b in blocks)
        if self.budget_bytes is not None:
            assert self.used_blocks * (self.block_bytes or 0) \
                <= self.budget_bytes
            assert self.used_bytes <= self.budget_bytes


#: cache leaves with a position axis (the ones a page actually stores rows
#: of); recurrent state (ssm_state, conv_tail) has no per-token capacity
#: and is skipped by the memory accounting.
_TIME_INDEXED_KEYS = frozenset({"k", "v", "ckv", "krope", "mem_k", "mem_v"})


def pool_token_bytes(segments) -> int:
    """Bytes per cached token position across all time-indexed leaves.

    ``reserved = mapped_pages · page_size · pool_token_bytes`` is the
    engine's live KV reservation; the same per-token figure prices the
    dense engine's ``slots · max_len`` reservation, so the Table I-style
    utilization split compares like with like.  Leaves are [L, pages|B, H,
    ps|T, hd]: per-token bytes drop the two middle capacity axes.
    """
    import jax.tree_util as jtu

    total = 0

    def visit(path, leaf):
        nonlocal total
        last = path[-1]
        key = last.key if hasattr(last, "key") else str(last)
        if key in _TIME_INDEXED_KEYS and leaf.ndim >= 4:
            # [L, pages|B, ..., T, ...]: drop the capacity axes (1 and -2)
            per_token = int(np.prod(leaf.shape)) // (
                leaf.shape[1] * leaf.shape[-2]
            )
            total += per_token * leaf.dtype.itemsize

    jtu.tree_map_with_path(visit, segments)
    return total
