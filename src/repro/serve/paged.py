"""Paged KV cache: block pool, page allocator, block tables.

The dense serving cache reserves ``[slots, max_len]`` KV rows per slot at
admission — a short request strands almost its whole reservation, and the
engine's concurrency ceiling is ``pool_bytes / (max_len · bytes_per_token)``
regardless of how long requests actually run.  The paged cache instead
treats KV memory the way the paper treats compute: a pool of
runtime-(re)assignable regions.  Pages are the memory analogue of the
paper's partially-reconfigurable regions — a fixed-size physical resource
bound to a logical tenant at runtime and returned to the pool the moment
the tenant finishes — so admission is bounded by *actual* footprint, not by
the worst-case reservation.

Layout
------
Each KV cache leaf ``[L, B, Hkv, max_len, hd]`` of the dense engine becomes
a pool leaf ``[L, P, Hkv, page_size, hd]``: axis 1 indexes *pages* instead
of slots.  A per-slot block table ``[slots, max_len/page_size]`` maps
logical page indices to pool pages; one table is shared by every layer and
every leaf (all layers cache the same positions).  Page 0 is reserved as a
scratch ("trash") page: unmapped table entries point at it, so the fused
decode scan's masked dummy writes (finished slots keep absorbing writes at
their frozen position — see ``ServeEngine._fused_decode_fn``) land
somewhere harmless instead of corrupting a live page.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """A page allocation found the pool empty.

    Unreachable when admission runs with ``AdmissionPolicy.growth_reserve``
    = 1.0 (every admitted request's worst-case page count is accounted
    before admission); possible under optimistic overcommit (< 1.0), where
    the caller decided the projection risk was acceptable.
    """


@dataclasses.dataclass
class PageStats:
    total_pages: int                 # usable pages (scratch page excluded)
    free_pages: int
    allocated_pages: int
    high_water: int                  # max simultaneously allocated
    allocs: int
    frees: int


class PageAllocator:
    """Free-list allocator over the global block pool.

    Page 0 is never handed out (the scratch page for masked writes).
    Double-free and foreign-free are hard errors — a page's owner is
    tracked so serving bugs surface as exceptions, not silent corruption.
    """

    def __init__(self, num_pages: int) -> None:
        if num_pages < 2:
            raise ValueError(
                f"need >= 2 pages (1 scratch + 1 usable), got {num_pages}"
            )
        self.num_pages = num_pages
        self._free: list[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self._owner: dict[int, int] = {}        # page -> owner uid
        self._high_water = 0
        self._allocs = 0
        self._frees = 0

    @property
    def total_pages(self) -> int:
        return self.num_pages - 1               # scratch page is not usable

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return len(self._owner)

    def allocate(self, owner: int, n: int = 1) -> list[int]:
        """Take ``n`` pages for ``owner`` (a request uid). All-or-nothing."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"({self.allocated_pages}/{self.total_pages} allocated) — "
                "admission overcommitted (growth_reserve < 1.0)?"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        self._allocs += n
        self._high_water = max(self._high_water, len(self._owner))
        return pages

    def free(self, owner: int, pages: list[int]) -> None:
        """Return ``pages`` to the pool; every page must belong to ``owner``."""
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("cannot free the scratch page")
            got = self._owner.get(p)
            if got is None:
                raise ValueError(f"double free of page {p}")
            if got != owner:
                raise ValueError(
                    f"page {p} belongs to request {got}, not {owner}"
                )
        for p in pages:
            del self._owner[p]
            self._free.append(p)
        self._frees += len(pages)

    def pages_of(self, owner: int) -> list[int]:
        return [p for p, o in self._owner.items() if o == owner]

    def stats(self) -> PageStats:
        return PageStats(
            total_pages=self.total_pages,
            free_pages=self.free_pages,
            allocated_pages=self.allocated_pages,
            high_water=self._high_water,
            allocs=self._allocs,
            frees=self._frees,
        )

    def check_invariants(self) -> None:
        """free + allocated must tile the usable pool exactly, no aliasing."""
        allocated = set(self._owner)
        free = set(self._free)
        assert not (allocated & free), f"aliased pages {allocated & free}"
        assert TRASH_PAGE not in allocated and TRASH_PAGE not in free
        union = allocated | free
        expect = set(range(1, self.num_pages))
        assert union == expect, f"leaked pages {expect - union}"


# ---------------------------------------------------------------------------
# pool construction / prefill scatter (pure-jax helpers the engine jits)
# ---------------------------------------------------------------------------


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to store ``tokens`` KV rows."""
    return -(-tokens // page_size)


def build_pool(slot_cache_segments, num_pages: int, page_size: int):
    """Zeroed pool tree from one slot's prefill cache ``segments`` tree.

    Each leaf ``[L, 1, Hkv, T, hd]`` maps to ``[L, num_pages, Hkv,
    page_size, hd]``; non-KV leaves are rejected upstream by the engine's
    paged-support check.
    """
    def leaf(x):
        L, _, H, _, hd = x.shape
        return jnp.zeros((L, num_pages, H, page_size, hd), x.dtype)

    return jax.tree.map(leaf, slot_cache_segments)


def scatter_prefill(pool_segments, slot_segments, pages: jax.Array,
                    page_size: int):
    """Write one slot's prefill cache into its freshly mapped pages.

    ``slot_segments`` leaves are ``[L, 1, Hkv, T, hd]`` with T >= n·ps;
    ``pages`` is the [n] array of pool pages covering positions
    ``[0, n·ps)``.  Page tails beyond the prompt hold prefill values of pad
    positions — causally inert, masked by ``length`` at attention time.
    """
    n = pages.shape[0]

    def leaf(pool, one):
        L, _, H, T, hd = one.shape
        src = one[:, 0, :, : n * page_size]                   # [L,H,n*ps,hd]
        src = src.reshape(L, H, n, page_size, hd).transpose(0, 2, 1, 3, 4)
        return pool.at[:, pages].set(src.astype(pool.dtype))

    return jax.tree.map(leaf, pool_segments, slot_segments)


def scatter_chunk(pool_segments, slot_segments, table_row: jax.Array,
                  start: int, count: int, page_size: int):
    """Write one prefill chunk's rows ``[start, start+count)`` into the pool.

    Chunk-granular sibling of :func:`scatter_prefill`: the rows land in
    whatever pages ``table_row`` (the slot's full block-table row) maps
    their positions to, page-alignment-free — a chunk may straddle a page
    boundary or fill the middle of a page another chunk started.  Only real
    prompt rows are scattered; pad rows stay in staging (attention masks
    them by ``length``, exactly like the unchunked path's page tails).
    """
    pos = start + jnp.arange(count)
    pages = table_row[pos // page_size]                       # [count]
    offs = pos % page_size                                    # [count]

    def leaf(pool, one):
        src = one[:, 0, :, start:start + count]               # [L,H,count,hd]
        src = jnp.moveaxis(src, 2, 0)                         # [count,L,H,hd]
        return pool.at[:, pages, :, offs].set(src.astype(pool.dtype))

    return jax.tree.map(leaf, pool_segments, slot_segments)


# ---------------------------------------------------------------------------
# page snapshot save/restore (preemption's zero-recompute resume path)
# ---------------------------------------------------------------------------


def gather_pages(pool_segments, pages: np.ndarray):
    """Copy ``pages`` of every pool leaf to host memory.

    Returns a tree of numpy arrays ``[L, n, Hkv, page_size, hd]`` — the
    victim's KV exactly as it sits in the pool.  The copy is bit-preserving
    (device → host of the same dtype), which is what lets a snapshot resume
    keep the engine's bitwise-identity guarantee without recomputing
    anything.
    """
    idx = jnp.asarray(pages, jnp.int32)
    return jax.tree.map(lambda pool: np.asarray(pool[:, idx]), pool_segments)


def restore_pages(pool_segments, saved, pages: np.ndarray):
    """Scatter a :func:`gather_pages` snapshot back into freshly mapped
    ``pages`` (the *physical* page ids may differ from the ones saved —
    the block table indirection is what makes that invisible)."""
    idx = jnp.asarray(pages, jnp.int32)
    return jax.tree.map(
        lambda pool, sv: pool.at[:, idx].set(jnp.asarray(sv, pool.dtype)),
        pool_segments, saved,
    )


def snapshot_bytes(saved) -> int:
    """Host bytes a :func:`gather_pages` snapshot holds while parked."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(saved))


#: cache leaves with a position axis (the ones a page actually stores rows
#: of); recurrent state (ssm_state, conv_tail) has no per-token capacity
#: and is skipped by the memory accounting.
_TIME_INDEXED_KEYS = frozenset({"k", "v", "ckv", "krope", "mem_k", "mem_v"})


def pool_token_bytes(segments) -> int:
    """Bytes per cached token position across all time-indexed leaves.

    ``reserved = mapped_pages · page_size · pool_token_bytes`` is the
    engine's live KV reservation; the same per-token figure prices the
    dense engine's ``slots · max_len`` reservation, so the Table I-style
    utilization split compares like with like.  Leaves are [L, pages|B, H,
    ps|T, hd]: per-token bytes drop the two middle capacity axes.
    """
    import jax.tree_util as jtu

    total = 0

    def visit(path, leaf):
        nonlocal total
        last = path[-1]
        key = last.key if hasattr(last, "key") else str(last)
        if key in _TIME_INDEXED_KEYS and leaf.ndim >= 4:
            # [L, pages|B, ..., T, ...]: drop the capacity axes (1 and -2)
            per_token = int(np.prod(leaf.shape)) // (
                leaf.shape[1] * leaf.shape[-2]
            )
            total += per_token * leaf.dtype.itemsize

    jtu.tree_map_with_path(visit, segments)
    return total
