"""Flash attention (online-softmax, KV-blocked) — the attention role.

TPU-native adaptation:

  - Q/K/V tiles stream HBM→VMEM under explicit BlockSpecs; the running
    (max, denominator, accumulator) state lives in VMEM scratch and is carried
    across the KV grid axis (innermost), so logits never materialize in HBM —
    the classic O(S²) → O(S) memory rewrite, expressed for the MXU with
    128-aligned q/k blocks.
  - GQA is folded into the index maps: the K/V BlockSpecs map query head ``h``
    to kv head ``h // group`` — no repeated KV materialization.
  - ``causal`` + ``window`` masking happens block-wise: invisible blocks are
    skipped via ``pl.when`` (on TPU this prunes whole MXU passes; ~2× for
    causal), visible-but-partial blocks mask elementwise at -1e30.
  - ``kv_offset = T - S`` places queries at the end of the KV axis, which makes
    the same kernel serve prefill (S == T), chunked prefill (S < T), and
    sliding-window decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import ResourceFootprint

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_k: int,
    causal: bool,
    window: int | None,
    kv_offset: int,
) -> None:
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- block-level visibility (static grid, dynamic skip) -------------------
    q_start = qi * block_q + kv_offset          # first query position on kv axis
    q_end = q_start + block_q - 1
    k_start = ki * block_k
    k_end = k_start + block_k - 1
    visible = True
    if causal:
        visible = jnp.logical_and(visible, k_start <= q_end)
    if window is not None:
        visible = jnp.logical_and(visible, k_end > q_start - window)

    @pl.when(visible)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # [bq, bk]
        correction = jnp.exp(m_prev - m_new)          # [bq, 1]
        l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * correction + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                   # [B, Hq, S, D]
    k: jax.Array,                   # [B, Hkv, T, D]
    v: jax.Array,                   # [B, Hkv, T, D]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    bq, bk = min(block_q, S), min(block_k, T)
    if S % bq or T % bk:
        raise ValueError(f"S={S} T={T} not divisible by blocks ({bq},{bk})")
    scale = scale if scale is not None else 1.0 / float(np.sqrt(D))
    n_k = T // bk
    kv_offset = T - S

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        block_q=bq,
        block_k=bk,
        n_k=n_k,
        causal=causal,
        window=window,
        kv_offset=kv_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, S // bq, n_k),                         # kv innermost
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),               # running max
            pltpu.VMEM((bq, 1), jnp.float32),               # running denominator
            pltpu.VMEM((bq, D), jnp.float32),               # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def footprint(block_q: int = 256, block_k: int = 256, d: int = 128,
              itemsize: int = 2) -> ResourceFootprint:
    vmem = (
        block_q * d * itemsize            # q tile
        + 2 * block_k * d * itemsize      # k, v tiles
        + block_q * d * 4                 # accumulator
        + 2 * block_q * 4                 # m, l
        + block_q * block_k * 4           # logits tile
        + block_q * d * itemsize          # out tile
    )
    return ResourceFootprint(
        vmem_bytes=vmem,
        mxu_tiles=2 * (block_q // 128) * (block_k // 128) * max(1, d // 128),
    )
