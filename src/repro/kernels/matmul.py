"""Blocked MXU matmul — the "fully connected" roles (paper Table I, roles 1/2).

TPU-native design notes (the FPGA → TPU hardware adaptation):

  - The FPGA role streams activations through DSP slices; the TPU analogue is
    feeding the 128×128 MXU systolic array from VMEM.  Block shapes are
    multiples of 128 on the M/N/K matmul dims so every pass fills the array.
  - VMEM is the reconfigurable-region budget here: the working set per grid
    step is ``bm*bk + bk*bn + bm*bn(acc)`` elements and must fit well inside
    128 MiB; defaults (256, 256, 512) use ~1.6 MiB at bf16 — deliberately small
    so several "roles" can stay co-resident, mirroring the paper's multi-role
    regions.
  - Accumulation is f32 in a VMEM scratch accumulator across the K grid axis
    (K innermost → the accumulator is revisited, never spilled to HBM).
  - ``activation`` fuses the epilogue (silu/gelu) into the same kernel — the
    "fixed function" efficiency the paper gets from specialized roles.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import ResourceFootprint


def _epilogue(acc: jax.Array, activation: str | None) -> jax.Array:
    if activation is None:
        return acc
    if activation == "silu":
        return acc * jax.nn.sigmoid(acc)
    if activation == "gelu":
        return jax.nn.gelu(acc)
    raise ValueError(f"unknown activation {activation!r}")


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, activation: str | None,
               out_dtype) -> None:
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == n_k - 1)
    def _finalize():
        o_ref[...] = _epilogue(acc_ref[...], activation).astype(out_dtype)


def matmul(
    x: jax.Array,                       # [M, K]
    w: jax.Array,                       # [K, N]
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype: jnp.dtype | None = None,
    activation: str | None = None,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(
            f"shape ({M},{K})x({K},{N}) not divisible by blocks ({bm},{bn},{bk})"
        )
    out_dtype = out_dtype or x.dtype
    n_k = K // bk

    kernel = functools.partial(
        _mm_kernel, n_k=n_k, activation=activation, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),                       # K innermost
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def matmul_fixed_weight(
    w: jax.Array,
    **kw,
) -> Callable[..., jax.Array]:
    """Fixed-weight role factory: weights baked into the program (paper §IV).

    The returned callable closes over ``w`` as a compile-time constant, so the
    compiled executable is weight-specialized — one role per layer, faster
    (weights pre-resident in the program image), but each layer now needs its
    own region.  The role planner decides when this pays off.
    """

    def fixed(x: jax.Array, *, interpret: bool = False) -> jax.Array:
        return matmul(x, w, interpret=interpret, **kw)

    fixed.__name__ = f"matmul_fixed_{w.shape[0]}x{w.shape[1]}"
    return fixed


def footprint(
    block_m: int = 256, block_n: int = 256, block_k: int = 512,
    itemsize: int = 2,
) -> ResourceFootprint:
    vmem = (
        block_m * block_k * itemsize
        + block_k * block_n * itemsize
        + block_m * block_n * 4                 # f32 accumulator
        + block_m * block_n * itemsize          # output block
    )
    return ResourceFootprint(
        vmem_bytes=vmem,
        mxu_tiles=(block_m // 128) * (block_n // 128) * (block_k // 128),
    )
