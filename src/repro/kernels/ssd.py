"""Mamba-2 SSD (state-space duality) chunked-scan kernel.

The SSD insight: a selective-SSM recurrence over a chunk of Q tokens is a
*masked matmul* (quadratic-in-Q, MXU-friendly) plus a rank-1 state carry across
chunks (linear in sequence).  That is exactly the right decomposition for the
TPU: intra-chunk work fills the 128×128 MXU; the inter-chunk state ([P, N] per
head) lives in VMEM scratch and is carried across the chunk grid axis
(innermost), so the sequential part never touches HBM.

Per chunk (all f32, decay factors are ≤ 1 so no overflow):

  cum[i]   = Σ_{k≤i} dt_k·a_log                       (running log-decay)
  M[i,j]   = (C_i·B_j) · exp(cum[i] − cum[j]) · 1[i≥j]
  Y_intra  = M @ (dt ⊙ X)                              [Q,Q]@[Q,P]
  Y_inter  = exp(cum) ⊙ (C @ h_prevᵀ)                  [Q,N]@[N,P]
  h_new    = exp(cum[Q−1])·h_prev + (w ⊙ dt ⊙ X)ᵀ @ B  [P,Q]@[Q,N],
             w_j = exp(cum[Q−1] − cum[j])
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import ResourceFootprint


def _ssd_kernel(
    x_ref, b_ref, c_ref, dt_ref, alog_ref,
    y_ref, state_ref,
    h_scratch,
    *,
    chunk: int,
    n_chunks: int,
) -> None:
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0, :, 0].astype(jnp.float32)          # [Q, P]
    b = b_ref[0, :, 0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0, :, 0].astype(jnp.float32)          # [Q, N]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    a_log = alog_ref[0].astype(jnp.float32)         # scalar

    cum = jnp.cumsum(dt * a_log)                    # [Q], non-increasing
    dtx = x * dt[:, None]                           # [Q, P]

    # intra-chunk masked matmul (exponent clamped: see ops.xla_ssd note)
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)          # [Q, Q]
    decay = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))   # [Q, Q]
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(i_idx >= j_idx, g * decay, 0.0)
    y = jnp.dot(m, dtx, preferred_element_type=jnp.float32)          # [Q, P]

    # inter-chunk contribution from carried state
    h_prev = h_scratch[...]                                          # [P, N]
    y += jnp.exp(cum)[:, None] * jnp.dot(
        c, h_prev.T, preferred_element_type=jnp.float32
    )

    # state carry
    w = jnp.exp(cum[-1] - cum)                                       # [Q]
    h_scratch[...] = jnp.exp(cum[-1]) * h_prev + jnp.dot(
        (dtx * w[:, None]).T, b, preferred_element_type=jnp.float32
    )

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = h_scratch[...]


def ssd(
    x: jax.Array,                   # [B, S, H, P]
    a_log: jax.Array,               # [H]
    b: jax.Array,                   # [B, S, G, N]
    c: jax.Array,                   # [B, S, G, N]
    dt: jax.Array,                  # [B, S, H]
    *,
    chunk: int = 256,
    return_state: bool = False,
    interpret: bool = False,
):
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G
    q = min(chunk, S)
    if S % q:
        raise ValueError(f"S={S} not divisible by chunk={q}")
    n_chunks = S // q

    kernel = functools.partial(_ssd_kernel, chunk=q, n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),                      # chunk innermost
        in_specs=[
            pl.BlockSpec((1, q, 1, P), lambda bb, h, cc: (bb, cc, h, 0)),
            pl.BlockSpec((1, q, 1, N), lambda bb, h, cc: (bb, cc, h // rep, 0)),
            pl.BlockSpec((1, q, 1, N), lambda bb, h, cc: (bb, cc, h // rep, 0)),
            pl.BlockSpec((1, q, 1), lambda bb, h, cc: (bb, cc, h)),
            pl.BlockSpec((1,), lambda bb, h, cc: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, P), lambda bb, h, cc: (bb, cc, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bb, h, cc: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, b, c, dt, a_log)
    if return_state:
        return y, state
    return y


def footprint(chunk: int = 256, p: int = 64, n: int = 128,
              itemsize: int = 2) -> ResourceFootprint:
    vmem = (
        chunk * p * itemsize          # x tile
        + 2 * chunk * n * itemsize    # b, c tiles
        + chunk * chunk * 4           # masked matmul tile
        + p * n * 4                   # carried state
        + chunk * p * 4               # y accumulator
    )
    return ResourceFootprint(
        vmem_bytes=vmem,
        mxu_tiles=3 * (chunk // 128) * max(1, n // 128),
    )
