"""Registered kernel implementations — the role catalogue.

Importing this module populates ``GLOBAL_REGISTRY`` with three sources per op:

  - ``reference``: pure-jnp oracle (ref.py),
  - ``xla``: production XLA formulation (memory-efficient where it matters —
    chunked attention for 32k prefill, chunked SSD scan),
  - ``pallas``: the hand-written TPU kernel (the presynthesized role).

Model code never imports these directly; it calls ``dispatch.op(name, ...)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import GLOBAL_REGISTRY as REG
from repro.kernels import conv2d as conv2d_k
from repro.kernels import flash_attention as fa_k
from repro.kernels import matmul as matmul_k
from repro.kernels import ref
from repro.kernels import rmsnorm as rmsnorm_k
from repro.kernels import ssd as ssd_k

# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------


def xla_matmul(x, w, *, out_dtype=None, activation=None):
    """Emits the input dtype directly for bf16 inputs: the TPU MXU
    accumulates in f32 internally either way, and an f32 dot output +
    convert doubles the tensor's HBM traffic at every fusion boundary."""
    target = out_dtype or x.dtype
    pet = jnp.float32 if target == jnp.float32 else x.dtype
    acc = jnp.dot(x, w, preferred_element_type=pet)
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc.astype(jnp.float32)).astype(acc.dtype)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation is not None:
        raise ValueError(activation)
    return acc.astype(target)


def _fit_block(dim: int, target: int) -> int:
    b = min(target, dim)
    while dim % b:
        b //= 2
        if b < 8:
            return dim  # single block
    return b


def pallas_matmul(x, w, *, out_dtype=None, activation=None, interpret: bool = False):
    """Reshapes batched x to 2-D and picks dividing block sizes."""
    *lead, K = x.shape
    M = int(np.prod(lead)) if lead else 1
    N = w.shape[-1]
    bm, bn, bk = _fit_block(M, 256), _fit_block(N, 256), _fit_block(K, 512)
    out = matmul_k.matmul(
        x.reshape(M, K), w, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, activation=activation, interpret=interpret,
    )
    return out.reshape(*lead, N)


REG.register(
    __import__("repro.core.registry", fromlist=["KernelImpl"]).KernelImpl(
        op="matmul", device_kind="any", source="reference", fn=ref.matmul,
    )
)
from repro.core.registry import KernelImpl  # noqa: E402

REG.register(KernelImpl(op="matmul", device_kind="any", source="xla", fn=xla_matmul))
REG.register(
    KernelImpl(
        op="matmul", device_kind="tpu", source="pallas", fn=pallas_matmul,
        footprint=matmul_k.footprint(),
    )
)

# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

REG.register(KernelImpl(op="rmsnorm", device_kind="any", source="reference", fn=ref.rmsnorm))
REG.register(KernelImpl(op="rmsnorm", device_kind="any", source="xla", fn=ref.rmsnorm))
REG.register(
    KernelImpl(
        op="rmsnorm", device_kind="tpu", source="pallas", fn=rmsnorm_k.rmsnorm,
        footprint=rmsnorm_k.footprint(),
    )
)

# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


def xla_flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    scale: float | None = None, block_q: int = 512,
):
    """Memory-efficient exact attention: lax.map over query chunks.

    Peak memory is O(block_q · T) per (batch, head) instead of O(S · T) — the
    property that lets 32k-token prefill fit HBM. Equivalent to ref for all
    mask settings (golden-tested).
    """
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    group = Hq // Hkv
    scale_ = scale if scale is not None else 1.0 / float(np.sqrt(D))
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    n_blocks = S // bq
    kv_offset = T - S

    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    kpos = jnp.arange(T)[None, :]

    def one_block(i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=2)        # [B,H,bq,D]
        # f32 softmax statistics; probs stored in the compute dtype for the
        # PV matmul — the all-f32 chain doubled attention HBM traffic
        logits = jnp.einsum("bhsd,bhtd->bhst", qb, kg,
                            preferred_element_type=jnp.float32) * scale_
        qpos = (i * bq + jnp.arange(bq) + kv_offset)[:, None]
        mask = jnp.ones((bq, T), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bhtd->bhsd", probs, vg,
                          preferred_element_type=jnp.float32)

    from repro.roofline.unrolling import inner_loops_unrolled

    Dv = v.shape[-1]                    # MLA: d_v may differ from d_qk
    if n_blocks == 1:
        out = one_block(jnp.asarray(0))
    elif inner_loops_unrolled():        # cost-mode: straight-line for FLOP counting
        out = jnp.stack([one_block(jnp.asarray(i)) for i in range(n_blocks)])
        out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, S, Dv)
    else:
        out = jax.lax.map(one_block, jnp.arange(n_blocks))              # [n,B,H,bq,Dv]
        out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, S, Dv)
    return out.astype(q.dtype)


REG.register(
    KernelImpl(op="flash_attention", device_kind="any", source="reference",
               fn=ref.flash_attention)
)
REG.register(
    KernelImpl(op="flash_attention", device_kind="any", source="xla",
               fn=xla_flash_attention)
)
REG.register(
    KernelImpl(
        op="flash_attention", device_kind="tpu", source="pallas",
        fn=fa_k.flash_attention, footprint=fa_k.footprint(),
    )
)

# --------------------------------------------------------------------------
# decode attention (single-token query over a padded KV cache)
# --------------------------------------------------------------------------

def xla_decode_attention(q, k_cache, v_cache, length, *, scale=None):
    """Grouped-GQA decode attention: cache read once in its storage dtype
    (no head-repeat materialization, no f32 cache upcast)."""
    B, Hq, D = q.shape
    Hkv, T = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale_ = scale if scale is not None else 1.0 / float(np.sqrt(D))
    qg = q.reshape(B, Hkv, group, D)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale_
    lengths = jnp.asarray(length)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    valid = jnp.arange(T)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    probs = jnp.exp(logits - m)
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,bktd->bkgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return (out / denom).reshape(B, Hq, D).astype(q.dtype)


REG.register(
    KernelImpl(op="decode_attention", device_kind="any", source="reference",
               fn=ref.decode_attention)
)
REG.register(
    KernelImpl(op="decode_attention", device_kind="any", source="xla",
               fn=xla_decode_attention)
)

from repro.kernels import decode_attention as dec_k  # noqa: E402

REG.register(
    KernelImpl(
        op="decode_attention", device_kind="tpu", source="pallas",
        fn=dec_k.decode_attention, footprint=dec_k.footprint(),
    )
)

# --------------------------------------------------------------------------
# paged decode attention (block-table KV gather)
# --------------------------------------------------------------------------


def xla_paged_decode_attention(q, k_pages, v_pages, block_table, length, *,
                               scale=None):
    """Gather-then-dense formulation: ``jnp.take`` reassembles the sequence's
    pages into the dense [B, Hkv, T, hd] layout, then the grouped-GQA dense
    decode attention runs unchanged.  Because the gather is arithmetic-free
    and the downstream math is *the same function*, the result is
    bitwise-identical to :func:`xla_decode_attention` over an equivalent
    dense cache — the property the paged serving engine's equivalence
    guarantee rests on.  (The Pallas kernel instead resolves pages on the
    HBM→VMEM stream and never materializes the dense copy.)"""
    kg = ref.gather_kv_pages(k_pages, block_table)
    vg = ref.gather_kv_pages(v_pages, block_table)
    return xla_decode_attention(q, kg, vg, length, scale=scale)


REG.register(
    KernelImpl(op="paged_decode_attention", device_kind="any",
               source="reference", fn=ref.paged_decode_attention)
)
REG.register(
    KernelImpl(op="paged_decode_attention", device_kind="any", source="xla",
               fn=xla_paged_decode_attention)
)
REG.register(
    KernelImpl(
        op="paged_decode_attention", device_kind="tpu", source="pallas",
        fn=dec_k.paged_decode_attention, footprint=dec_k.paged_footprint(),
    )
)

# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

REG.register(KernelImpl(op="conv2d", device_kind="any", source="reference", fn=ref.conv2d))
REG.register(KernelImpl(op="conv2d", device_kind="any", source="xla", fn=ref.conv2d))
REG.register(
    KernelImpl(
        op="conv2d", device_kind="tpu", source="pallas", fn=conv2d_k.conv2d,
        footprint=conv2d_k.footprint(),
    )
)

# --------------------------------------------------------------------------
# ssd (Mamba-2 state-space duality)
# --------------------------------------------------------------------------


def xla_ssd(x, a_log, b, c, dt, *, chunk: int = 256, initial_state=None,
            return_state: bool = False):
    """Chunked SSD in pure XLA: scan over chunk states, matmuls within chunks.

    Same decomposition as the Pallas kernel, vectorized over (B, H); the
    sequential dimension is S/chunk instead of S, preserving MXU-sized matmuls.
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    q = min(chunk, S)
    while S % q:
        q //= 2
    n_chunks = S // q

    xf = x.astype(jnp.float32).reshape(B, n_chunks, q, H, P)
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2).reshape(B, n_chunks, q, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2).reshape(B, n_chunks, q, H, N)
    dtf = dt.astype(jnp.float32).reshape(B, n_chunks, q, H)
    a = a_log.astype(jnp.float32)

    cum = jnp.cumsum(dtf * a[None, None, None, :], axis=2)              # [B,n,q,H]
    dtx = xf * dtf[..., None]                                           # [B,n,q,H,P]

    # intra-chunk masked matmul. The exponent is clamped to <= 0: upper-
    # triangle (future) pairs would overflow exp and poison the backward pass
    # through the where-mask; valid (i >= j) pairs are always <= 0.
    g = jnp.einsum("bnqhm,bnkhm->bnhqk", cf, bf)                        # [B,n,H,q,q]
    delta = jnp.minimum(cum[:, :, :, None] - cum[:, :, None, :], 0.0)   # i - j
    decay = jnp.exp(delta).transpose(0, 1, 4, 2, 3)                     # [B,n,H,q,q]
    tri = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(tri[None, None, None], g * decay, 0.0)
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", m, dtx)

    # per-chunk state contribution and carried scan over chunks
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)                            # [B,n,q,H]
    s_chunk = jnp.einsum("bnqhp,bnqhs->bnhps", dtx * w_end[..., None], bf)
    chunk_decay = jnp.exp(cum[:, :, -1])                                # [B,n,H]

    h0 = (
        jnp.zeros((B, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def scan_fn(h, inp):
        s_c, dec = inp                                                  # [B,H,P,N],[B,H]
        h_prev = h
        h = h * dec[..., None, None] + s_c
        return h, h_prev

    hT, h_prevs = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                               # [B,n,H,P,N]

    y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
        "bnqhs,bnhps->bnqhp", cf, h_prevs
    )
    y = (y_intra + y_inter).reshape(B, S, H, P).astype(x.dtype)
    if return_state:
        return y, hT
    return y


def ssd_step(h, x_t, a_log, b_t, c_t, dt_t):
    """Single-token SSD update (decode path): h' = decay·h + dt·x⊗b; y = h'·c."""
    B, H, P = x_t.shape
    G, N = b_t.shape[1], b_t.shape[2]
    rep = H // G
    bf = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)
    cf = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * a_log.astype(jnp.float32)[None, :])           # [B,H]
    h = h * decay[..., None, None] + (dtf[..., None] * x_t.astype(jnp.float32))[
        ..., None
    ] * bf[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, cf).astype(x_t.dtype)
    return h, y


REG.register(KernelImpl(op="ssd", device_kind="any", source="reference", fn=ref.ssd))
REG.register(KernelImpl(op="ssd", device_kind="any", source="xla", fn=xla_ssd))
REG.register(
    KernelImpl(
        op="ssd", device_kind="tpu", source="pallas", fn=ssd_k.ssd,
        footprint=ssd_k.footprint(),
    )
)
