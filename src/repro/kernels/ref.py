"""Pure-jnp oracles for every Pallas kernel.

These are the "reference" source in the registry: always correct, never
hand-optimized.  Kernel tests sweep shapes/dtypes and assert_allclose the
Pallas implementations (interpret=True) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    out_dtype: jnp.dtype | None = None,
    activation: str | None = None,
) -> jax.Array:
    """[M, K] @ [K, N] with f32 accumulation."""
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation!r}")
    return acc.astype(out_dtype or x.dtype)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMS norm over the last axis, f32 statistics."""
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / rms) * weight.astype(jnp.float32)).astype(x.dtype)


def flash_attention(
    q: jax.Array,                   # [B, Hq, S, D]
    k: jax.Array,                   # [B, Hkv, T, D]
    v: jax.Array,                   # [B, Hkv, T, D]
    *,
    causal: bool = True,
    window: int | None = None,      # sliding window (inclusive of self)
    scale: float | None = None,
) -> jax.Array:
    """Exact attention oracle with GQA head grouping."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), kg.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(S)[:, None] + (T - S)    # decode: q at the end of the kv axis
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def conv2d(
    x: jax.Array,                   # [B, H, W, Cin]
    w: jax.Array,                   # [kh, kw, Cin, F]
    *,
    accum_dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """VALID conv, stride 1. int16 weights accumulate in int32 (paper roles 3/4)."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        accum_dtype = jnp.int32
    out = jax.lax.conv_general_dilated(
        x.astype(accum_dtype),
        w.astype(accum_dtype),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out


def ssd(
    x: jax.Array,                   # [B, S, H, P]   (heads, head dim)
    a_log: jax.Array,               # [H]            per-head decay log(a) < 0
    b: jax.Array,                   # [B, S, G, N]   input projection (groups, state)
    c: jax.Array,                   # [B, S, G, N]   output projection
    dt: jax.Array,                  # [B, S, H]      time deltas (positive)
    *,
    initial_state: jax.Array | None = None,   # [B, H, P, N]
    return_state: bool = False,
):
    """Mamba-2 SSD oracle: sequential state-space recurrence.

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t ⊗ b_t ;  y_t = h_t · c_t

    Heads are grouped over B/C (``G`` divides ``H``), as in Mamba-2.
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert H % G == 0
    rep = H // G
    xf = x.astype(jnp.float32)
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)    # [B,S,H,N]
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a_log.astype(jnp.float32)[None, None, :])   # [B,S,H]

    h0 = (
        jnp.zeros((B, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inputs):
        xt, bt, ct, dct, dtt = inputs           # [B,H,P],[B,H,N],[B,H,N],[B,H],[B,H]
        h = h * dct[..., None, None] + (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
        jnp.moveaxis(decay, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)   # [B,S,H,P]
    if return_state:
        return y, hT.astype(jnp.float32)
    return y


def gather_kv_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """Reassemble a per-sequence dense KV view from a paged pool.

    ``pages`` [P, Hkv, ps, D] is the global block pool; ``block_table``
    [B, NP] maps each sequence's page index to a pool page.  The result
    [B, Hkv, NP*ps, D] holds position ``t`` of sequence ``b`` at
    ``[b, :, t]`` — exactly the dense cache layout, so any dense decode
    attention runs unchanged (and bitwise-identically) on the gather.
    """
    B, NP = block_table.shape
    _, Hkv, ps, D = pages.shape
    out = jnp.take(pages, block_table, axis=0)           # [B, NP, Hkv, ps, D]
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, NP * ps, D)


def paged_decode_attention(
    q: jax.Array,                   # [B, Hq, D] single query token
    k_pages: jax.Array,             # [P, Hkv, ps, D] global block pool
    v_pages: jax.Array,             # [P, Hkv, ps, D]
    block_table: jax.Array,         # [B, NP] page index -> pool page
    length: jax.Array | int,        # valid cache length (scalar or [B])
    *,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention over a paged KV cache: gather, then dense oracle.

    Positions ``>= length`` (page tails, unmapped table entries pointing at
    the reserved scratch page) are masked before the softmax, so their
    contents never reach the output.
    """
    kg = gather_kv_pages(k_pages, block_table)
    vg = gather_kv_pages(v_pages, block_table)
    return decode_attention(q, kg, vg, length, scale=scale)


def decode_attention(
    q: jax.Array,                   # [B, Hq, D] single query token
    k_cache: jax.Array,             # [B, Hkv, T, D]
    v_cache: jax.Array,             # [B, Hkv, T, D]
    length: jax.Array | int,        # valid cache length (scalar or [B])
    *,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention over a (possibly padded) KV cache."""
    B, Hq, D = q.shape
    Hkv, T = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    kg = jnp.repeat(k_cache, group, axis=1)
    vg = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kg.astype(jnp.float32))
    logits = logits * scale
    pos = jnp.arange(T)[None, :]
    lengths = jnp.asarray(length)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    valid = pos < lengths[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bht,bhtd->bhd", probs, vg.astype(jnp.float32))
    return out.astype(q.dtype)
