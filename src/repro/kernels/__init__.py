"""Pallas TPU kernels (roles) + XLA/reference implementations.

Importing ``repro.kernels.ops`` registers every implementation in the global
kernel registry.
"""

from repro.kernels import ops  # noqa: F401  (registration side effect)
