"""Pallas decode-attention kernel: one query token against a long KV cache.

The serving hot-spot: per decoded token the MXU does almost nothing and the
chip streams the KV cache from HBM once — so the kernel's job is to be
perfectly memory-shaped.  Design:

  - grid (B, Hkv, T/bk), KV-block axis innermost; the bf16 cache streams
    HBM→VMEM in ``bk``-sized tiles and is read exactly once.
  - GQA is blocked natively: one grid cell processes all ``group`` query
    heads of a kv head against the tile ([group, bk] logits fill MXU lanes).
  - online softmax (running max / denominator / accumulator in VMEM scratch),
    identical algebra to the flash kernel.
  - ``lengths`` [B] masks per-sequence valid cache (continuous batching:
    slots hold different positions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import ResourceFootprint

NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                m_ref, l_ref, acc_ref,
                *, scale: float, block_k: int, n_k: int) -> None:
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)              # [bk, hd]
    length = len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [group, bk]
    kpos = ti * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ti == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,                   # [B, Hq, hd]
    k_cache: jax.Array,             # [B, Hkv, T, hd]
    v_cache: jax.Array,
    length,                         # scalar or [B] valid cache lengths
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv, T = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bk = min(block_k, T)
    if T % bk:
        raise ValueError(f"T={T} not divisible by block_k={bk}")
    n_k = T // bk
    scale = scale if scale is not None else 1.0 / float(np.sqrt(hd))

    lengths = jnp.asarray(length)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    lengths = lengths.astype(jnp.int32)
    qg = q.reshape(B, Hkv, group, hd)

    kernel = functools.partial(_dec_kernel, scale=scale, block_k=bk, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_k),                       # KV innermost
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1,), lambda b, h, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, lengths)
    return out.reshape(B, Hq, hd)


# ---------------------------------------------------------------------------
# paged (block-table) decode attention
# ---------------------------------------------------------------------------


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref,
                  *, scale: float, page_size: int, n_pages: int) -> None:
    """Same online softmax as :func:`_dec_kernel`, but the KV tile streamed at
    grid step ``i`` is pool page ``table_ref[b, i]`` (resolved by the
    scalar-prefetched block table in the BlockSpec index maps) instead of the
    ``i``-th contiguous slice of a dense cache — the cache never has to be
    contiguous in HBM, so the serving layer can allocate it page-at-a-time."""
    b = pl.program_id(0)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
    k = k_ref[0, 0].astype(jnp.float32)              # [ps, hd]
    v = v_ref[0, 0].astype(jnp.float32)              # [ps, hd]
    length = len_ref[b]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [group, ps]
    kpos = ti * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ti == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,                   # [B, Hq, hd]
    k_pages: jax.Array,             # [P, Hkv, ps, hd] global block pool
    v_pages: jax.Array,
    block_table: jax.Array,         # [B, NP] int32 page index -> pool page
    length,                         # scalar or [B] valid cache lengths
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over a paged KV cache via a scalar-prefetched block
    table: grid (B, Hkv, NP), the page axis innermost, each KV tile DMA'd
    straight from its (non-contiguous) pool page.  Unlike the gather-based
    XLA formulation, no dense [B, Hkv, T, hd] copy is ever materialized in
    HBM — the gather happens on the HBM→VMEM stream."""
    B, Hq, hd = q.shape
    Hkv, ps = k_pages.shape[1], k_pages.shape[2]
    NP = block_table.shape[1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(hd))

    lengths = jnp.asarray(length)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    lengths = lengths.astype(jnp.int32)
    table = block_table.astype(jnp.int32)
    qg = q.reshape(B, Hkv, group, hd)

    kernel = functools.partial(
        _paged_kernel, scale=scale, page_size=ps, n_pages=NP
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # block table + lengths
        grid=(B, Hkv, NP),                        # page axis innermost
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, i, tab, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, i, tab, ln: (tab[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, i, tab, ln: (tab[b, i], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, i, tab, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        interpret=interpret,
    )(table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, Hq, hd)


def paged_footprint(group: int = 8, page_size: int = 64, hd: int = 128,
                    itemsize: int = 2) -> ResourceFootprint:
    vmem = (
        group * hd * (itemsize + 4)       # q tile + accumulator
        + 2 * page_size * hd * itemsize   # k, v page tiles
        + group * page_size * 4           # logits tile
        + 2 * group * 4                   # m, l
    )
    return ResourceFootprint(vmem_bytes=vmem,
                             mxu_tiles=2 * max(1, page_size // 128))


def footprint(group: int = 8, block_k: int = 512, hd: int = 128,
              itemsize: int = 2) -> ResourceFootprint:
    vmem = (
        group * hd * (itemsize + 4)     # q tile + accumulator
        + 2 * block_k * hd * itemsize   # k, v tiles
        + group * block_k * 4           # logits tile
        + 2 * group * 4                 # m, l
    )
    return ResourceFootprint(vmem_bytes=vmem,
                             mxu_tiles=2 * max(1, block_k // 128))
