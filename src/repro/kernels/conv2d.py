"""Small-filter convolution roles — paper Table I roles 3 & 4.

The paper's roles 3/4 are a 5×5/1-filter and a 3×3/2-filter VALID convolution
with fixed int16 weights packed into DSP slices.  The MXU-idiomatic equivalent
unrolls the kh×kw taps into shifted multiply-accumulates over a VMEM-resident
image tile (int16 → int32 accumulation; the MXU's native int8/int16 path).
``conv2d_fixed_weight`` bakes the weights as compile-time constants — the
weight-specialized role the paper trades regions for.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.registry import ResourceFootprint


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, accum_dtype) -> None:
    x = x_ref[0].astype(accum_dtype)              # [H, W, Cin]
    w = w_ref[...].astype(accum_dtype)            # [kh, kw, Cin, F]
    H, W, _ = x.shape
    oh, ow = H - kh + 1, W - kw + 1
    acc = jnp.zeros((oh, ow, w.shape[-1]), accum_dtype)
    for di in range(kh):                           # static unroll over taps
        for dj in range(kw):
            patch = x[di:di + oh, dj:dj + ow, :]   # [oh, ow, Cin]
            acc = acc + jnp.einsum(
                "hwc,cf->hwf", patch, w[di, dj],
                preferred_element_type=accum_dtype,
            )
    o_ref[0] = acc.astype(o_ref.dtype)


def conv2d(
    x: jax.Array,                   # [B, H, W, Cin]
    w: jax.Array,                   # [kh, kw, Cin, F]
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, W, Cin = x.shape
    kh, kw, Cin2, F = w.shape
    assert Cin == Cin2, (x.shape, w.shape)
    accum_dtype = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32
    oh, ow = H - kh + 1, W - kw + 1

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, accum_dtype=accum_dtype)
    return pl.pallas_call(
        kernel,
        grid=(B,),                                 # one image tile per grid step
        in_specs=[
            pl.BlockSpec((1, H, W, Cin), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, Cin, F), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, F), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, oh, ow, F), accum_dtype),
        interpret=interpret,
    )(x, w)


def conv2d_fixed_weight(w: jax.Array) -> Callable[..., jax.Array]:
    """Weight-specialized conv role (paper roles 3/4: 'fixed weights')."""
    w_const = jnp.asarray(w)

    def fixed(x: jax.Array, *, interpret: bool = False) -> jax.Array:
        return conv2d(x, w_const, interpret=interpret)

    fixed.__name__ = f"conv2d_fixed_{w.shape[0]}x{w.shape[1]}x{w.shape[3]}"
    return fixed


def footprint(h: int = 128, w: int = 128, cin: int = 1, f: int = 2,
              kh: int = 3, kw: int = 3, itemsize: int = 2) -> ResourceFootprint:
    vmem = h * w * cin * itemsize + kh * kw * cin * f * itemsize + h * w * f * 4
    return ResourceFootprint(vmem_bytes=vmem)
