"""Fused RMSNorm kernel.

One HBM round-trip instead of three (square-reduce, normalize, scale): rows
are blocked into VMEM, statistics computed in f32 on-chip, and the scaled
result written once.  The feature axis is kept whole per block (d_model up to
8192 ≈ 32 KiB/row at f32 — trivially VMEM-resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.registry import ResourceFootprint


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float) -> None:
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,                  # [..., D]
    weight: jax.Array,             # [D]
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    if rows % br:
        # fall back to a row count that divides; pallas grids must tile exactly
        br = 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)


def footprint(block_rows: int = 256, d: int = 8192, itemsize: int = 2) -> ResourceFootprint:
    return ResourceFootprint(vmem_bytes=block_rows * d * (itemsize + 4) + d * itemsize)
