"""Paper Table III: efficiency benefit of registered roles vs plain CPU (n=1000).

The paper compares FPGA roles against a plain ARM Cortex-A53 implementation
in OP/cycle.  Host analogue: per-op NumPy eager execution (the "plain CPU"
path a developer writes by hand) vs the registered, compiled role executable
(XLA-fused).  OP/cycle derives from measured ops/s over the host clock; the
``tpu_target`` column adds the roofline OP/cycle of the Pallas role on the
TPU v5e MXU for the same shapes.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import FC_DIM, IMG, make_paper_roles, pallas_footprints
from repro.core.hsa import hsa_init, hsa_shut_down
from repro.core.ledger import OverheadLedger
from repro.hw import TPU_V5E

HOST_HZ = 3.0e9          # nominal host clock for OP/cycle accounting


def _flops(name: str) -> float:
    if name.startswith(("role1", "role2")):
        return 2.0 * FC_DIM ** 3
    if "conv5x5" in name:
        return 2.0 * (IMG - 4) ** 2 * 25
    return 2.0 * (IMG - 2) ** 2 * 9 * 2


def _numpy_baseline(name: str, args) -> float:
    """Plain per-op host implementation, timed per call (seconds)."""
    n = 50
    if name.startswith(("role1", "role2")):
        a, b = (np.asarray(x, np.float32) for x in args)
        t = time.perf_counter()
        for _ in range(n):
            out = a @ b
        return (time.perf_counter() - t) / n
    (x,) = args
    xi = np.asarray(x, np.int32)[0, :, :, 0]
    kh = 5 if "5x5" in name else 3
    f = 1 if "5x5" in name else 2
    w = np.ones((kh, kh, f), np.int32)
    t = time.perf_counter()
    for _ in range(n):
        oh, ow = xi.shape[0] - kh + 1, xi.shape[1] - kh + 1
        acc = np.zeros((oh, ow, f), np.int32)
        for di in range(kh):
            for dj in range(kh):
                acc += xi[di:di + oh, dj:dj + ow, None] * w[di, dj]
    return (time.perf_counter() - t) / n


def run(n: int = 1000) -> list[str]:
    hsa_shut_down()
    sys_ = hsa_init(num_regions=4, ledger=OverheadLedger())
    rows = []
    try:
        roles = make_paper_roles(sys_.library)
        sys_.library.synthesize_all()
        fps = pallas_footprints()
        for name, (role, args) in roles.items():
            exe = role.load()
            jax.block_until_ready(exe(*args))       # warm
            t = time.perf_counter()
            for _ in range(n):
                out = exe(*args)
            jax.block_until_ready(out)
            accel_s = (time.perf_counter() - t) / n
            base_s = _numpy_baseline(name, args)

            flops = _flops(name)
            ops_cycle_base = flops / (base_s * HOST_HZ)
            ops_cycle_accel = flops / (accel_s * HOST_HZ)
            speedup = base_s / accel_s
            # TPU-target: MXU utilisation implied by the Pallas footprint
            tpu_opc = min(flops, TPU_V5E.flops_per_cycle)
            rows.append(
                f"table3,{name},{accel_s*1e6:.1f},"
                f"op_cycle_increase={speedup:.2f};"
                f"base_us={base_s*1e6:.1f};opc_base={ops_cycle_base:.2f};"
                f"opc_accel={ops_cycle_accel:.2f};"
                f"tpu_target_opc={tpu_opc:.0f}"
            )
    finally:
        hsa_shut_down()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
