"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Run with
``PYTHONPATH=src python -m benchmarks.run [--fast] [--json [PATH]]``.

``--json`` additionally writes the rows as machine-readable JSON so CI can
smoke-test the perf trajectory and downstream tooling can diff runs without
re-parsing CSV.  PATH is optional and defaults to ``BENCH_results.json`` at
the repo root.  Schema 2: ``{"schema": 2, "git_sha": str, "fast": bool,
"rows": [{"table", "metric", "value", "derived"}]}``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

JSON_SCHEMA = 2

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_results.json")


def git_sha() -> str:
    """Current commit sha, so a results file is attributable to a tree state."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=REPO_ROOT, timeout=10,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def parse_row(row: str) -> dict:
    """Split one ``table,metric,value,derived`` CSV row; the derived field may
    itself contain commas (it is everything after the third)."""
    parts = row.split(",", 3)
    table, metric = parts[0], parts[1] if len(parts) > 1 else ""
    try:
        value: float | None = float(parts[2]) if len(parts) > 2 else None
    except ValueError:
        value = None
    return {
        "table": table,
        "metric": metric,
        "value": value,
        "derived": parts[3] if len(parts) > 3 else "",
    }


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    fast = "--fast" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 < len(argv) and not argv[i + 1].startswith("--"):
            json_path = argv[i + 1]
        else:
            json_path = DEFAULT_JSON
    n = 100 if fast else 1000

    from benchmarks import (
        table1_utilization,
        table2_overhead,
        table3_efficiency,
        table4_multitenancy,
        table5_prefetch,
        table6_dispatch,
        table7_paged,
        table8_overcommit,
        table9_traffic,
        table10_faults,
        table11_spill,
        table12_integrity,
        table13_prefix,
    )

    suites = (
        (table1_utilization.run, {}),
        (table2_overhead.run, {"n": n}),
        (table3_efficiency.run, {"n": n}),
        (table4_multitenancy.run, {"n": min(n, 128)}),
        (table5_prefetch.run, {"n": min(n, 64)}),
        (table6_dispatch.run, {"n": min(n, 64)}),
        (table7_paged.run, {"n": min(n, 64)}),
        (table8_overcommit.run, {"n": min(n, 64)}),
        (table9_traffic.run, {"n": min(n, 64)}),
        (table10_faults.run, {"n": min(n, 48)}),
        (table11_spill.run, {"n": min(n, 64)}),
        (table12_integrity.run, {"n": min(n, 48)}),
        (table13_prefix.run, {"n": min(n, 64)}),
    )
    print("name,us_per_call,derived", flush=True)
    rows: list[str] = []
    for fn, kw in suites:          # stream per table: slow != wedged
        table_rows = fn(**kw)
        for row in table_rows:
            print(row)
        sys.stdout.flush()
        rows += table_rows

    if json_path is not None:
        payload = {
            "schema": JSON_SCHEMA,
            "git_sha": git_sha(),
            "fast": fast,
            "rows": [parse_row(r) for r in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(payload['rows'])} rows to {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
