"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Run with
``PYTHONPATH=src python -m benchmarks.run [--fast] [--json PATH]``.

``--json PATH`` additionally writes the rows as machine-readable JSON
(schema 1: ``{"schema": 1, "fast": bool, "rows": [{"table", "metric",
"value", "derived"}]}``) so CI can smoke-test the perf trajectory and
downstream tooling can diff runs without re-parsing CSV.
"""

from __future__ import annotations

import json
import sys

JSON_SCHEMA = 1


def parse_row(row: str) -> dict:
    """Split one ``table,metric,value,derived`` CSV row; the derived field may
    itself contain commas (it is everything after the third)."""
    parts = row.split(",", 3)
    table, metric = parts[0], parts[1] if len(parts) > 1 else ""
    try:
        value: float | None = float(parts[2]) if len(parts) > 2 else None
    except ValueError:
        value = None
    return {
        "table": table,
        "metric": metric,
        "value": value,
        "derived": parts[3] if len(parts) > 3 else "",
    }


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    fast = "--fast" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires a path argument")
        json_path = argv[i + 1]
    n = 100 if fast else 1000

    from benchmarks import (
        table1_utilization,
        table2_overhead,
        table3_efficiency,
        table4_multitenancy,
        table5_prefetch,
    )

    suites = (
        (table1_utilization.run, {}),
        (table2_overhead.run, {"n": n}),
        (table3_efficiency.run, {"n": n}),
        (table4_multitenancy.run, {"n": min(n, 128)}),
        (table5_prefetch.run, {"n": min(n, 64)}),
    )
    print("name,us_per_call,derived", flush=True)
    rows: list[str] = []
    for fn, kw in suites:          # stream per table: slow != wedged
        table_rows = fn(**kw)
        for row in table_rows:
            print(row)
        sys.stdout.flush()
        rows += table_rows

    if json_path is not None:
        payload = {
            "schema": JSON_SCHEMA,
            "fast": fast,
            "rows": [parse_row(r) for r in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(payload['rows'])} rows to {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
