"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Run with
``PYTHONPATH=src python -m benchmarks.run [--fast]``.
"""

from __future__ import annotations

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    n = 100 if fast else 1000

    from benchmarks import (
        table1_utilization,
        table2_overhead,
        table3_efficiency,
        table4_multitenancy,
    )

    print("name,us_per_call,derived")
    for row in table1_utilization.run():
        print(row)
    for row in table2_overhead.run(n=n):
        print(row)
    for row in table3_efficiency.run(n=n):
        print(row)
    for row in table4_multitenancy.run(n=min(n, 128)):
        print(row)


if __name__ == "__main__":
    main()
