"""Table IV (extension): multi-tenant scheduling — async multi-queue vs sync.

Reproduces the paper's co-residency scenario end-to-end: the serving engine's
decode launches land on one HSA soft queue while a synthetic "OpenCL-style"
background producer cycles fixed-weight conv roles through the reconfigurable
regions on a second queue.  Two schedules of the *same* packet workload:

  sync   — single queue, reconfiguration occupies the device
           (the seed's blocking executor),
  async  — two queues, round-robin grants, reconfiguration engine overlapped
           so only the missing queue stalls.

Costs are calibrated from real measured loads/executions, then both schedules
run on the deterministic virtual clock, so the reported device-idle fractions
are exact properties of the schedule (not timer noise).  The async idle
fraction must be strictly lower.  Per-queue wait/exec/reconfig comes from the
overhead ledger's queue breakdown.
"""

from __future__ import annotations

from benchmarks.common import calibrate_costs, make_paper_roles
from repro.core import ledger as L
from repro.core.hsa.clock import VirtualClock
from repro.core.hsa.queue import Queue
from repro.core.hsa.scheduler import Scheduler
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary

# producer-cycle roles: 4 roles through 2 regions -> reconfig on every packet
BG_ORDER = ("role3_conv5x5", "role4_conv3x3", "role1_fc", "role3_conv5x5")


def _decode_workload(engine_steps: int):
    """The decode tenant: ServeEngine driving real decode steps when the model
    stack is available, else a matmul stand-in with the same cadence."""
    try:
        import jax
        import numpy as np

        from repro.configs import ARCHS, reduced
        from repro.models import build_model
        from repro.models.params import init_params

        cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.key(0))
        from repro.serve.engine import ServeEngine

        def make(queue, scheduler):
            eng = ServeEngine(model, params, batch_slots=2, max_len=32,
                              hsa_queue=queue, hsa_scheduler=scheduler)
            eng.submit(list(np.arange(4) + 1), max_new_tokens=engine_steps)
            eng.submit([7, 9], max_new_tokens=engine_steps)
            return eng

        return make
    except Exception:                      # pragma: no cover - reduced envs
        return None


def _run_schedule(roles, costs, *, nbg: int, engine_steps: int,
                  multi_queue: bool) -> tuple[Scheduler, OverheadLedger]:
    ledger = OverheadLedger()
    lib = RoleLibrary(ledger=ledger)
    # re-register this run's roles in a fresh library (fresh residency state)
    run_roles = {}
    for name, (role, args) in roles.items():
        run_roles[name] = (lib.add(role), args)
        role.unload()
    regions = RegionManager(2, ledger=ledger)
    clock = VirtualClock()
    sched = Scheduler(
        regions, lib, ledger=ledger, clock=clock,
        cost_model=lambda kind, what, measured: costs.get((kind, what), measured),
        overlap_reconfig=multi_queue,
    )
    q_serve = sched.add_queue(Queue(None, 4096, name="serve"))
    q_bg = (
        sched.add_queue(Queue(None, 4096, name="opencl")) if multi_queue else q_serve
    )

    # background producer: submit everything up front (a saturating tenant)
    for i in range(nbg):
        role, args = run_roles[BG_ORDER[i % len(BG_ORDER)]]
        q_bg.dispatch(role.key, *args, producer="opencl")

    make_engine = _decode_workload(engine_steps)
    if make_engine is not None:
        engine = make_engine(q_serve, sched)
        engine.run_to_completion(max_steps=engine_steps + 8)
    else:
        role, args = run_roles["role1_fc"]
        for _ in range(engine_steps):
            q_serve.dispatch(role.key, *args, producer="tf-serving")
    sched.run_until_idle()
    return sched, ledger


def run(n: int = 64) -> list[str]:
    probe_ledger = OverheadLedger()
    probe_lib = RoleLibrary(ledger=probe_ledger)
    roles = make_paper_roles(probe_lib)
    costs = calibrate_costs(roles)

    engine_steps = max(4, min(16, n // 8))
    sync_sched, _ = _run_schedule(
        roles, costs, nbg=n, engine_steps=engine_steps, multi_queue=False
    )
    async_sched, async_ledger = _run_schedule(
        roles, costs, nbg=n, engine_steps=engine_steps, multi_queue=True
    )

    t_sync = sync_sched.timeline()
    t_async = async_sched.timeline()
    rows = [
        f"table4,device_idle_fraction_sync,{t_sync['idle_fraction']:.4f},"
        f"makespan_us={t_sync['makespan_s']*1e6:.0f}",
        f"table4,device_idle_fraction_async,{t_async['idle_fraction']:.4f},"
        f"makespan_us={t_async['makespan_s']*1e6:.0f};"
        f"overlap_wins={t_async['idle_fraction'] < t_sync['idle_fraction']}",
    ]
    for qname, rep in sorted(async_sched.queue_report().items()):
        rows.append(
            f"table4,queue_{qname},{rep['exec_s']*1e6:.0f},"
            f"wait_us={rep['wait_s']*1e6:.0f};reconfig_us={rep['reconfig_s']*1e6:.0f};"
            f"dispatched={int(rep['dispatched'])}"
        )
    for qname, cats in sorted(async_ledger.queue_breakdown().items()):
        parts = ";".join(
            f"{c}={s.total_s*1e6:.0f}us/n{s.count}" for c, s in sorted(cats.items())
        )
        rows.append(f"table4,ledger_{qname},0,{parts}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
