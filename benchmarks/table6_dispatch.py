"""Table VI (extension): fused decode × burst submission — invocation overhead.

Paper Table II charges a fixed "dispatch latency" to *every* kernel
invocation; the toolflow surveys (Venieris et al., Guo et al.) single out
launch amortization as the lever separating batch-style accelerators from
per-op ones.  This benchmark measures that lever on the serving hot path,
where the ledger now splits the invocation round trip into
``dispatch_submit`` (packet write + doorbell), ``dispatch_grant`` (scheduler
pick-up -> launch) and ``dispatch_wait`` (producer blocked on completion):

  1. **Calibrated virtual-clock trace** — a serving producer generating
     ``n`` tokens as dependency-chained decode packets.  Fusion depth K
     folds K tokens into one packet (virtual exec time scales with K, from
     the real measured per-token cost); burst depth B submits B chained
     packets per doorbell and waits them with one ``wait_all``.  The
     ``dispatch_*`` legs are real measured host seconds, so per-token
     overhead is an honest host-cost measurement even though the device
     timeline is simulated.
  2. **Real-jax serving path** — ``ServeEngine(decode_fusion=K)`` routed
     through the HSA queue on a tiny model: same split, real launches.

Acceptance: per-token dispatch overhead at K>=4 must undercut K=1 by >=2x
on the calibrated trace (the ``fusion_wins`` row CI asserts).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import calibrate_costs, make_paper_roles
from repro.core import ledger as ledger_mod
from repro.core.hsa.clock import VirtualClock
from repro.core.hsa.queue import Queue, call_packet
from repro.core.hsa.scheduler import Scheduler
from repro.core.hsa.signal import wait_all
from repro.core.ledger import OverheadLedger
from repro.core.reconfig import RegionManager
from repro.core.roles import RoleLibrary

FUSION_SWEEP = (1, 2, 4, 8)
BURST_SWEEP = (1, 8)


def _dispatch_overhead_us_per_token(ledger: OverheadLedger, ntokens: int) -> float:
    split = ledger.dispatch_split()
    return (split["total_s"] / ntokens) * 1e6


def _run_trace(ntokens: int, k: int, burst: int, exec_tok_s: float) -> OverheadLedger:
    """One serving producer: ntokens tokens as chained decode packets."""
    ledger = OverheadLedger()
    lib = RoleLibrary(ledger=ledger)
    regions = RegionManager(2, ledger=ledger)
    costs = {("exec", f"decode_k{k}"): k * exec_tok_s}
    sched = Scheduler(
        regions, lib, ledger=ledger, clock=VirtualClock(),
        cost_model=lambda kind, what, measured: costs.get((kind, what), measured),
    )
    q = sched.add_queue(Queue(None, 8192, name="serve"))

    def decode_launch():
        return None                      # host no-op: device time is simulated

    decode_launch.__name__ = f"decode_k{k}"

    npackets = -(-ntokens // k)          # ceil: the last launch is partial
    submitted = 0
    prev = None
    while submitted < npackets:
        b = min(burst, npackets - submitted)
        pkts = []
        for _ in range(b):
            pkt = call_packet(
                decode_launch, producer="tf-serving",
                deps=(prev.completion,) if prev is not None else (),
            )
            pkts.append(pkt)
            prev = pkt
        if b == 1:
            q.submit(pkts[0])
        else:
            q.submit_burst(pkts)
        sched.drain(q)
        # the producer's completion-wait leg: one wait covers the burst
        t0 = time.perf_counter_ns()
        wait_all([p.completion for p in pkts], 0)
        dt = (time.perf_counter_ns() - t0) * 1e-9
        for p in pkts:
            ledger.record(
                ledger_mod.DISPATCH_WAIT, dt / b, queue=q.name,
                producer="tf-serving", burst=b,
            )
        submitted += b
    return ledger


def _run_serving(n_new: int, k) -> tuple[float, list[list[int]], int]:
    """Real-jax path: a tiny LM served through the HSA queue at fusion k."""
    from repro.configs import ARCHS, reduced
    from repro.models import build_model
    from repro.models.params import init_params
    from repro.serve.engine import ServeEngine

    cfg = reduced(ARCHS["llama3.2-1b"], layers=2, d_model=64, vocab=128)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))

    ledger = OverheadLedger()
    lib = RoleLibrary(ledger=ledger)
    sched = Scheduler(RegionManager(2, ledger=ledger), lib, ledger=ledger,
                      clock=VirtualClock())
    q = sched.add_queue(Queue(None, 4096, name="serve"))
    eng = ServeEngine(model, params, batch_slots=2, max_len=64,
                      decode_fusion=k, hsa_queue=q, hsa_scheduler=sched)
    # warm the jit caches (prefill bucket + every fused-decode trace this
    # request-length mix will hit), then measure from a clean ledger so the
    # dispatch legs reflect steady-state serving, not one-time compiles
    eng.submit([9, 9, 9, 9], max_new_tokens=n_new)
    eng.run_to_completion()
    ledger.reset()
    warm_packets = int(sched.queue_report()["serve"]["dispatched"])
    for p in ([3, 14, 15, 92], [7, 8], [1, 2, 3]):
        eng.submit(p, max_new_tokens=n_new)
    done = eng.run_to_completion()
    tokens = sum(len(r.generated) for r in done)
    packets = int(sched.queue_report()["serve"]["dispatched"]) - warm_packets
    return (
        _dispatch_overhead_us_per_token(ledger, tokens),
        [r.generated for r in sorted(done, key=lambda r: r.uid)],
        packets,
    )


def run(n: int = 64) -> list[str]:
    # calibrate the per-token decode cost from one real measured role exec
    probe_ledger = OverheadLedger()
    probe_lib = RoleLibrary(ledger=probe_ledger)
    roles = make_paper_roles(probe_lib)
    costs = calibrate_costs(roles)
    exec_tok_s = costs[("exec", "role1_fc")]

    ntokens = max(32, n)
    rows = []
    per_tok: dict[tuple[int, int], float] = {}
    for k in FUSION_SWEEP:
        for burst in BURST_SWEEP:
            ledger = _run_trace(ntokens, k, burst, exec_tok_s)
            us = _dispatch_overhead_us_per_token(ledger, ntokens)
            per_tok[(k, burst)] = us
            split = ledger.dispatch_split()
            rows.append(
                f"table6,dispatch_per_token_k{k}_b{burst},{us:.2f},"
                f"submit_us={split['submit_s']*1e6:.0f};"
                f"grant_us={split['grant_s']*1e6:.0f};"
                f"wait_us={split['wait_s']*1e6:.0f};"
                f"packets={split['submit_n']:.0f};tokens={ntokens}"
            )

    base = per_tok[(1, 1)]
    fused = per_tok[(4, 1)]
    reduction = base / fused if fused else float("inf")
    ok = fused * 2.0 <= base
    rows.append(
        f"table6,fusion_wins,{int(ok)},"
        f"k1_us_per_tok={base:.2f};k4_us_per_tok={fused:.2f};"
        f"reduction_x={reduction:.1f}"
    )

    # burst amortization at fixed K: submit leg must shrink
    b1 = per_tok[(1, 1)]
    b8 = per_tok[(1, 8)]
    rows.append(
        f"table6,burst_amortization,{b1/b8 if b8 else 0.0:.2f},"
        f"b1_us_per_tok={b1:.2f};b8_us_per_tok={b8:.2f}"
    )

    # real-jax serving path: same split through actual fused launches
    n_new = 8 if n <= 128 else 12
    us1, gen1, pkts1 = _run_serving(n_new, 1)
    us4, gen4, pkts4 = _run_serving(n_new, 4)
    identical = int(gen1 == gen4)
    rows.append(
        f"table6,serve_dispatch_per_token_k1,{us1:.1f},packets={pkts1}"
    )
    rows.append(
        f"table6,serve_dispatch_per_token_k4,{us4:.1f},"
        f"packets={pkts4};identical_streams={identical}"
    )
    rows.append(
        f"table6,serve_fused_identical,{identical},"
        f"k1_packets={pkts1};k4_packets={pkts4}"
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
