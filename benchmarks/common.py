"""Shared benchmark fixtures: the paper's four roles, sized per §IV.

Role 1: fully connected (float32)
Role 2: fully connected with barrier (float32)     — barrier-AND packet sync
Role 3: conv 5×5, 1 filter, fixed weights (int16)
Role 4: conv 3×3, 2 filters, fixed weights (int16)
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401
from repro.core.ledger import OverheadLedger
from repro.core.registry import FIXED_WEIGHT, GLOBAL_REGISTRY, KernelImpl
from repro.core.roles import Role, RoleLibrary
from repro.kernels.conv2d import conv2d_fixed_weight
from repro.kernels import matmul as matmul_k
from repro.kernels import conv2d as conv2d_k

RNG = np.random.default_rng(0)

FC_DIM = 256
IMG = 64


def make_paper_roles(lib: RoleLibrary):
    """Returns dict name -> (role, concrete_args)."""
    roles = {}

    # Roles 1 & 2: generic fully connected; role 2 is the barrier-synchronised
    # variant (distinct op so it occupies its own region, as on the FPGA)
    fc_impl = GLOBAL_REGISTRY.resolve("matmul", "any", ("xla",))
    barrier_impl = KernelImpl(
        op="fc_barrier", device_kind="any", source="xla", fn=fc_impl.fn,
        footprint=fc_impl.footprint,
    )
    GLOBAL_REGISTRY.register(barrier_impl, allow_override=True)
    x = jnp.asarray(RNG.normal(size=(FC_DIM, FC_DIM)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(FC_DIM, FC_DIM)), jnp.float32)
    a = jax.ShapeDtypeStruct((FC_DIM, FC_DIM), jnp.float32)
    roles["role1_fc"] = (lib.make_role(fc_impl, (a, a), name="role1_fc"), (x, w))
    roles["role2_fc_barrier"] = (
        lib.make_role(barrier_impl, (a, a), name="role2_fc_barrier"), (x, w),
    )

    # Roles 3 & 4: fixed-weight int16 conv (weights baked into the program)
    w5 = jnp.asarray(RNG.integers(-8, 8, size=(5, 5, 1, 1)), jnp.int16)
    w3 = jnp.asarray(RNG.integers(-8, 8, size=(3, 3, 1, 2)), jnp.int16)
    xi = jnp.asarray(RNG.integers(-100, 100, size=(1, IMG, IMG, 1)), jnp.int16)
    xa = jax.ShapeDtypeStruct((1, IMG, IMG, 1), jnp.int16)

    for name, wfix in (("role3_conv5x5", w5), ("role4_conv3x3", w3)):
        # fixed-weight role, host-executable (XLA source); the Pallas
        # conv2d_fixed_weight variant is the TPU-target twin (same algebra,
        # golden-tested in tests/test_kernels.py)
        def fixed_fn(x, *, _w=wfix):
            from repro.kernels import ref
            return ref.conv2d(x, _w)

        impl = KernelImpl(
            op=f"{name}", device_kind="any", source="xla", fn=fixed_fn,
            specialization=FIXED_WEIGHT,
            footprint=conv2d_k.footprint(IMG, IMG, 1, wfix.shape[-1],
                                         wfix.shape[0], wfix.shape[1], 2),
        )
        GLOBAL_REGISTRY.register(impl, allow_override=True)
        roles[name] = (lib.make_role(impl, (xa,), name=name), (xi,))

    return roles


def calibrate_costs(roles) -> dict[tuple[str, str], float]:
    """Measure one real load + exec per role; the measured seconds drive the
    virtual timeline of the scheduling benchmarks (table4/table5)."""
    import time

    costs: dict[tuple[str, str], float] = {}
    for name, (role, args) in roles.items():
        role.synthesize()
        t0 = time.perf_counter()
        exe = role.load()
        costs[("reconfig", role.name)] = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = exe(*args)
        jnp.asarray(out).block_until_ready()
        costs[("exec", role.name)] = time.perf_counter() - t0
        role.unload()
    return costs


def pallas_footprints():
    """Per-role VMEM/MXU claims of the Pallas (TPU-target) implementations."""
    return {
        "role1_fc": matmul_k.footprint(FC_DIM, FC_DIM, FC_DIM, 4),
        "role2_fc_barrier": matmul_k.footprint(FC_DIM, FC_DIM, FC_DIM, 4),
        "role3_conv5x5": conv2d_k.footprint(IMG, IMG, 1, 1, 5, 5, 2),
        "role4_conv3x3": conv2d_k.footprint(IMG, IMG, 1, 2, 3, 3, 2),
    }
